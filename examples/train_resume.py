"""Fault-tolerance drill: train, crash mid-run, resume, verify equivalence.

Demonstrates the production restart story end-to-end on CPU:
  * checkpoints are atomic (tmp+rename) and written asynchronously,
  * the data pipeline is step-indexed, so the resumed run consumes exactly
    the batches a never-failed run would have,
  * the resumed run's final loss matches an uninterrupted reference run
    bit-for-bit.

  PYTHONPATH=src python examples/train_resume.py
"""

import shutil
import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np

from repro import configs as C
from repro.data.pipeline import DataConfig
from repro.optim import adamw as O
from repro.train import (SimulatedFailure, TrainLoopConfig, run_training)


def main() -> None:
    cfg = C.get_smoke("gemma2-27b")
    opt = O.OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=40)
    data = DataConfig(vocab=cfg.vocab, batch=4, seq=24, seed=11)
    ckdir = tempfile.mkdtemp(prefix="kratos_ck_")

    print("=== reference run (no failure) ===")
    ref = run_training(cfg, opt, data,
                       TrainLoopConfig(steps=40, log_every=10))

    print("\n=== run with injected failure at step 23 ===")
    try:
        run_training(cfg, opt, data, TrainLoopConfig(
            steps=40, ckpt_dir=ckdir, ckpt_every=10, log_every=10,
            fail_at_step=23))
    except SimulatedFailure as e:
        print(f"!! crashed as injected: {e}")

    print("\n=== resume (same command, auto-restores latest checkpoint) ===")
    out = run_training(cfg, opt, data, TrainLoopConfig(
        steps=40, ckpt_dir=ckdir, ckpt_every=10, log_every=10))
    print(f"resumed from step {out['resumed_from']}")

    ref_loss = ref["history"][-1]["loss"]
    res_loss = out["history"][-1]["loss"]
    print(f"\nfinal loss — reference: {ref_loss:.6f}, resumed: {res_loss:.6f}")
    assert np.isclose(ref_loss, res_loss, rtol=0, atol=0), "NOT bitwise equal"
    print("bitwise-identical resume OK")
    shutil.rmtree(ckdir, ignore_errors=True)


if __name__ == "__main__":
    main()
