"""Traced serving: one run, three views of the same events.

Runs a mixed trace through the continuous-batching engine with the serve
tracer on, then shows what tracing buys you over the summary line:

  1. a per-request SPAN TIMELINE (queue -> ttft -> decode -> finish) in
     both clocks — the deterministic engine-step clock benchmarks gate on
     and monotonic wall milliseconds;
  2. a reconciliation against `ServeMetrics` — the tracer is a strictly
     richer view of the same events, so its step-clock numbers match the
     metrics records EXACTLY (asserted here and in tests/test_trace.py);
  3. a Chrome trace-event JSON — open results/traced/serve.chrome.json in
     chrome://tracing or https://ui.perfetto.dev to see one track per
     decode slot, the admission queue, the dispatch lane, and the batch
     occupancy counter.

  PYTHONPATH=src python examples/serve_traced.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.kratos import KratosSpec
from repro.serve import (EngineConfig, InferenceEngine, ModelRegistry,
                         TraceConfig)

ARCH = "h2o-danube-1.8b"
SPEC = KratosSpec(sparsity=0.5, bits=8, bk=8, bn=8)
OUT_JSONL = "results/traced/serve.trace.jsonl"
OUT_CHROME = "results/traced/serve.chrome.json"
# (prompt_len, gen_len, arrival_step) — ragged on purpose so the spans show
# queueing, staggered admission, and slots turning over mid-run
TRACE = [(20, 16, 0), (8, 24, 0), (14, 10, 2), (24, 12, 4), (6, 20, 6),
         (16, 8, 9)]


def main() -> None:
    rng = np.random.default_rng(0)
    model = ModelRegistry().load(ARCH, SPEC)
    engine = InferenceEngine(model, EngineConfig(
        n_slots=4, max_len=64, decode_chunk=4,
        trace=TraceConfig(out=OUT_JSONL, chrome=OUT_CHROME)))

    reqs = [engine.submit(rng.integers(0, model.cfg.vocab, s0), gen,
                          arrival_step=at) for s0, gen, at in TRACE]
    engine.run()
    print(engine.metrics.format_report(), "\n")

    # -- 1. one request's span timeline, both clocks ------------------------
    rid = reqs[3].id                      # arrived step 4: it queued
    print(engine.trace.format_timeline(rid), "\n")

    # -- 2. spans reconcile exactly with ServeMetrics -----------------------
    spans = engine.trace.request_spans()
    for r in reqs:
        s, rec = spans[r.id], engine.metrics.records[r.id]
        assert s["ttft_steps"] == rec.first_token_step - rec.arrival_step
        assert s["latency_steps"] == rec.finish_step - rec.arrival_step
        assert s["tokens"] == rec.n_generated == len(r.generated)
    print(f"spans reconcile with ServeMetrics for all {len(reqs)} requests "
          "(ttft/latency steps + token counts identical)")

    # -- 3. exports ---------------------------------------------------------
    engine.trace.export()                 # writes TraceConfig.out + .chrome
    print(f"wrote {OUT_JSONL} ({len(engine.trace.events)} events, "
          f"{engine.trace.dropped} dropped)")
    print(f"wrote {OUT_CHROME} — open in chrome://tracing or "
          "https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
