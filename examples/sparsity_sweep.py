"""The paper's motivating tradeoff, end-to-end: accuracy vs efficiency
across sparsity x precision on a real (small) training task.

Trains the same model at {dense, 50%, 90%} sparsity x {bf16, 8, 4}-bit
weights on the learnable markov task, and prints final loss next to the
compute/byte cost of each point — the accuracy-efficiency frontier the
paper's §V trends feed into (its ref [53]: 'pruning vs quantization').

  PYTHONPATH=src python examples/sparsity_sweep.py [--steps 120]
"""

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro import configs as C
from repro.core import kratos as kr
from repro.data.pipeline import DataConfig
from repro.optim import adamw as O
from repro.train import TrainLoopConfig, run_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    a = ap.parse_args()

    base = C.get_smoke("h2o-danube-1.8b")
    grid = [(0.0, None), (0.5, None), (0.9, None),
            (0.0, 8), (0.0, 4), (0.5, 8), (0.9, 4)]
    print(f"{'sparsity':>8} {'bits':>5} {'final_loss':>10} "
          f"{'mac_frac':>9} {'byte_frac':>9}")
    for s, bits in grid:
        spec = kr.KratosSpec(sparsity=s, bits=bits, bk=8, bn=8)
        cfg = dataclasses.replace(base, kratos=spec)
        out = run_training(
            cfg, O.OptimizerConfig(lr=2e-3, warmup_steps=10,
                                   total_steps=a.steps),
            DataConfig(vocab=cfg.vocab, batch=8, seq=32, seed=3),
            TrainLoopConfig(steps=a.steps, log_every=0))
        rep = kr.cost_report(cfg.d_model, cfg.d_ff, spec)
        print(f"{s:>8.1f} {bits or 16:>5} "
              f"{out['history'][-1]['loss']:>10.4f} "
              f"{rep['mac_fraction']:>9.2f} "
              f"{rep['weight_bytes_fraction']:>9.2f}")
    print("sparsity_sweep OK")


if __name__ == "__main__":
    main()
