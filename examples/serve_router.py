"""Replica-routed serving: one front-end over N engine replicas.

Builds two continuous-batching engine replicas with BOUNDED waiting deques
(EngineConfig.max_waiting) and drives a bursty trace through
`serve.ReplicaRouter`: least-loaded admission spreads arrivals, a replica
whose deque fills REJECTS (counted, raising EngineSaturated) and the router
spills the request to its sibling or parks it in the overflow deque, and
the per-step rebalancer moves tail-of-queue requests off a backed-up
replica. Aggregate metrics pool both replicas (fleet-level p99, not a mean
of per-replica p99s).

On a multi-device host the same script scales out: give each replica a
disjoint data-submesh via
    XLA_FLAGS=--xla_force_host_platform_device_count=8
and ShardedBackend(mesh=launch.mesh.replica_meshes(4, 2, 2)[i]) — greedy
outputs are identical to the local backend, so the router's routing
decisions are placement-independent.

  PYTHONPATH=src python examples/serve_router.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core.kratos import KratosSpec
from repro.serve import EngineConfig, ModelRegistry, ReplicaRouter

SPEC = KratosSpec(sparsity=0.5, bits=8, bk=8, bn=8)
N_REPLICAS, N_SLOTS, MAX_WAITING = 2, 2, 2
# (prompt_len, gen_len, arrival_step) — a burst at t=0 that MUST spill
# (> one replica's slots + deque), then a trickle.
TRACE = [(8, 12, 0), (6, 10, 0), (10, 8, 0), (7, 14, 0), (9, 6, 0),
         (5, 12, 0), (8, 10, 4), (6, 8, 8)]


def main() -> None:
    rng = np.random.default_rng(0)
    model = ModelRegistry().load("h2o-danube-1.8b", SPEC)
    router = ReplicaRouter.build(
        model,
        EngineConfig(n_slots=N_SLOTS, max_len=48, decode_chunk=2,
                     max_waiting=MAX_WAITING),
        N_REPLICAS)
    reqs = [router.submit(rng.integers(0, model.cfg.vocab, s0), gen,
                          arrival_step=at) for s0, gen, at in TRACE]
    router.run()
    rep = router.report()
    print(f"router: {router.format_report()}")
    per_replica = [int(e.metrics.tokens_generated) for e in router.replicas]
    print(f"tokens per replica: {per_replica} "
          f"(imbalance {max(per_replica) / max(1, min(per_replica)):.2f}x)")
    assert all(len(r.generated) == g for r, (_, g, _) in zip(reqs, TRACE))
    assert rep["requests_completed"] == len(TRACE)
    print("serve_router OK")


if __name__ == "__main__":
    main()
