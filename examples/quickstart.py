"""Quickstart: train a small LM with the Kratos technique attached.

Runs in ~1 minute on CPU. Shows the three things this framework is about:
  1. a model config with a KratosSpec (50% block-sparse + 8-bit weights)
     on every projection,
  2. a real training loop on a learnable synthetic task (loss drops),
  3. the per-projection cost report — compute/bytes vs the dense model
     (the paper's 'area' saving, restated for TPU time).

  PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses
import sys

sys.path.insert(0, "src")

from repro import configs as C
from repro.core import kratos as kr
from repro.data.pipeline import DataConfig
from repro.optim import adamw as O
from repro.train import TrainLoopConfig, run_training


def main() -> None:
    spec = kr.KratosSpec(sparsity=0.5, bits=8, bk=8, bn=8)
    cfg = dataclasses.replace(C.get_smoke("h2o-danube-1.8b"), kratos=spec)

    rep = kr.cost_report(cfg.d_model, cfg.d_ff, spec)
    print(f"kratos spec: {spec}")
    print(f"per-projection vs dense: {rep['mac_fraction']:.2f}x MACs, "
          f"{rep['weight_bytes_fraction']:.2f}x weight bytes\n")

    out = run_training(
        cfg,
        O.OptimizerConfig(lr=2e-3, warmup_steps=20, total_steps=150),
        DataConfig(vocab=cfg.vocab, batch=8, seq=32, source="markov"),
        TrainLoopConfig(steps=150, log_every=25),
    )
    losses = [h["loss"] for h in out["history"]]
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(irreducible ~ noise entropy; uniform would be ln V = "
          f"{__import__('math').log(cfg.vocab):.2f})")
    assert losses[-1] < losses[0] - 1.0, "training did not learn!"
    print("quickstart OK")


if __name__ == "__main__":
    main()
