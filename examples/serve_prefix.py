"""Prefix-cached paged serving end-to-end: a chat fleet sharing one system
prompt.

The canonical shape prefix reuse exists for: every request carries the SAME
long system prompt followed by a short unique user turn. The demo serves
the trace twice through the same packed model — once with the slab pool
(every admission prefills the whole prompt), once with the paged pool +
radix prefix index (`EngineConfig.page_size`): the first admission prefills
and PUBLISHES the system prompt's pages, every later admission matches
them, bumps their refcounts, and prefills only its user suffix. The demo
prints, per run: admitted tokens per second, the prefix hit rate, how many
prompt tokens were never prefilled (and the FLOPs that saved), the
page-pool occupancy, and each request's matched length. It then verifies
greedy token-identity: sharing must not change one token.

  PYTHONPATH=src python examples/serve_prefix.py
"""

import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.serve import EngineConfig, InferenceEngine, ModelRegistry

ARCH = "nemotron-4-340b"           # full-attention transformer smoke config
N_SLOTS, PAGE = 4, 8
SYS_LEN, N_TURNS = 96, 8           # one system prompt, 8 user questions
MAX_LEN = SYS_LEN + 16 + 16


def build_trace(vocab: int):
    rng = np.random.default_rng(0)
    system = rng.integers(0, vocab, SYS_LEN)
    trace = []
    for i in range(N_TURNS):
        user = rng.integers(0, vocab, int(rng.integers(4, 12)))
        trace.append((np.concatenate([system, user]), 12, i))
    return trace


def run(model, trace, **kw):
    engine = InferenceEngine(
        model, EngineConfig(n_slots=N_SLOTS, max_len=MAX_LEN,
                            decode_chunk=2, **kw))
    # first replay warms: jit compiles (one per suffix length on the paged
    # side) AND the radix tree; the timed replay is the steady state a
    # long-running chat fleet lives in
    reqs = [engine.submit(p, g, arrival_step=a) for p, g, a in trace]
    engine.run()
    t0 = time.time()
    off = engine.step_count + 1
    reqs2 = [engine.submit(p, g, arrival_step=a + off) for p, g, a in trace]
    engine.run()
    dt = max(time.time() - t0, 1e-9)
    admitted = sum(len(p) + g for p, g, _ in trace)
    return [r.generated for r in reqs2], engine, admitted / dt, reqs2


def main() -> None:
    registry = ModelRegistry()
    model = registry.load(ARCH)
    trace = build_trace(model.cfg.vocab)
    print(f"[prefix] {model.name}: {N_TURNS} chat turns sharing a "
          f"{SYS_LEN}-token system prompt (+4-11 token user suffixes)")

    slab, slab_eng, slab_tps, _ = run(model, trace)
    paged, paged_eng, paged_tps, reqs = run(model, trace, page_size=PAGE)

    rep = paged_eng.metrics.report()
    flops_saved = 2.0 * model.cfg.active_param_count() \
        * rep["prefill_tokens_skipped"]
    print(f"[prefix] slab : {slab_tps:8.1f} admitted tok/s "
          f"(every prompt fully prefilled)")
    print(f"[prefix] paged: {paged_tps:8.1f} admitted tok/s | hit rate "
          f"{rep['prefix_hit_rate']:.2f} | {int(rep['prefill_tokens_skipped'])}"
          f" prompt toks never prefilled ({rep['prefill_skip_fraction']:.0%}"
          f" of all prompt tokens, ~{flops_saved / 1e9:.2f} GFLOPs) | pages "
          f"{rep['pages_in_use']:.1f}/{paged_eng.pool.n_usable_pages} "
          f"({rep['page_occupancy']:.2f} full)")
    print("[prefix] per-request matched prefix:")
    for r in reqs:
        print(f"    req{r.id}: matched {r.prefix_matched:3d} of "
              f"{len(r.prompt)} prompt tokens"
              + ("  <- first admission publishes the prefix"
                 if r.prefix_matched == 0 else ""))

    assert slab == paged, "prefix sharing changed greedy output!"
    print(f"[prefix] greedy outputs token-identical; "
          f"{paged_tps / slab_tps:.2f}x admitted throughput "
          f"({paged_eng.pool.describe()['n_pages']} pages x {PAGE} positions"
          f" vs {N_SLOTS} x {MAX_LEN}-position slab rows)")


if __name__ == "__main__":
    main()
