"""Speculative decode end-to-end: plain vs self-draft serving, one config.

The same trace is served twice through the same packed target — once with
the plain device-resident loop, once speculatively with a SELF-DRAFT (the
target's weights re-packed at 8-bit through core/quantize, derived by
`registry.load(..., draft_spec=...)`). The demo prints, per run: tokens per
decode dispatch (the host-sync economy speculation buys), the fleet
acceptance rate and rollback count, the draft/verify FLOP ratio, and the
PER-SLOT acceptance rates — the tuning signal for picking a draft point
(more sparsity / fewer layers = cheaper draft, lower acceptance). It then
verifies greedy token-identity: speculation must not change one token.

  PYTHONPATH=src python examples/serve_speculative.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.serve import (DraftSpec, EngineConfig, InferenceEngine,
                         ModelRegistry)

ARCH = "nemotron-4-340b"           # full-attention transformer smoke config
DRAFT = DraftSpec(bits=8)          # highest-fidelity self-draft
K = 4                              # draft tokens per propose-verify dispatch
N_SLOTS, MAX_LEN = 4, 64
# (prompt_len, gen_len, arrival_step) — deliberately ragged
TRACE = [(12, 16, 0), (6, 20, 0), (9, 12, 2), (15, 18, 4), (5, 14, 7)]


def run(model, speculate: int):
    rng = np.random.default_rng(0)
    engine = InferenceEngine(
        model, EngineConfig(n_slots=N_SLOTS, max_len=MAX_LEN,
                            speculate=speculate))
    reqs = [engine.submit(rng.integers(0, model.cfg.vocab, p), g,
                          arrival_step=a) for p, g, a in TRACE]
    engine.run()
    return [r.generated for r in reqs], engine


def main() -> None:
    registry = ModelRegistry()
    model = registry.load(ARCH, draft_spec=DRAFT)
    print(f"[spec] {model.name}: draft packs {model.draft_packed} "
          f"projections at {DRAFT.tag}, draft/verify flops "
          f"{model.draft_cost_fraction():.2f}")

    plain, plain_eng = run(model, speculate=0)
    spec, spec_eng = run(model, speculate=K)

    for label, eng in (("plain", plain_eng), (f"spec K={K}", spec_eng)):
        rep = eng.metrics.report()
        print(f"[spec] {label:9s} {int(rep['tokens_generated'])} toks over "
              f"{int(rep['decode_steps'])} dispatches = "
              f"{rep['tokens_per_dispatch']:.2f} tok/dispatch"
              + (f" | accept {rep['acceptance_rate']:.3f} "
                 f"({int(rep['draft_rolled_back'])} rolled back)"
                 if eng.metrics.spec_dispatches else ""))

    print("[spec] per-slot acceptance:")
    for slot in sorted(spec_eng.metrics.slot_acceptance):
        acc, prop = spec_eng.metrics.slot_acceptance[slot]
        print(f"    slot {slot}: {acc}/{prop} = {acc / max(1, prop):.3f}")

    assert plain == spec, "speculation changed greedy output!"
    ratio = (spec_eng.metrics.report()["tokens_per_dispatch"]
             / plain_eng.metrics.report()["tokens_per_dispatch"])
    print(f"[spec] greedy outputs token-identical; {ratio:.2f}x tokens per "
          "dispatch vs the plain loop")


if __name__ == "__main__":
    main()
