"""Batched serving: prefill + streaming decode across mixed architectures.

Serves three very different backbones (SWA dense, MLA+MoE, pure SSM) through
the SAME prefill/decode API the dry-run lowers for the production mesh, and
prints per-arch cache sizes — the reason long_500k is feasible for SSM/SWA
archs (O(1) / O(window) state) and skipped for full-attention ones.

  PYTHONPATH=src python examples/serve_batched.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.distributed import steps as ST
from repro.models import transformer as T

ARCHS = ("h2o-danube-1.8b", "deepseek-v2-lite-16b", "falcon-mamba-7b")
B, S0, GEN = 4, 24, 24


def cache_bytes(caches) -> int:
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(caches))


def main() -> None:
    rng = np.random.default_rng(0)
    for arch in ARCHS:
        cfg = C.get_smoke(arch)
        params = T.init(jax.random.PRNGKey(1), cfg)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, S0)), jnp.int32)
        caches = T.make_caches(cfg, B, S0 + GEN)
        prefill = jax.jit(ST.make_prefill_step(cfg))
        decode = jax.jit(ST.make_decode_step(cfg))

        t0 = time.time()
        logits, caches = prefill(params, {"tokens": prompts}, caches)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        gen = [tok]
        for t in range(GEN - 1):
            logits, caches = decode(params, caches, tok, jnp.int32(S0 + t))
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            gen.append(tok)
        dt = time.time() - t0
        out = np.asarray(jnp.concatenate(gen, 1))
        kind = ("SSM: O(1) state" if cfg.is_ssm else
                f"SWA: O(window={cfg.window})" if cfg.window else
                f"MLA: O(S x {cfg.kv_lora_rank + cfg.qk_rope_dim})"
                if cfg.mla else "full: O(S x 2·H·dh)")
        print(f"{arch:24s} {B}x{GEN} tokens in {dt:5.1f}s | "
              f"cache {cache_bytes(caches)/1e6:6.2f} MB ({kind}) | "
              f"sample: {out[0][:8]}")
    print("serve_batched OK")


if __name__ == "__main__":
    main()
