"""Continuous-batched serving across mixed architectures.

Serves three very different backbones (SWA dense, MLA+MoE, pure SSM) through
the SAME continuous-batching engine: requests with staggered arrivals and
unequal prompt/generation lengths join and leave the decode slab
mid-flight, weights are packed once at load (`kratos.pack` via the serve
registry), and per-arch cache-slab sizes are printed — the reason long_500k
is feasible for SSM/SWA archs (O(1) / O(window) state per slot) and skipped
for full-attention ones.

  PYTHONPATH=src python examples/serve_batched.py
"""

import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core.kratos import KratosSpec
from repro.serve import EngineConfig, InferenceEngine, ModelRegistry

ARCHS = ("h2o-danube-1.8b", "deepseek-v2-lite-16b", "falcon-mamba-7b")
SPEC = KratosSpec(sparsity=0.5, bits=8, bk=8, bn=8)   # the paper's headline
N_SLOTS, MAX_LEN = 4, 64
DECODE_CHUNK = 4   # K micro-steps per device-resident dispatch (1 sync per K)
# (prompt_len, gen_len, arrival_step) — deliberately ragged
TRACE = [(20, 16, 0), (8, 24, 0), (14, 10, 2), (24, 12, 4), (6, 20, 6),
         (16, 8, 9)]


def main() -> None:
    rng = np.random.default_rng(0)
    registry = ModelRegistry()
    for arch in ARCHS:
        model = registry.load(arch, SPEC)
        engine = InferenceEngine(
            model, EngineConfig(n_slots=N_SLOTS, max_len=MAX_LEN,
                                decode_chunk=DECODE_CHUNK))
        cfg = model.cfg
        t0 = time.time()
        reqs = [engine.submit(rng.integers(0, cfg.vocab, s0), gen,
                              arrival_step=at) for s0, gen, at in TRACE]
        engine.run()
        dt = time.time() - t0
        kind = ("SSM: O(1) state" if cfg.is_ssm else
                f"SWA: O(window={cfg.window})" if cfg.window else
                f"MLA: O(S x {cfg.kv_lora_rank + cfg.qk_rope_dim})"
                if cfg.mla else "full: O(S x 2·H·dh)")
        rep = engine.metrics.report()
        print(f"{arch:24s} {int(rep['tokens_generated'])} toks in {dt:5.1f}s"
              f" | {rep['tokens_per_step']:.2f} tok/step,"
              f" {rep['host_syncs_per_token']:.2f} syncs/tok,"
              f" occupancy {rep['mean_occupancy']:.2f}"
              f" | slab {engine.pool.bytes() / 1e6:6.2f} MB"
              f"/{N_SLOTS} slots ({kind})"
              f" | packed {model.compression:.1f}x"
              f" | sample: {np.asarray(reqs[0].generated)[:6]}")
    print("serve_batched OK")


if __name__ == "__main__":
    main()
