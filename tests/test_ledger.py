"""serve.ledger: device-resident ineffectual-work counters.

The contract under test, per the module docstring: zero-cost when
disabled (NULL_LEDGER allocates nothing), zero EXTRA host syncs when
enabled (the counter matrix drains inside the dispatch's existing token
device_get), step-clock deterministic (bit-identical counters and
histograms across identical runs), greedy-token-neutral (probes observe,
never perturb), and per-tier quality gauges that match an offline
recompute of the recorded probe log exactly.
"""

import gc
import sys

import numpy as np
import pytest

from repro.serve import (EngineConfig, InferenceEngine, LedgerConfig,
                         ModelRegistry, NULL_LEDGER, hist_checksum)
from repro.serve.ledger import (C_DEAD_KB, C_ELEMS, C_HIST, C_KBLOCKS,
                                C_NEAR, C_ZEROS, LedgerProbe, LedgerSink)

_REGISTRY = ModelRegistry()


def _trace(model, n=3, prompt=8, gen=6, seed=0):
    rng = np.random.default_rng(seed)
    return [(i, rng.integers(0, model.cfg.vocab, prompt), gen)
            for i in range(n)]


def _run(model, trace, *, ledger=None, temperature=0.0, decode_chunk=2,
         **cfg_kw):
    eng = InferenceEngine(model, EngineConfig(
        n_slots=2, max_len=48, decode_chunk=decode_chunk, ledger=ledger,
        **cfg_kw))
    reqs = [eng.submit(p, g, arrival_step=a, temperature=temperature)
            for a, p, g in trace]
    eng.run()
    return eng, reqs


# ---------------------------------------------------------------------------
# disabled: zero cost
# ---------------------------------------------------------------------------

def test_null_ledger_zero_alloc():
    """The disabled sink's hot-path calls (one per dispatch) allocate
    NOTHING — same contract and measurement idiom as NULL_TRACER."""
    led = NULL_LEDGER

    def hot_path():
        led.on_drain(None, 7)
        led.rebase()

    deltas = []
    for _ in range(3):
        hot_path()
        gc.collect()
        before = sys.getallocatedblocks()
        hot_path()
        deltas.append(sys.getallocatedblocks() - before)
    assert deltas[-1] == 0, f"disabled ledger allocated: deltas={deltas}"
    assert not led.enabled
    assert led.summary() == {}


def test_ledger_requires_device_loop():
    model = _REGISTRY.load("h2o-danube-1.8b")
    with pytest.raises(ValueError):
        InferenceEngine(model, EngineConfig(
            n_slots=2, max_len=48, device_loop=False,
            ledger=LedgerConfig()))


# ---------------------------------------------------------------------------
# probe math vs a numpy recompute
# ---------------------------------------------------------------------------

def test_probe_measure_matches_numpy():
    cfg = LedgerConfig(threshold=0.25, group=4, k_block=4)
    probe = LedgerProbe(cfg)
    rng = np.random.default_rng(1)
    x = rng.normal(size=(3, 16)).astype(np.float32)
    x[x < 0.4] = 0.0                       # plant plenty of exact zeros
    row = np.asarray(probe.measure(x, 8))

    near = np.abs(x) <= cfg.threshold
    assert row[C_ELEMS] == x.size
    assert row[C_ZEROS] == (x == 0.0).sum()
    assert row[C_NEAR] == near.sum()
    grouped = near.reshape(3, 4, 4).sum(axis=-1)
    hist = np.bincount(grouped.ravel(), minlength=cfg.group + 1)
    assert np.array_equal(row[C_HIST:], hist.astype(np.float32))
    dead = near.reshape(3, 4, 4).all(axis=-1)
    assert row[C_KBLOCKS] == dead.size
    assert row[C_DEAD_KB] == dead.sum()


def test_hist_checksum_orders():
    """The checksum must distinguish permuted histograms (it is the ONE
    scalar the qor gate uses for whole-matrix bit-determinism)."""
    a = np.zeros((2, C_HIST + 5))
    b = np.zeros((2, C_HIST + 5))
    a[0, C_HIST] = 3.0
    b[0, C_HIST + 2] = 3.0
    assert hist_checksum(a, 4) != hist_checksum(b, 4)


# ---------------------------------------------------------------------------
# engine integration: sync economy, determinism, neutrality
# ---------------------------------------------------------------------------

def test_ledger_no_extra_host_syncs():
    """host_syncs_decode must equal the dispatch count exactly — the
    ledger rides the existing token device_get — and must equal the
    disabled engine's count on the same trace."""
    model = _REGISTRY.load("nemotron-4-340b")
    trace = _trace(model)
    eng_off, _ = _run(model, trace)
    eng_on, _ = _run(model, trace, ledger=LedgerConfig())
    on, off = eng_on.metrics.report(), eng_off.metrics.report()
    assert on["host_syncs_decode"] == on["decode_steps"]
    assert on["host_syncs_decode"] == off["host_syncs_decode"]
    assert on["ledger_dispatches"] == on["decode_steps"]
    assert on["host_syncs_quality"] == 0      # quality_every=0


def test_ledger_greedy_tokens_unchanged():
    """Probes observe; they must not perturb the decoded stream."""
    model = _REGISTRY.load("nemotron-4-340b")
    trace = _trace(model)
    _, reqs_off = _run(model, trace)
    _, reqs_on = _run(model, trace, ledger=LedgerConfig())
    for r_off, r_on in zip(reqs_off, reqs_on):
        assert r_off.generated == r_on.generated


def test_ledger_step_clock_deterministic():
    """Two identical runs: every counter, per-layer fraction, and the
    full per-layer histogram matrix bit-identical."""
    model = _REGISTRY.load("nemotron-4-340b")
    trace = _trace(model)
    led = LedgerConfig(group=8, k_block=8)
    eng1, _ = _run(model, trace, ledger=led)
    eng2, _ = _run(model, trace, ledger=led)
    s1, s2 = eng1.ledger.summary(), eng2.ledger.summary()
    assert s1 == s2
    assert s1["act_zeros"] > 0               # squared-ReLU makes real zeros
    assert s1["act_hist_checksum"] == s2["act_hist_checksum"]


def test_ledger_measures_relu_zeros():
    model = _REGISTRY.load("nemotron-4-340b")
    eng, _ = _run(model, _trace(model), ledger=LedgerConfig(k_block=8))
    rep = eng.metrics.report()
    assert rep["act_zeros"] > 0
    assert 0.0 < rep["act_zero_fraction"] < 1.0
    assert rep["flops_effective"] <= rep["flops_dense"]
    assert rep["bytes_effective"] <= rep["bytes_dense"]
    s = eng.ledger.summary()
    # fixed traffic: probe totals reconcile between metrics and sink
    assert rep["act_probe_elems"] == s["act_probe_elems"]
    assert rep["act_zeros"] == s["act_zeros"]


def test_ledger_paged_matches_slab():
    """The paged dispatch carries the same ledger operand: greedy tokens
    must match the slab engine on the same trace, and the measured zero
    fractions must agree closely. Counters are NOT required to be
    bit-equal ACROSS layouts — paged gathers fuse differently, so
    borderline activations can differ by an ulp — but each layout must be
    bit-deterministic against itself (the qor gate always compares like
    with like)."""
    model = _REGISTRY.load("nemotron-4-340b")
    trace = _trace(model)
    led = LedgerConfig(k_block=8)
    eng_slab, reqs_slab = _run(model, trace, ledger=led)
    eng_paged, reqs_paged = _run(model, trace, ledger=led, page_size=8)
    for rs, rp in zip(reqs_slab, reqs_paged):
        assert rs.generated == rp.generated
    ss, sp = eng_slab.ledger.summary(), eng_paged.ledger.summary()
    assert sp["act_zeros"] > 0
    f_slab = ss["act_zeros"] / ss["act_probe_elems"]
    f_paged = sp["act_zeros"] / sp["act_probe_elems"]
    assert abs(f_slab - f_paged) < 0.01
    eng_paged2, _ = _run(model, trace, ledger=led, page_size=8)
    assert eng_paged2.ledger.summary() == sp


def test_ledger_speculative_counts_target_only():
    """Spec decode probes only the TARGET verify forwards (the draft is
    accounted analytically); the ledger must still drain once per
    dispatch and stay token-identical with the unledgered engine."""
    from repro.serve import DraftSpec
    model = _REGISTRY.load("nemotron-4-340b", draft_spec=DraftSpec(bits=8))
    trace = _trace(model)
    eng_off, reqs_off = _run(model, trace, speculate=2, decode_chunk=1)
    eng_on, reqs_on = _run(model, trace, speculate=2, decode_chunk=1,
                           ledger=LedgerConfig(k_block=8))
    for r_off, r_on in zip(reqs_off, reqs_on):
        assert r_off.generated == r_on.generated
    rep = eng_on.metrics.report()
    assert rep["ledger_dispatches"] == rep["spec_dispatches"]
    assert rep["act_zeros"] > 0
    assert rep["host_syncs_decode"] \
        == eng_off.metrics.report()["host_syncs_decode"]


# ---------------------------------------------------------------------------
# quality probes
# ---------------------------------------------------------------------------

def test_quality_gauges_match_offline_recompute():
    """The per-tier gauges must be EXACTLY recomputable from the probe
    log; on a single-tier engine the tier-0 shadow is the same compiled
    prefill, so agreement is exact (top1 rate 1.0, MAD 0.0)."""
    model = _REGISTRY.load("nemotron-4-340b")
    eng, _ = _run(model, _trace(model, n=4),
                  ledger=LedgerConfig(quality_every=2))
    rep = eng.metrics.report()
    assert rep["quality_probes"] == len(eng.quality_log) == 2
    assert rep["host_syncs_quality"] == 2 * rep["quality_probes"]
    # quality syncs are tracked separately: the decode invariant holds
    assert rep["host_syncs_decode"] == rep["decode_steps"]

    # offline recompute from the probe log
    by_tier = {}
    for e in eng.quality_log:
        t = by_tier.setdefault(e["tier"], [0, 0, 0.0])
        t[0] += 1
        t[1] += bool(e["top1"])
        t[2] += e["mad"]
    expect = {tier: {"probes": n, "top1_rate": hits / n, "logit_mad": m / n}
              for tier, (n, hits, m) in by_tier.items()}
    assert eng.metrics.quality_by_tier() == expect
    assert rep["quality_top1_rate"] == 1.0
    assert rep["quality_logit_mad"] == 0.0


def test_quality_probe_deterministic():
    model = _REGISTRY.load("nemotron-4-340b")
    led = LedgerConfig(quality_every=2)
    eng1, _ = _run(model, _trace(model, n=4), ledger=led)
    eng2, _ = _run(model, _trace(model, n=4), ledger=led)
    assert eng1.quality_log == eng2.quality_log


# ---------------------------------------------------------------------------
# sink accounting
# ---------------------------------------------------------------------------

def test_sink_delta_and_rebase():
    """on_drain computes per-dispatch deltas against the cumulative device
    matrix; rebase() re-zeroes the snapshot so totals keep growing."""
    cfg = LedgerConfig(group=2, k_block=2)
    sink = LedgerSink(cfg, n_layers=2)
    cum = np.zeros((2, cfg.width), np.float32)
    cum[0, C_ELEMS] = 10.0
    sink.on_drain(cum, step=1)
    cum2 = cum.copy()
    cum2[0, C_ELEMS] = 25.0
    sink.on_drain(cum2, step=2)
    assert sink.total[0, C_ELEMS] == 25.0
    sink.rebase()                    # device buffer was zeroed
    cum3 = np.zeros_like(cum)
    cum3[0, C_ELEMS] = 5.0
    sink.on_drain(cum3, step=3)
    assert sink.total[0, C_ELEMS] == 30.0
