"""ReplicaRouter edge cases (PR 4 satellite): empty fleet, all-saturated
overflow drain, single-replica no-op rebalance, steal_waiting/adopt
boundaries."""

import numpy as np
import pytest

from repro.serve import (EngineConfig, EngineSaturated, InferenceEngine,
                         ModelRegistry, ReplicaRouter)

ARCH = "h2o-danube-1.8b"
_REGISTRY = ModelRegistry()


def _model():
    return _REGISTRY.load(ARCH)


def _prompt(model, n=4, seed=0):
    return np.random.default_rng(seed).integers(0, model.cfg.vocab, n)


def test_empty_fleet_is_rejected():
    with pytest.raises(ValueError, match="at least one replica"):
        ReplicaRouter([])


def test_all_saturated_fleet_parks_then_drains_overflow():
    """Every replica's bounded deque full: the submit parks in the router's
    overflow deque (counted once, no extra spills on retry rounds) and
    drains into the first replica with queue headroom."""
    m = _model()
    router = ReplicaRouter.build(
        m, EngineConfig(n_slots=1, max_len=24, max_waiting=1), 2)
    # before any step runs, fleet admission capacity is the 2 bounded
    # deques (slots fill only at step time): submits 3..5 all park
    reqs = [router.submit(_prompt(m), 3) for _ in range(5)]
    assert router.overflowed == 3
    assert len(router._overflow) == 3
    spills_before = router.spills
    router.step()        # a still-saturated retry round must not re-spill
    assert router.spills == spills_before
    router.run()
    assert all(len(r.generated) == 3 for r in reqs)
    assert len(router._overflow) == 0
    rep = router.report()
    assert rep["requests_completed"] == 5.0
    assert rep["overflowed"] == 3.0


def test_all_saturated_without_hold_raises():
    m = _model()
    router = ReplicaRouter.build(
        m, EngineConfig(n_slots=1, max_len=24, max_waiting=0), 1,
        hold_overflow=False)
    with pytest.raises(EngineSaturated):
        router.submit(_prompt(m), 3)
    assert router.requests == []         # the failed submit is not tracked


def test_single_replica_rebalance_is_a_noop():
    """One replica with a backed-up queue: the rebalancer has no sibling to
    donate to and must leave the queue intact (no self-moves, no counter
    drift, no request loss)."""
    m = _model()
    router = ReplicaRouter.build(m, EngineConfig(n_slots=1, max_len=24), 1)
    reqs = [router.submit(_prompt(m), 3) for _ in range(4)]
    assert router.replicas[0].n_waiting > router.replicas[0].pool.n_free
    router._rebalance()
    assert router.rebalanced == 0
    assert router.replicas[0].n_waiting + router.replicas[0].pool.n_active \
        == 4
    router.run()
    assert all(len(r.generated) == 3 for r in reqs)


def test_steal_waiting_edge_cases():
    m = _model()
    eng = InferenceEngine(m, EngineConfig(n_slots=1, max_len=24))
    assert eng.steal_waiting(3) == []            # nothing queued: empty, not error
    reqs = [eng.submit(_prompt(m), 2, arrival_step=9) for _ in range(3)]
    # ask for more than exists: returns what's there, arrival order kept
    stolen = eng.steal_waiting(99)
    assert stolen == reqs
    assert eng.n_waiting == 0 and eng.requests == {}
    assert all(r.id == -1 for r in stolen)       # de-registered handles
    assert eng.steal_waiting(1) == []            # drained deque


def test_adopt_rehomes_stolen_requests_and_validates():
    """adopt() re-registers a stolen Request under a fresh id on the new
    engine (the caller's handle object survives) and still enforces the
    admission bounds."""
    m = _model()
    src = InferenceEngine(m, EngineConfig(n_slots=1, max_len=24))
    dst = InferenceEngine(m, EngineConfig(n_slots=1, max_len=24))
    r = src.submit(_prompt(m), 2, arrival_step=0)
    [stolen] = src.steal_waiting(1)
    assert stolen is r
    adopted = dst.adopt(stolen)
    assert adopted is r and r.id >= 0
    assert dst.requests[r.id] is r
    dst.run()
    assert len(r.generated) == 2
    # adopt still validates: an oversized request is refused on a
    # length-bounded arch (full attention; SWA caches are circular and
    # serve past the slab), a full bounded deque raises EngineSaturated
    full = _REGISTRY.load("nemotron-4-340b")
    big = InferenceEngine(full, EngineConfig(n_slots=1, max_len=8))
    with pytest.raises(ValueError):
        big.submit(_prompt(full, n=6), 8)
    tight = InferenceEngine(m, EngineConfig(n_slots=1, max_len=24,
                                            max_waiting=0))
    with pytest.raises(EngineSaturated):
        tight.submit(_prompt(m), 2)
    assert tight.metrics.rejected == 1


def test_fleet_rejection_reconciles():
    """One submit that every replica bounces: each replica counts its OWN
    bounce in `rejected` (a single submit can bounce off all N), and the
    router counts the fleet-level refusal exactly once in
    `rejected_fleet` — so per-replica and fleet totals reconcile instead
    of the refusal vanishing from the aggregate."""
    m = _model()
    n = 2
    router = ReplicaRouter.build(
        m, EngineConfig(n_slots=1, max_len=24, max_waiting=0), n,
        hold_overflow=False)
    refused = 3
    for _ in range(refused):
        with pytest.raises(EngineSaturated):
            router.submit(_prompt(m), 3)
    per_replica = [e.metrics.rejected for e in router.replicas]
    rep = router.report()
    assert router.rejected_fleet == refused
    assert rep["rejected_fleet"] == float(refused)
    # every replica bounced every refused submit
    assert per_replica == [refused] * n
    assert rep["rejected"] == float(sum(per_replica)) == float(refused * n)
    assert router.requests == []         # refused submits are not tracked
