"""Chaos harness (PR 7): deterministic fault injection + exact recovery.

The acceptance bar: under an injected replica crash mid-decode, every
non-finished request completes on a survivor or is shed with an explicit
terminal state (no hangs), pools drain to pristine, and greedy outputs are
token-identical to a fault-free run — failover evacuation folds generated
tokens into the prompt, so a survivor's re-prefill resumes each stream at
its exact position.
"""

import numpy as np
import pytest

from repro.serve import (ChaosHarness, EngineConfig, Fault, InferenceEngine,
                         ModelRegistry, ReplicaFault, ReplicaRouter,
                         seeded_schedule)

ARCH = "h2o-danube-1.8b"
_REGISTRY = ModelRegistry()


def _model():
    return _REGISTRY.load(ARCH)


def _jobs(m, n=4, gen=8):
    rng = np.random.default_rng(5)
    return [(rng.integers(0, m.cfg.vocab, 6), gen) for _ in range(n)]


def _assert_pristine(eng):
    """After a full drain every pool resource is back: all slots free, and
    on a paged pool every surviving page reference is tree retention —
    finished requests publish their conversation into the prefix tree
    (PR 8), so retained pages must exactly match the tree's node count,
    and clearing the tree must hand every page back to the free list."""
    assert eng.pool.n_active == 0
    assert eng.pool.n_free == eng.cfg.n_slots
    if hasattr(eng.pool, "_free_pages"):
        if getattr(eng.pool, "index", None) is not None:
            assert eng.pool.pages_in_use == eng.pool.index.n_nodes
            eng.pool.index.clear(eng.pool._release)
        assert int(np.asarray(eng.pool.refs)[1:].sum()) == 0
        assert len(eng.pool._free_pages) == eng.pool.n_usable_pages


def _run_fleet(m, jobs, faults, n_replicas=2, **router_kw):
    router = ReplicaRouter.build(
        m, EngineConfig(n_slots=2, max_len=48), n_replicas, **router_kw)
    reqs = [router.submit(p, g) for p, g in jobs]
    harness = ChaosHarness(router, faults)
    harness.run()
    return router, reqs, harness


# ---------------------------------------------------------------------------
# schedule / Fault plumbing
# ---------------------------------------------------------------------------

def test_fault_validates():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault(kind="meteor", step=1)
    with pytest.raises(ValueError, match="duration"):
        Fault(kind="crash", step=1, duration=0)


def test_seeded_schedule_is_deterministic():
    a = seeded_schedule(7, 60, 3)
    b = seeded_schedule(7, 60, 3)
    assert a == b
    assert all(f.kind in ("crash", "nan_logits", "pool_squeeze",
                          "slow_dispatch") for f in a)
    assert all(0 <= f.replica < 3 and f.step >= 2 for f in a)
    # restricting kinds restricts the storm
    only_slow = seeded_schedule(7, 60, 3, kinds=("slow_dispatch",))
    assert all(f.kind == "slow_dispatch" for f in only_slow)


# ---------------------------------------------------------------------------
# crash -> failover
# ---------------------------------------------------------------------------

def test_crash_fails_over_token_identically():
    """Replica 0 crashes mid-decode: the router marks it dead, evacuates
    its requests (running streams resume from their exact position on a
    survivor), and every request's greedy output matches the fault-free
    run token for token."""
    m = _model()
    jobs = _jobs(m)
    clean_router, clean_reqs, _ = _run_fleet(m, jobs, [])
    router, reqs, harness = _run_fleet(
        m, jobs, [Fault(kind="crash", step=3, replica=0)])
    assert [f.kind for f in harness.injected] == ["crash"]
    assert router.alive == [False, True]
    assert all(r.state == "done" for r in reqs)
    assert [tuple(r.generated) for r in reqs] == \
        [tuple(r.generated) for r in clean_reqs]
    rep = router.report()
    assert rep["replica_deaths"] == 1.0
    assert router.replica_deaths == 1
    # evacuated requests were re-admitted on the survivor and counted there
    assert rep["failovers"] >= 1.0
    assert router.replicas[1].metrics.failovers >= 1
    _assert_pristine(router.replicas[1])


def test_crash_with_auto_restart_rebuilds_the_replica():
    m = _model()
    jobs = _jobs(m, n=6)
    router, reqs, _ = _run_fleet(
        m, jobs, [Fault(kind="crash", step=3, replica=0)],
        auto_restart=True)
    assert router.alive == [True, True]          # replaced, back in rotation
    assert router.restarts == 1 and router.replica_deaths == 1
    assert all(r.state == "done" and len(r.generated) == 8 for r in reqs)
    rep = router.report()
    assert rep["restarts"] == 1.0
    # the dead replica's metrics retired into the aggregate: the fleet
    # still accounts for every completion
    assert rep["requests_completed"] == float(len(reqs))
    assert rep["n_replicas"] == 2.0
    for eng in router.replicas:
        _assert_pristine(eng)


def test_all_dead_raises_instead_of_hanging():
    m = _model()
    with pytest.raises(RuntimeError, match="every replica is dead"):
        _run_fleet(m, _jobs(m), [Fault(kind="crash", step=2, replica=0)],
                   n_replicas=1)


# ---------------------------------------------------------------------------
# nan_logits -> sync validation refuses corrupt tokens
# ---------------------------------------------------------------------------

def test_nan_logits_is_caught_at_the_sync_boundary():
    """One poisoned sync (out-of-vocab tokens, what argmax-over-NaN
    degenerates to): the engine's decode validation must raise
    ReplicaFault BEFORE emitting any corrupt token, and the router fails
    the replica over — outputs stay token-identical to the clean run."""
    m = _model()
    jobs = _jobs(m)
    clean_router, clean_reqs, _ = _run_fleet(m, jobs, [])
    router, reqs, harness = _run_fleet(
        m, jobs, [Fault(kind="nan_logits", step=3, replica=0)])
    assert router.alive == [False, True]
    assert router.replica_deaths == 1
    assert all(r.state == "done" for r in reqs)
    vocab = m.cfg.vocab
    assert all(0 <= t < vocab for r in reqs for t in r.generated)
    assert [tuple(r.generated) for r in reqs] == \
        [tuple(r.generated) for r in clean_reqs]


def test_engine_rejects_out_of_vocab_sync_directly():
    """Unit form of the validation: poison the backend under a bare engine
    and assert the dispatch raises rather than emitting garbage."""
    m = _model()
    eng = InferenceEngine(m, EngineConfig(n_slots=2, max_len=48))
    r = eng.submit(_jobs(m, n=1)[0][0], 8)
    eng.step()                                    # prefill
    k, b = eng.cfg.decode_chunk, eng.cfg.n_slots
    eng.backend.decode_block = \
        lambda: np.full((k, b), -1, np.int32)
    with pytest.raises(ReplicaFault, match="decode sync outside"):
        eng.step()
    assert r.generated == [] or all(0 <= t < m.cfg.vocab
                                    for t in r.generated)


# ---------------------------------------------------------------------------
# pool_squeeze -> admission backpressure, then recovery
# ---------------------------------------------------------------------------

def test_pool_squeeze_delays_admission_then_recovers():
    """Confiscating free pages makes admission wait (resident requests
    keep decoding); at expiry the pages return and the queue drains —
    nothing shed, pool pristine. Full-attention arch: SWA caches are
    resident, only here does the pool budget real pages."""
    m = _REGISTRY.load("nemotron-4-340b")
    router = ReplicaRouter.build(
        m, EngineConfig(n_slots=2, max_len=32, page_size=8, n_pages=9), 1)
    reqs = [router.submit(np.arange(2, 8) * (i + 3) % 97, 8)
            for i in range(3)]
    harness = ChaosHarness(
        router, [Fault(kind="pool_squeeze", step=2, duration=4, pages=6)])
    harness.run()
    eng = router.replicas[0]
    assert all(r.state == "done" and len(r.generated) == 8 for r in reqs)
    assert eng.metrics.pool_waits >= 1           # the squeeze was felt
    assert eng.metrics.shed == 0
    _assert_pristine(eng)


def test_pool_squeeze_refuses_slab_pools():
    m = _model()
    router = ReplicaRouter.build(m, EngineConfig(n_slots=2, max_len=48), 1)
    router.submit(_jobs(m, n=1)[0][0], 4)
    harness = ChaosHarness(
        router, [Fault(kind="pool_squeeze", step=2, pages=2)])
    with pytest.raises(ValueError, match="paged pool"):
        harness.run()


# ---------------------------------------------------------------------------
# slow_dispatch -> wall degradation only
# ---------------------------------------------------------------------------

def test_slow_dispatch_degrades_wall_not_tokens():
    """A slowed dispatch window changes nothing on the step clock: same
    outputs, no deaths, and the wrapper is restored at expiry."""
    m = _model()
    jobs = _jobs(m)
    clean_router, clean_reqs, _ = _run_fleet(m, jobs, [])
    router, reqs, harness = _run_fleet(
        m, jobs, [Fault(kind="slow_dispatch", step=2, duration=2,
                        delay_s=0.002)])
    assert router.alive == [True, True]
    assert [tuple(r.generated) for r in reqs] == \
        [tuple(r.generated) for r in clean_reqs]
    assert harness._active == []                 # undo ran at expiry
    be = router.replicas[0].backend
    assert be.decode_block.__name__ != "slow"    # original method restored


# ---------------------------------------------------------------------------
# a seeded storm stays survivable
# ---------------------------------------------------------------------------

def test_seeded_storm_drains_with_auto_restart():
    """A reproducible multi-fault storm (crashes excluded from replica 1 by
    auto_restart safety net instead): every request reaches a terminal
    state and the fleet aggregate accounts for all of them."""
    m = _model()
    jobs = _jobs(m, n=6, gen=6)
    faults = [f for f in seeded_schedule(11, 30, 2, rate=0.2)
              if f.kind != "pool_squeeze"]       # slab replicas
    router, reqs, harness = _run_fleet(m, jobs, faults, auto_restart=True)
    assert all(r.state in ("done", "shed") for r in reqs)
    assert all(len(r.generated) == 6 for r in reqs if r.state == "done")
    rep = router.report()
    assert rep["requests_completed"] + rep["shed"] == float(len(reqs))
    for eng in router.replicas:
        _assert_pristine(eng)
