"""Model substrate: per-arch smoke tests + decode/prefill consistency +
MoE / SSM / attention oracles."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.core import kratos as kr
from repro.distributed import steps as ST
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import transformer as T
from repro.optim import adamw as O


def _batch_for(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    out = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.enc_dec:
        out["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.enc_positions, cfg.d_model)) * 0.1,
            jnp.float32)
    if cfg.n_img_tokens:
        out["img_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_img_tokens, cfg.d_model)) * 0.1,
            jnp.float32)
    return out


# ---------------------------------------------------------------------------
# Per-arch smoke: one train step, reduced config, finite loss + right shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = C.get_smoke(arch)
    state = ST.init_train_state(jax.random.PRNGKey(0), cfg, O.OptimizerConfig())
    batch = _batch_for(cfg)
    step = jax.jit(ST.make_train_step(cfg, O.OptimizerConfig()))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state["step"]) == 1
    # params changed and stayed finite
    leaves = jax.tree_util.tree_leaves(new_state["params"])
    assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in leaves)


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_arch_smoke_forward_shapes(arch):
    cfg = C.get_smoke(arch)
    params = T.init(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)
    enc_out = None
    if cfg.enc_dec:
        enc_out = T.encode(params, batch["frames"], cfg)
        assert enc_out.shape == (2, cfg.enc_positions, cfg.d_model)
    logits, aux, _ = T.forward(params, batch["tokens"], cfg,
                               img_embeds=batch.get("img_embeds"),
                               enc_out=enc_out)
    exp_s = 16 + cfg.n_img_tokens
    assert logits.shape == (2, exp_s, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


# ---------------------------------------------------------------------------
# decode == forward (teacher-forced): THE serving-correctness invariant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["h2o_danube_1_8b", "gemma2_27b",
                                  "minicpm3_4b", "falcon_mamba_7b",
                                  "jamba_v0_1_52b", "whisper_large_v3",
                                  "deepseek_v2_lite_16b"])
def test_decode_matches_forward(arch):
    """Prefill s0 tokens then decode the rest one-by-one; logits must match
    the full-sequence forward at every position.

    MoE archs run at no-drop capacity: capacity-based routing drops *depend
    on the routing-group token count by design*, so exact prefill/forward
    equivalence only holds when no token overflows an expert."""
    cfg = C.get_smoke(arch)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    b, s0, s1 = 2, 8, 4
    params = T.init(jax.random.PRNGKey(1), cfg)
    batch = _batch_for(cfg, b=b, s=s0 + s1, seed=3)
    toks = batch["tokens"]
    enc_out = None
    if cfg.enc_dec:
        enc_out = T.encode(params, batch["frames"], cfg)
    img = batch.get("img_embeds")

    full_logits, _, _ = T.forward(params, toks, cfg, enc_out=enc_out,
                                  img_embeds=img)

    n_img = cfg.n_img_tokens
    caches = T.make_caches(cfg, b, s0 + s1 + n_img)
    pre_logits, _, caches = T.forward(params, toks[:, :s0], cfg,
                                      caches=caches, enc_out=enc_out,
                                      img_embeds=img)
    np.testing.assert_allclose(
        np.asarray(pre_logits, np.float32),
        np.asarray(full_logits[:, :s0 + n_img], np.float32),
        rtol=2e-2, atol=2e-3)

    for t in range(s1):
        index = jnp.int32(n_img + s0 + t)
        step_logits, _, caches = T.forward(
            params, toks[:, s0 + t:s0 + t + 1], cfg, caches=caches,
            index=index, enc_out=enc_out)
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0], np.float32),
            np.asarray(full_logits[:, n_img + s0 + t], np.float32),
            rtol=2e-2, atol=2e-3,
            err_msg=f"decode step {t} diverged from forward")


def test_swa_decode_beyond_window():
    """Sliding-window decode with the circular cache: decoding past the
    window must equal full forward (which masks to the window anyway)."""
    cfg = C.get_smoke("h2o_danube_1_8b")
    assert cfg.window is not None
    w = cfg.window
    total = w + 6                       # decode well past the window
    b = 1
    params = T.init(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(7)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, total)), jnp.int32)
    full_logits, _, _ = T.forward(params, toks, cfg)

    caches = T.make_caches(cfg, b, w)   # cache is O(window), not O(total)!
    _, _, caches = T.forward(params, toks[:, :4], cfg, caches=caches)
    for t in range(4, total):
        step_logits, _, caches = T.forward(
            params, toks[:, t:t + 1], cfg, caches=caches, index=jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=2e-2, atol=2e-3, err_msg=f"pos {t}")


# ---------------------------------------------------------------------------
# MoE: grouped-capacity routing vs per-token dense oracle
# ---------------------------------------------------------------------------

def test_moe_matches_dense_oracle_high_capacity():
    cfg = M.MoEConfig(d_model=32, n_experts=8, top_k=2, d_ff_expert=64,
                      n_shared=1, capacity_factor=8.0)   # no drops
    params = M.moe_init(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, 32)) * 0.5
    y, aux = M.moe_apply(params, x, cfg)
    want = M.moe_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-3, atol=1e-4)
    assert float(aux) > 0.0


def test_moe_capacity_drops_are_partial_not_nan():
    cfg = M.MoEConfig(d_model=16, n_experts=4, top_k=2, d_ff_expert=32,
                      capacity_factor=0.25)              # heavy drops
    params = M.moe_init(jax.random.PRNGKey(5), cfg)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 32, 16))
    y, _ = M.moe_apply(params, x, cfg)
    assert np.isfinite(np.asarray(y)).all()


def test_moe_decode_single_token_group():
    cfg = M.MoEConfig(d_model=16, n_experts=4, top_k=2, d_ff_expert=32,
                      capacity_factor=8.0)
    params = M.moe_init(jax.random.PRNGKey(7), cfg)
    x = jax.random.normal(jax.random.PRNGKey(8), (8, 1, 16)) * 0.5
    y, _ = M.moe_apply(params, x, cfg)
    want = M.moe_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# SSM: chunked associative scan vs sequential recurrence; decode streaming
# ---------------------------------------------------------------------------

def test_ssm_chunked_scan_matches_sequential():
    b, s, di, st = 2, 37, 8, 4          # deliberately not a chunk multiple
    rng = np.random.default_rng(9)
    dA = jnp.asarray(rng.uniform(0.7, 1.0, (b, s, di, st)), jnp.float32)
    dBx = jnp.asarray(rng.standard_normal((b, s, di, st)) * 0.1, jnp.float32)
    cfg = S.MambaConfig(d_model=16, d_inner=di, d_state=st, chunk=8)
    got = S._scan_chunked(dA, dBx, cfg)
    want = S.mamba_scan_ref(dA, dBx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_mamba_decode_matches_full():
    cfg = S.MambaConfig(d_model=16, d_inner=32, d_state=4, d_conv=4, chunk=8)
    params = S.mamba_init(jax.random.PRNGKey(10), cfg)
    x = jax.random.normal(jax.random.PRNGKey(11), (2, 12, 16)) * 0.5
    y_full, _ = S.mamba_apply(params, x, cfg)

    cache = S.make_mamba_cache(cfg, 2)
    y_pre, cache = S.mamba_apply(params, x[:, :6], cfg, cache=cache)
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_full[:, :6]),
                               rtol=1e-3, atol=1e-4)
    for t in range(6, 12):
        y_t, cache = S.mamba_apply(params, x[:, t:t + 1], cfg, cache=cache,
                                   index=jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(y_t[:, 0]), np.asarray(y_full[:, t]),
            rtol=1e-3, atol=1e-4, err_msg=f"pos {t}")


# ---------------------------------------------------------------------------
# attention details
# ---------------------------------------------------------------------------

def test_chunked_attention_equals_dense():
    b, h, s, d = 1, 2, 32, 8
    q, k, v = (jax.random.normal(jax.random.PRNGKey(i), (b, h, s, d))
               for i in (12, 13, 14))
    pos = jnp.arange(s)
    dense = A.attention_positional(q, k, v, pos, pos, causal=True)
    chunked = A.attention_chunked(q, k, v, pos, pos, causal=True, chunk=8)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               rtol=1e-4, atol=1e-5)


def test_rope_preserves_relative_positions():
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    d = 16
    q = jax.random.normal(jax.random.PRNGKey(15), (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(16), (1, 1, 1, d))

    def score(i, j):
        qr = L.apply_rope(q, jnp.asarray([i]))
        kr_ = L.apply_rope(k, jnp.asarray([j]))
        return float(jnp.sum(qr * kr_))

    assert abs(score(5, 3) - score(105, 103)) < 1e-3
    assert abs(score(5, 3) - score(7, 3)) > 1e-4   # but not absolute-invariant


def test_gqa_head_grouping_matches_repeat():
    cfg = A.AttnConfig(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8)
    params = A.gqa_init(jax.random.PRNGKey(17), cfg)
    x = jax.random.normal(jax.random.PRNGKey(18), (2, 8, 32))
    y, _ = A.gqa_apply(params, x, cfg)
    assert y.shape == (2, 8, 32)
    assert np.isfinite(np.asarray(y)).all()


def test_mla_cache_is_compressed():
    """MLA decode cache stores (kv_lora + rope) per token, not 2*H*dh."""
    cfg = C.get_smoke("deepseek_v2_lite_16b")
    caches = T.make_caches(cfg, batch=1, max_len=64)
    sizes = [np.prod(l.shape) for l in jax.tree_util.tree_leaves(caches)]
    acfg = T.attn_cfg_for(cfg, T.layer_kind(cfg, 1))
    per_tok_full = 2 * cfg.n_heads * acfg.q_head_dim
    per_tok_mla = cfg.kv_lora_rank + cfg.qk_rope_dim
    assert per_tok_mla < per_tok_full / 3
    total = sum(sizes)
    assert total <= cfg.n_layers * 64 * per_tok_mla * 1 * 1.1


# ---------------------------------------------------------------------------
# Kratos attached to a whole model
# ---------------------------------------------------------------------------

def test_kratos_spec_through_full_model():
    spec = kr.KratosSpec(sparsity=0.5, bits=8, bk=8, bn=8)
    cfg = dataclasses.replace(C.get_smoke("h2o_danube_1_8b"), kratos=spec)
    state = ST.init_train_state(jax.random.PRNGKey(19), cfg,
                                O.OptimizerConfig())
    batch = _batch_for(cfg)
    step = jax.jit(ST.make_train_step(cfg, O.OptimizerConfig()))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # pruned blocks stayed zero after the update (masked-weight training)
    wq = state["params"]["blocks"][0]["mixer"]["wq"]["w"][0]
    plan = kr.plan_for(*wq.shape, spec)
    from repro.core import sparsity as sp
    mask = sp.plan_mask(plan)
    np.testing.assert_allclose(np.asarray(wq) * (1 - mask), 0.0, atol=1e-6)
