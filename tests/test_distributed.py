"""Sharding rules, local-mesh execution, HLO analyzer, dryrun plumbing."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import configs as C
from repro.analysis import hlo as HA
from repro.distributed import sharding as SH
from repro.distributed import steps as ST
from repro.launch import mesh as M
from repro.launch import shapes as SP
from repro.models import transformer as T
from repro.optim import adamw as O


# ---------------------------------------------------------------------------
# param/cache pspec rules
# ---------------------------------------------------------------------------

def _fake_mesh(shape=(16, 16), axes=("data", "model")):
    devs = np.asarray([jax.devices()[0]] * int(np.prod(shape))).reshape(shape)
    return Mesh(devs, axes)      # structural only — never dispatched to


def test_param_pspecs_cover_all_archs():
    mesh = _fake_mesh()
    for arch in C.ARCH_IDS:
        cfg = C.get_config(arch)
        shapes = SP.param_specs(cfg)
        specs = SH.param_pspecs(shapes, mesh)
        n_sharded = 0
        for sds, spec in zip(jax.tree_util.tree_leaves(shapes),
                             jax.tree_util.tree_leaves(
                                 specs, is_leaf=lambda x: isinstance(x, P))):
            assert len(spec) <= len(sds.shape), (arch, sds.shape, spec)
            # divisibility sanitization: every entry must divide the dim
            for i, entry in enumerate(spec):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                shards = int(np.prod([mesh.shape[a] for a in axes]))
                assert sds.shape[i] % shards == 0, (arch, sds.shape, spec)
                n_sharded += 1
        assert n_sharded > 0, f"{arch}: nothing sharded at all"


def test_big_projections_are_2d_sharded():
    """Every >=1M-param 2-D projection must shard over BOTH mesh axes
    (FSDP x TP) — scalars/norms may replicate, big weights must not."""
    mesh = _fake_mesh()
    cfg = C.get_config("nemotron-4-340b")
    shapes = SP.param_specs(cfg)
    specs = SH.param_pspecs(shapes, mesh)
    flat_s = jax.tree_util.tree_leaves_with_path(shapes)
    flat_p = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    for (path, sds), spec in zip(flat_s, flat_p):
        used = [a for e in spec if e is not None
                for a in (e if isinstance(e, tuple) else (e,))]
        if np.prod(sds.shape) >= 1 << 25:      # true projections: FSDP x TP
            assert "model" in used and "data" in used, (path, sds.shape, spec)
        elif np.prod(sds.shape) >= 1 << 20:    # stacked vectors: >= 1 axis
            assert used, (path, sds.shape, spec)


def test_cache_pspecs_decode_vs_long():
    mesh = _fake_mesh()
    cfg = SP.config_for_dryrun("nemotron_4_340b")
    caches = SP.cache_specs(cfg, 128, 32768, jnp.bfloat16)
    specs = SH.cache_pspecs(caches, mesh, 128)
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    # kv=8 doesn't divide model=16: cache must shard seq over model instead
    assert any("model" in str(s) for s in leaves)
    # batch=1 long-context: batch axis must NOT be sharded
    specs1 = SH.cache_pspecs(caches, mesh, 1)
    for s in jax.tree_util.tree_leaves(specs1,
                                       is_leaf=lambda x: isinstance(x, P)):
        assert s[0] is None or (len(s) and s[0] != "data"), s


def test_batch_pspec_divisibility():
    mesh = _fake_mesh()
    assert SH.batch_pspec(mesh, 256) == P(("data",))
    assert SH.batch_pspec(mesh, 16) == P("data")
    assert SH.batch_pspec(mesh, 1) == P(None)
    mesh3 = _fake_mesh((2, 16, 16), ("pod", "data", "model"))
    assert SH.batch_pspec(mesh3, 256) == P(("pod", "data"))


def test_activation_resolver_dedup_and_divisibility():
    mesh = _fake_mesh((4, 4), ("data", "model"))
    rules = SH.activation_rules(mesh)
    assert rules["batch"] == ("data",)
    resolver = SH._resolver_for(mesh)
    # duplicate 'model' request: second use must drop, not crash
    x = jnp.zeros((8, 8, 8, 8))
    # can't actually dispatch on a fake mesh; check the spec path via trace
    jaxpr = jax.make_jaxpr(
        lambda y: resolver(y, ("expert", None, None, "ffn")))(x)
    assert "sharding_constraint" in str(jaxpr)


# ---------------------------------------------------------------------------
# real execution on a local (1-device) mesh
# ---------------------------------------------------------------------------

def test_train_step_under_local_mesh():
    cfg = C.get_smoke("gemma2_27b")
    mesh = M.make_local_mesh(1, 1)
    with SH.use_mesh(mesh):
        state = ST.init_train_state(jax.random.PRNGKey(0), cfg,
                                    O.OptimizerConfig())
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                  jnp.int32)}
        step = jax.jit(ST.make_train_step(cfg, O.OptimizerConfig()))
        state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))


# ---------------------------------------------------------------------------
# HLO analyzer: hand-computable oracles
# ---------------------------------------------------------------------------

def test_hlo_analyzer_matmul_exact():
    a = jnp.zeros((256, 512), jnp.float32)
    b = jnp.zeros((512, 128), jnp.float32)
    c = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    r = HA.analyze(c.as_text())
    expect = 2 * 256 * 512 * 128
    assert abs(r["flops"] - expect) / expect < 0.05


def test_hlo_analyzer_scan_trip_count():
    def g(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, x, ws)[0]

    x = jnp.zeros((64, 128))
    ws = jnp.zeros((10, 128, 128))
    c = jax.jit(g).lower(x, ws).compile()
    r = HA.analyze(c.as_text())
    expect = 10 * (2 * 64 * 128 * 128 + 64 * 128)
    assert abs(r["flops"] - expect) / expect < 0.02
    assert not r["warnings"]


def test_hlo_analyzer_nested_scans_multiply():
    def g(x, ws):
        def outer(x, _):
            def inner(x, w):
                return x @ w, None
            x, _ = jax.lax.scan(inner, x, ws)
            return x, None
        return jax.lax.scan(outer, x, None, length=3)[0]

    x = jnp.zeros((32, 64))
    ws = jnp.zeros((5, 64, 64))
    c = jax.jit(g).lower(x, ws).compile()
    r = HA.analyze(c.as_text())
    expect = 3 * 5 * 2 * 32 * 64 * 64
    assert abs(r["flops"] - expect) / expect < 0.05


def test_hlo_analyzer_bytes_sane():
    a = jnp.zeros((1024, 1024), jnp.float32)
    c = jax.jit(lambda a: a + 1.0).lower(a).compile()
    r = HA.analyze(c.as_text())
    expect = 2 * 1024 * 1024 * 4         # read + write
    assert 0.5 * expect <= r["bytes"] <= 3 * expect


# ---------------------------------------------------------------------------
# shape-cell plumbing
# ---------------------------------------------------------------------------

def test_cell_grid_is_complete():
    cells = [(a, s.name) for a in C.ARCH_IDS for s in SP.SHAPES]
    assert len(cells) == 40
    runnable = [c for c in cells if SP.cell_applicable(*c)[0]]
    skipped = [c for c in cells if not SP.cell_applicable(*c)[0]]
    assert len(runnable) == 35 and len(skipped) == 5
    for arch, shape in skipped:
        assert shape == "long_500k"
        ok, reason = SP.cell_applicable(arch, shape)
        assert "full-attention" in reason


def test_input_specs_never_allocate():
    cfg = SP.config_for_dryrun("nemotron_4_340b")
    kind, args = SP.cell_inputs("nemotron_4_340b", SP.SHAPES_BY_NAME["train_4k"],
                                cfg=cfg)
    assert kind == "train"
    for leaf in jax.tree_util.tree_leaves(args):
        assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)
    # 340B params present as shapes only
    total = sum(int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(args[0]["params"]))
    assert total > 300e9
