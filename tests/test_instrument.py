"""instrument.EventRegistry: named event lists, aliasing, scoped reset."""

from repro.instrument import REGISTRY, EventList
from repro.kernels.pallas_compat import PAGED_ATTN_EVENTS, SKINNY_M_EVENTS
from repro.serve.paging import GATHER_EVENTS


def test_registry_returns_same_object():
    a = REGISTRY.event_list("test_stream_a")
    b = REGISTRY.event_list("test_stream_a")
    assert a is b
    assert isinstance(a, EventList) and isinstance(a, list)
    a.clear()


def test_legacy_names_are_registry_aliases():
    """The historical module globals must BE the registry's lists — tests
    that clear one must affect the other (same object, never rebound)."""
    assert SKINNY_M_EVENTS is REGISTRY.event_list("skinny_m")
    assert PAGED_ATTN_EVENTS is REGISTRY.event_list("paged_attn")
    assert GATHER_EVENTS is REGISTRY.event_list("gather")


def test_scoped_isolates_and_restores():
    lst = REGISTRY.event_list("test_stream_scoped")
    lst.clear()
    lst.append(("outer", 1))
    with REGISTRY.scoped("test_stream_scoped") as seen:
        inner = seen["test_stream_scoped"]
        assert inner is lst          # in-place: aliases stay live
        assert list(inner) == []     # prior events invisible inside
        inner.append(("inner", 2))
    assert list(lst) == [("outer", 1)]   # inner events did not leak out
    lst.clear()


def test_scoped_restores_on_exception():
    lst = REGISTRY.event_list("test_stream_exc")
    lst.clear()
    lst.append("keep")
    try:
        with REGISTRY.scoped("test_stream_exc"):
            lst.append("dropped")
            raise RuntimeError("boom")
    except RuntimeError:
        pass
    assert list(lst) == ["keep"]
    lst.clear()


def test_reset_and_snapshot():
    lst = REGISTRY.event_list("test_stream_snap")
    lst.clear()
    lst.extend([1, 2])
    snap = REGISTRY.snapshot()
    assert snap["test_stream_snap"] == (1, 2)
    REGISTRY.reset("test_stream_snap")
    assert list(lst) == []
