"""Control-plane unit tests (serve.control): wire serialization, the
FleetState judgements (credits, staleness, death), the socket transport,
and the delayed-snapshot no-flap regression.

Everything here is deterministic on a caller-advanced clock — no jax, no
model, no subprocesses; the fleet integration tests live in
tests/test_fleet.py."""

import numpy as np
import pytest

from repro.serve.control import (ControlListener, FleetConfig, FleetState,
                                 ProcessStatus, connect, decode_message,
                                 encode_message)


# ------------------------------------------------------------ serialization

def test_encode_decode_roundtrip_all_field_types():
    msg = {
        "kind": "submit",
        "rid": np.int64(7),
        "prompt": np.arange(5, dtype=np.int32),
        "temperature": np.float32(0.25),
        "eos_id": None,
        "flag": True,
        "name": "req-7",
        "nested": {"loads": [np.int32(1), 2], "occ": np.float64(0.5)},
        "tuple_field": (1, 2, 3),
    }
    out = decode_message(encode_message(msg))
    assert out["kind"] == "submit"
    assert out["rid"] == 7
    assert out["prompt"] == [0, 1, 2, 3, 4]
    assert out["temperature"] == pytest.approx(0.25)
    assert out["eos_id"] is None
    assert out["flag"] is True
    assert out["name"] == "req-7"
    assert out["nested"] == {"loads": [1, 2], "occ": 0.5}
    assert out["tuple_field"] == [1, 2, 3]
    # one message per line, newline-terminated
    assert encode_message(msg).endswith(b"\n")
    assert encode_message(msg).count(b"\n") == 1


def test_encode_requires_kind():
    with pytest.raises(ValueError):
        encode_message({"rid": 1})
    with pytest.raises(ValueError):
        decode_message(b"[1, 2, 3]")


def test_process_status_wire_roundtrip():
    st = ProcessStatus(process_index=3, seq=11, step=42,
                       replica_loads=[2, 5], n_free_slots=1, n_waiting=4,
                       page_occupancy=0.75, qos_tier=1, submits_seen=9,
                       progress={"12": [101, 102], "13": [7]})
    back = ProcessStatus.from_wire(
        decode_message(encode_message(st.to_wire())))
    assert back == st
    assert back.load == 7


def test_from_wire_ignores_unknown_fields():
    st = ProcessStatus(process_index=0, seq=1, step=0, replica_loads=[0],
                       n_free_slots=4, n_waiting=0, page_occupancy=0.0,
                       qos_tier=0, submits_seen=0)
    wire = st.to_wire()
    wire["future_field"] = "whatever"   # forward compatibility
    assert ProcessStatus.from_wire(wire) == st


# ------------------------------------------------------------------- config

def test_fleet_config_invariant():
    FleetConfig(staleness=4.0, heartbeat_timeout=10.0)   # fine
    with pytest.raises(ValueError):
        FleetConfig(staleness=11.0, heartbeat_timeout=10.0)
    with pytest.raises(ValueError):
        FleetConfig(staleness=0.0)


# -------------------------------------------------------------- fleet state

def _status(pi, seq, loads, submits_seen=0):
    return ProcessStatus(process_index=pi, seq=seq, step=seq,
                         replica_loads=list(loads), n_free_slots=0,
                         n_waiting=0, page_occupancy=0.0, qos_tier=0,
                         submits_seen=submits_seen)


def test_observe_seq_gating():
    fs = FleetState()
    assert fs.observe(_status(0, 2, [1]), now=1.0)
    assert not fs.observe(_status(0, 2, [9]), now=2.0)   # duplicate
    assert not fs.observe(_status(0, 1, [9]), now=3.0)   # reordered
    assert fs.status[0].load == 1
    assert fs.last_seen[0] == 1.0                        # ignored != seen


def test_credits_prevent_submit_herding():
    """All submits between two heartbeats must not land on one process:
    the submit credit raises its effective load immediately."""
    fs = FleetState()
    fs.observe(_status(0, 1, [0], submits_seen=0), now=0.0)
    fs.observe(_status(1, 1, [0], submits_seen=0), now=0.0)
    homes = []
    for _ in range(8):
        p = fs.least_loaded(now=1.0)
        fs.note_submit(p)
        homes.append(p)
    assert homes.count(0) == 4 and homes.count(1) == 4
    # and never more than one in a row on the same process
    assert all(a != b for a, b in zip(homes, homes[1:]))


def test_hello_only_process_admissible_at_credit_load():
    """A process that said hello but has not heartbeated yet is
    admissible with load == submits sent — the first status to arrive
    must not soak up the whole backlog."""
    fs = FleetState()
    fs.last_seen[0] = 0.0           # hello
    fs.last_seen[1] = 0.0
    fs.observe(_status(1, 1, [0]), now=0.0)   # only 1 has a snapshot
    homes = [0, 0]
    while not all(homes.count(p) for p in (0, 1)):
        p = fs.least_loaded(now=0.0)
        fs.note_submit(p)
        homes.append(p)
        assert len(homes) < 12
    assert fs.load(0) == fs.submits_sent[0]


def test_staleness_excludes_but_does_not_kill():
    cfg = FleetConfig(staleness=4.0, heartbeat_timeout=25.0)
    fs = FleetState(cfg)
    fs.observe(_status(0, 1, [0]), now=0.0)
    fs.observe(_status(1, 1, [5]), now=10.0)
    # process 0's snapshot is 10 old: excluded from admission, not dead
    assert fs.least_loaded(now=10.0) == 1
    assert not fs.check(now=10.0)
    assert 0 not in fs.dead
    # everyone stale -> no placement, and the refusal is counted
    before = fs.stale_skips
    assert fs.least_loaded(now=30.0) is None
    assert fs.stale_skips == before + 1


def test_heartbeat_timeout_death_is_terminal():
    cfg = FleetConfig(staleness=4.0, heartbeat_timeout=6.0)
    fs = FleetState(cfg)
    fs.observe(_status(0, 1, [0]), now=0.0)
    fs.observe(_status(1, 1, [0]), now=5.0)
    assert fs.check(now=7.0) == [0]          # only 0 crossed the horizon
    assert fs.check(now=7.5) == []           # newly-dead reported ONCE
    # resurrection: a late heartbeat from the dead process is dropped
    assert not fs.observe(_status(0, 99, [0]), now=8.0)
    assert fs.resurrections_ignored == 1
    assert not fs.alive(0) and fs.alive(1)
    assert fs.least_loaded(now=8.0) == 1


def test_max_inflight_caps_admission():
    cfg = FleetConfig(max_inflight=2)
    fs = FleetState(cfg)
    fs.observe(_status(0, 1, [0]), now=0.0)
    for _ in range(2):
        assert fs.least_loaded(now=0.0) == 0
        fs.note_submit(0)
    assert fs.least_loaded(now=0.0) is None  # cap reached, snapshot unmoved
    fs.observe(_status(0, 2, [0], submits_seen=2), now=1.0)
    assert fs.least_loaded(now=1.0) == 0     # snapshot caught up


# ------------------------------------------- delayed-snapshot no-flap replay

def test_no_flap_under_delayed_snapshots():
    """Regression for bounded stale-load admission: snapshots arrive D
    steps late, two submits arrive per step, each process drains one
    request per step. Without the credit term every inter-snapshot burst
    herds onto one process and the next snapshot swings it back; with
    it, placement must stay balanced and alternating."""
    D, STEPS = 3, 40
    cfg = FleetConfig(heartbeat_every=1, staleness=float(D + 2),
                      heartbeat_timeout=50.0)
    fs = FleetState(cfg)
    fs.last_seen.update({0: 0.0, 1: 0.0})   # hello, as FleetRouter seeds
    queue = {0: 0, 1: 0}        # worker-side queue depths (ground truth)
    seen = {0: 0, 1: 0}         # worker-side submits_seen at status time
    inflight = []               # (deliver_at, ProcessStatus)
    homes, seq = [], {0: 0, 1: 0}
    for t in range(STEPS):
        # workers: drain one, emit a status that lands D steps later
        for p in (0, 1):
            queue[p] = max(0, queue[p] - 1)
            seq[p] += 1
            inflight.append((t + D, _status(p, seq[p], [queue[p]],
                                            submits_seen=seen[p])))
        for at, st in [x for x in inflight if x[0] <= t]:
            fs.observe(st, now=float(t))
            inflight.remove((at, st))
        # coordinator: two arrivals per step
        for _ in range(2):
            p = fs.least_loaded(now=float(t))
            assert p is not None
            fs.note_submit(p)
            queue[p] += 1
            seen[p] = fs.submits_sent[p]   # worker sees it next status
            homes.append(p)
    warm = homes[2 * D:]                   # after the first snapshots land
    # balanced overall...
    assert abs(warm.count(0) - warm.count(1)) <= 2
    # ...and no herding run longer than one heartbeat+delay window
    run, longest = 1, 1
    for a, b in zip(warm, warm[1:]):
        run = run + 1 if a == b else 1
        longest = max(longest, run)
    assert longest <= D + 1, f"flapping: {longest}-long run in {warm}"


# ---------------------------------------------------------------- transport

def test_socket_endpoint_roundtrip():
    listener = ControlListener()
    try:
        worker = connect(listener.address)
        coord = listener.accept(timeout=10.0)
        worker.send({"kind": "hello", "process_index": 0})
        coord.send({"kind": "submit", "rid": 0,
                    "prompt": np.arange(4, dtype=np.int32),
                    "max_new_tokens": 8})
        import time
        deadline = time.monotonic() + 5.0
        got_c, got_w = [], []
        while (not got_c or not got_w) and time.monotonic() < deadline:
            got_c += coord.poll()
            got_w += worker.poll()
            time.sleep(0.005)
        assert got_c and got_c[0]["kind"] == "hello"
        assert got_w and got_w[0]["prompt"] == [0, 1, 2, 3]
        worker.close()
        deadline = time.monotonic() + 5.0
        while coord.alive and time.monotonic() < deadline:
            time.sleep(0.005)
        assert not coord.alive            # peer hangup is a liveness fact
        assert coord.send({"kind": "stop"}) in (True, False)  # no raise
        coord.close()
    finally:
        listener.close()
