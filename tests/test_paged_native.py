"""Page-table-native decode attention (PR 8).

Contracts under test:

  * three-way greedy token identity per cache family: the NATIVE paged
    decode (attention reads/writes the page-major store through the page
    table) == the LEGACY gather-run-scatter wrap (paged_native=False) ==
    the unpaged slab — plain and speculate=K, local and (subprocess,
    8 forced CPU devices) sharded;
  * the native decode hot path never touches `PageLayout.gather/scatter`
    (GATHER_EVENTS stays empty) while the legacy wrap does, and the native
    path dispatches through the paged attention op (PAGED_ATTN_EVENTS);
  * `gather_bytes_avoided` counts the traffic the native path did not
    move (> 0 native, == 0 legacy/slab) and pools across replicas;
  * multi-turn chat: a finished request publishes its WHOLE conversation
    (prompt + generated) into the prefix tree, so the next turn matches
    the full prior exchange, skips that prefill, and still emits the
    slab engine's exact tokens — with the pool draining back to pristine;
  * suffix-prefill pow2 bucketing never bucket-pads PAGE allocation: the
    slot's pages are sized from the true footprint even when the prefill
    shape is padded (satellite regression).
"""

import numpy as np
import pytest

from repro.kernels import ops
from repro.serve import (DraftSpec, EngineConfig, InferenceEngine,
                         ModelRegistry)
from repro.serve.paging import GATHER_EVENTS

from test_serve_paged import ARCHS, _jobs, run_script

_REGISTRY = ModelRegistry()


def _run(model, jobs, *, n_slots=3, max_len=32, **kw):
    eng = InferenceEngine(model, EngineConfig(n_slots=n_slots,
                                              max_len=max_len, **kw))
    reqs = [eng.submit(p, g, arrival_step=i)
            for i, (p, g) in enumerate(jobs)]
    eng.run()
    return [r.generated for r in reqs], eng


# ---------------------------------------------------------------------------
# three-way identity + hot-path trace events
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_native_vs_legacy_identity_and_events(arch):
    """Native and legacy paged decode emit identical greedy tokens (each is
    separately slab-identical — test_serve_paged gates that), and the
    trace-time event logs prove WHICH path compiled: the native decode
    never materialises a gather/scatter, the legacy wrap does."""
    m = _REGISTRY.load(arch)
    jobs = _jobs(m)
    GATHER_EVENTS.clear()
    ops.PAGED_ATTN_EVENTS.clear()
    native, eng_n = _run(m, jobs, decode_chunk=2, page_size=8,
                         prefix_cache=False)
    assert not GATHER_EVENTS, GATHER_EVENTS   # no gather on the hot path
    assert ops.PAGED_ATTN_EVENTS              # paged attention op compiled
    legacy, eng_l = _run(m, jobs, decode_chunk=2, page_size=8,
                         prefix_cache=False, paged_native=False)
    assert any(ev[0] == "gather" for ev in GATHER_EVENTS)
    assert any(ev[0] == "scatter" for ev in GATHER_EVENTS)
    assert native == legacy
    # the avoided-traffic ledger: positive per native dispatch, zero legacy
    rep_n, rep_l = eng_n.metrics.report(), eng_l.metrics.report()
    assert rep_n["gather_bytes_avoided"] > 0
    assert rep_n["gather_bytes_avoided"] == pytest.approx(
        eng_n.backend.gather_bytes_per_dispatch()
        * rep_n["decode_steps"])
    assert rep_l["gather_bytes_avoided"] == 0.0


def test_native_speculative_identity_and_ledger():
    """speculate=K through the native paged dispatch: token-identical to
    the legacy wrap (and transitively the slab), with the speculative
    cycle's avoided gather traffic on the ledger."""
    m = _REGISTRY.load(ARCHS[0], draft_spec=DraftSpec(bits=8))
    jobs = _jobs(m, seed=3)
    GATHER_EVENTS.clear()
    native, eng = _run(m, jobs, speculate=2, page_size=8)
    assert not GATHER_EVENTS
    legacy, _ = _run(m, jobs, speculate=2, page_size=8, paged_native=False)
    assert native == legacy
    rep = eng.metrics.report()
    assert rep["spec_dispatches"] > 0
    assert rep["gather_bytes_avoided"] > 0


def test_sharded_native_vs_legacy_identity():
    """(data=4, model=2) mesh: native paged decode == legacy wrap == local
    slab, with donation aliasing intact — the sharded leg of the grid
    (test_serve_paged covers native-sharded for every arch)."""
    run_script("""
        import numpy as np
        from repro.serve import (EngineConfig, InferenceEngine,
                                 ModelRegistry, ShardedBackend)
        reg = ModelRegistry()
        m = reg.load("nemotron-4-340b")
        rng = np.random.default_rng(11)
        jobs = [(rng.integers(0, m.cfg.vocab, s0), gen)
                for s0, gen in [(5, 6), (9, 4), (7, 5)]]
        def run(backend=None, **kw):
            eng = InferenceEngine(
                m, EngineConfig(n_slots=4, max_len=32, decode_chunk=2,
                                **kw), backend=backend)
            rs = [eng.submit(p, g, arrival_step=i)
                  for i, (p, g) in enumerate(jobs)]
            eng.run()
            return [r.generated for r in rs], eng
        slab, _ = run()
        nat, eng = run(ShardedBackend(mesh_shape=(4, 2)), page_size=8,
                       n_pages=24)
        leg, _ = run(ShardedBackend(mesh_shape=(4, 2)), page_size=8,
                     n_pages=24, paged_native=False)
        assert slab == nat == leg, (slab, nat, leg)
        assert eng.metrics.report()["gather_bytes_avoided"] > 0
        print("sharded native vs legacy identity OK")
    """)


# ---------------------------------------------------------------------------
# multi-turn conversation reuse
# ---------------------------------------------------------------------------

def test_multi_turn_chat_reuses_whole_conversation():
    """Turn 2 of a chat (prior prompt + prior reply + follow-up) matches
    every FULL page of the prior conversation — generated tokens included,
    which prompt-only publishing could never cover — skips that prefill,
    counts a conversation hit, and still emits the slab engine's exact
    tokens. Draining the engine returns the pool to pristine."""
    m = _REGISTRY.load(ARCHS[0])
    rng = np.random.default_rng(7)
    p1, g1 = rng.integers(0, m.cfg.vocab, 8), 17
    eng = InferenceEngine(m, EngineConfig(n_slots=2, max_len=64,
                                          page_size=8))
    r1 = eng.submit(p1, g1)
    eng.run()
    conv = np.concatenate([p1, np.asarray(r1.generated, np.int32)])
    assert len(conv) == 25
    # valid KV stops at the conversation's second-to-last position (the
    # final emitted token's KV was never written), so 3 full pages of the
    # 25-token exchange are published: 24 matched tokens for turn 2 —
    # prompt-only publishing would have matched just len(p1) = 8
    p2 = np.concatenate([conv, rng.integers(0, m.cfg.vocab, 5)])
    r2 = eng.submit(p2, 5)
    eng.run()
    assert r2.prefix_matched == 24
    rep = eng.metrics.report()
    assert rep["conversation_prefix_hits"] == 1.0
    assert rep["conversation_tokens_reused"] == 24.0
    # token identity: a fresh slab engine given the same turn-2 prompt
    slab, _ = _run(m, [(p2, 5)], n_slots=2, max_len=64)
    assert r2.generated == slab[0]
    # pristine drain: only tree-retained pages remain referenced
    pool = eng.pool
    assert pool.n_active == 0
    assert pool.pages_in_use == pool.index.n_nodes
    pool.index.clear(pool._release)
    assert int(pool.refs[1:].sum()) == 0
    assert len(pool._free_pages) == pool.n_usable_pages


def test_shed_request_never_publishes_conversation():
    """Cancel/shed paths free pages without publishing: the next admission
    of the same history must match only the PROMPT pages the admission
    path published, never pages from the cancelled generation."""
    m = _REGISTRY.load(ARCHS[0])
    rng = np.random.default_rng(9)
    p1 = rng.integers(0, m.cfg.vocab, 16)
    eng = InferenceEngine(m, EngineConfig(n_slots=2, max_len=64,
                                          page_size=8))
    r1 = eng.submit(p1, 12)
    for _ in range(4):
        eng.step()
    eng.cancel(r1)
    assert r1.state == "shed"
    conv = np.concatenate([p1, np.asarray(r1.generated, np.int32),
                           rng.integers(0, m.cfg.vocab, 4)])
    matched, _, from_conversation = eng.backend.prefix_match(conv)
    assert matched <= 16                # prompt pages only
    assert not from_conversation
    assert eng.metrics.report()["conversation_prefix_hits"] == 0.0


# ---------------------------------------------------------------------------
# suffix bucketing vs page accounting (satellite regression)
# ---------------------------------------------------------------------------

def test_suffix_bucket_page_accounting():
    """Page allocation is sized from the TRUE footprint (prompt + budget +
    headroom), never the pow2 prefill bucket: a 5-token suffix bucketed to
    a 16-token prefill shape must still allocate ceil(true/P) pages, with
    the padded tail's writes landing in the sink page / masked positions
    instead of costing real pages."""
    m = _REGISTRY.load(ARCHS[0])
    rng = np.random.default_rng(1)
    sys_p = rng.integers(0, m.cfg.vocab, 16)
    tail = rng.integers(0, m.cfg.vocab, 5)
    eng = InferenceEngine(m, EngineConfig(n_slots=1, max_len=32,
                                          page_size=8, n_pages=9))
    pages_at_start = {}

    def cb(r, tok):
        pages_at_start.setdefault(r.id, len(eng.pool._slot_pages[r.slot]))

    r1 = eng.submit(sys_p, 4, on_token=cb)
    eng.run()
    r2 = eng.submit(np.concatenate([sys_p, tail]), 4, on_token=cb)
    # the suffix path really is bucket-padded (5 -> 16): the regression
    # only bites when the prefill shape and the footprint disagree
    assert eng._suffix_len(5, 16) == 16
    eng.run()
    assert r2.prefix_matched == 16
    # true footprint: 21 prompt + 4 budget = 25 positions -> 4 pages
    # (2 shared + 2 private); bucket-padded accounting would take
    # ceil((16 + 16 + 4) / 8) = 5
    assert pages_at_start[r2.id] == 4
    assert eng.metrics.pool_waits == 0
    # identity against the slab for the bucketed-suffix request
    slab, _ = _run(m, [(sys_p, 4), (np.concatenate([sys_p, tail]), 4)],
                   n_slots=1, max_len=32)
    assert [r1.generated, r2.generated] == slab
