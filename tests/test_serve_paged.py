"""Paged KV pool + radix-tree prefix reuse (serve.paging / serve.prefix).

Contracts under test:

  * greedy decode through the paged pool is TOKEN-IDENTICAL to the unpaged
    slab — transformer / SSM-hybrid / MLA, plain and speculate=K, local and
    (subprocess, 8 forced CPU devices) sharded;
  * prefix reuse skips the matched prefill without changing a single token,
    and the skip shows up in the metrics;
  * pool-churn invariants: randomized admit/finish/evict traffic leaks no
    pages, refcounts return to zero, and page pressure surfaces as
    `PoolExhausted` -> requeue (`pool_waits`), never a crashed step;
  * LRU eviction drops the least-recently-matched unreferenced prefix
    pages first and never touches pages a live slot still references.

Sharded cases use the same subprocess isolation as test_serve_sharded.py
(jax locks the device count at first init).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.serve import (DraftSpec, EngineConfig, InferenceEngine,
                         ModelRegistry, PagedCachePool, PoolExhausted,
                         PrefixIndex, ServeMetrics, prefix_supported)

# the three cache families paging must cover: positional full-attention KV,
# recurrent-state hybrid (paged attn leaves + resident conv/ssm leaves),
# positional compressed MLA latents
ARCHS = ["nemotron-4-340b", "jamba-v0.1-52b", "minicpm3_4b"]

_REGISTRY = ModelRegistry()

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src")


def _jobs(model, seed=11, lens=((5, 6), (9, 4), (7, 5))):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, model.cfg.vocab, s0), gen) for s0, gen in lens]


def _run(model, jobs, *, n_slots=3, max_len=32, **kw):
    eng = InferenceEngine(model, EngineConfig(n_slots=n_slots,
                                              max_len=max_len, **kw))
    reqs = [eng.submit(p, g, arrival_step=i)
            for i, (p, g) in enumerate(jobs)]
    eng.run()
    return [r.generated for r in reqs], eng


def run_script(body: str, timeout=420) -> str:
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, timeout=timeout,
                       env=ENV, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


# ---------------------------------------------------------------------------
# greedy token-identity: paged vs slab
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_paged_greedy_identity_local(arch):
    """The paged dispatch gathers each slot's pages into exactly the slab
    layout and runs the unchanged fused step — greedy outputs must match
    the slab token for token, for every cache family and chunk K."""
    m = _REGISTRY.load(arch)
    jobs = _jobs(m)
    slab, _ = _run(m, jobs, decode_chunk=2)
    paged, eng = _run(m, jobs, decode_chunk=2, page_size=8,
                      prefix_cache=False)
    assert slab == paged
    # and with the prefix index live (distinct prompts: correctness only)
    paged2, _ = _run(m, jobs, decode_chunk=2, page_size=8)
    assert slab == paged2
    d = eng.pool.describe()
    if arch == "jamba-v0.1-52b":     # hybrid: recurrent leaves stay resident
        assert d["paged_leaves"] > 0 and d["resident_leaves"] > 0
    else:
        assert d["paged_leaves"] > 0 and d["resident_leaves"] == 0


@pytest.mark.parametrize("arch", ARCHS)
def test_paged_speculative_identity_local(arch):
    """speculate=K over the paged pool: rollback is an index rewind into
    PRIVATE headroom pages — still token-identical to plain slab decode."""
    m = _REGISTRY.load(arch, draft_spec=DraftSpec(bits=8))
    jobs = _jobs(m, seed=3)
    plain, _ = _run(m, jobs)
    spec_paged, eng = _run(m, jobs, speculate=2, page_size=8)
    assert plain == spec_paged
    assert eng.metrics.spec_dispatches > 0


def test_prefix_reuse_identity_and_skip_accounting():
    """Shared system prompt: every admission after the first matches the
    cached prefix, prefills only its suffix, and still emits exactly the
    slab engine's tokens. The skipped prefill is visible in the metrics
    and on the Request."""
    m = _REGISTRY.load(ARCHS[0])
    rng = np.random.default_rng(0)
    sys_p = rng.integers(0, m.cfg.vocab, 24)
    jobs = [(np.concatenate([sys_p, rng.integers(0, m.cfg.vocab, 5)]), 5)
            for _ in range(5)]
    slab, _ = _run(m, jobs, n_slots=2, max_len=48)
    paged, eng = _run(m, jobs, n_slots=2, max_len=48, page_size=8)
    assert slab == paged
    rep = eng.metrics.report()
    assert rep["prefix_hit_rate"] >= 0.7          # first admission misses
    assert rep["prefill_skip_fraction"] >= 0.5    # the acceptance gate
    assert rep["prefill_tokens_skipped"] == 4 * 24
    matched = sorted(r.prefix_matched for r in eng.requests.values())
    assert matched == [0, 24, 24, 24, 24]
    assert rep["pages_in_use"] > 0 and rep["page_occupancy"] > 0


def test_prefix_disables_itself_off_positional_archs():
    """Recurrent/windowed/enc-dec archs cannot share positional pages for a
    full prefill: the pool must refuse the index (paging itself still on)."""
    assert prefix_supported(_REGISTRY.load(ARCHS[0]).cfg)
    for arch in ("jamba-v0.1-52b", "falcon-mamba-7b", "h2o-danube-1.8b"):
        cfg = _REGISTRY.load(arch).cfg
        assert not prefix_supported(cfg), arch
    _, eng = _run(_REGISTRY.load("jamba-v0.1-52b"),
                  _jobs(_REGISTRY.load("jamba-v0.1-52b")), page_size=8)
    assert eng.pool.index is None
    assert eng.metrics.report()["prefix_hit_rate"] == 0.0


# ---------------------------------------------------------------------------
# pool churn / exhaustion / eviction
# ---------------------------------------------------------------------------

def test_pool_churn_invariants_randomized():
    """Randomized admit/finish/insert/evict traffic directly against the
    pool: no page leaks, refcounts mirror (slot uses + tree retention)
    exactly, and draining everything returns the pool to pristine."""
    cfg = _REGISTRY.load(ARCHS[0]).cfg
    pool = PagedCachePool(cfg, n_slots=4, max_len=32, page_size=8,
                          n_pages=15)
    rng = np.random.default_rng(0)
    live = {}                                    # slot -> prompt tokens
    for step in range(200):
        if live and (rng.random() < 0.45 or pool.n_free == 0):
            slot = int(rng.choice(list(live)))
            live.pop(slot)
            pool.free(slot)
            continue
        prompt = rng.integers(0, cfg.vocab, int(rng.integers(4, 28)))
        slot = pool.alloc()
        matched, shared, _ = pool.prefix_match(prompt)
        try:
            pool.alloc_pages(slot, len(prompt) + 4, shared)
        except PoolExhausted:
            pool.free(slot)                      # slot back, nothing leaked
            continue
        pool.prefix_insert(prompt, slot)
        live[slot] = prompt
        # invariant: every page's refcount == slot uses + tree retention
        uses = np.zeros(pool.n_pages, np.int64)
        for pages in pool._slot_pages:
            for p in pages:
                uses[p] += 1
        for node_pages in [pool.index.match(t)[0][:len(t) // 8]
                           for t in live.values()]:
            pass                                 # match only touches LRU
        assert int(pool.refs[1:].sum()) == int(uses[1:].sum()) \
            + pool.index.n_nodes
        assert pool.pages_in_use + len(pool._free_pages) \
            == pool.n_usable_pages
    for slot in list(live):
        pool.free(slot)
    dropped = pool.index.clear(pool._release)
    assert dropped >= 0
    assert int(pool.refs[1:].sum()) == 0
    assert len(pool._free_pages) == pool.n_usable_pages
    with pytest.raises(ValueError):
        pool.free(pool._free_slots[-1])          # double-free still caught


def test_pool_exhausted_surfaces_to_scheduler_not_the_step():
    """Free slots but not enough pages: the admission requeues (pool_waits
    counts it) and completes once a finishing request releases pages — the
    engine never crashes mid-step and every request drains in full."""
    m = _REGISTRY.load(ARCHS[0])
    rng = np.random.default_rng(5)
    jobs = [(rng.integers(0, m.cfg.vocab, 20), 8) for _ in range(4)]
    # 4 slots but pages for ~1.5 requests: admission is page-bound
    outs, eng = _run(m, jobs, n_slots=4, max_len=32, page_size=8,
                     n_pages=7, prefix_cache=False)
    assert all(len(o) == 8 for o in outs)
    assert eng.metrics.pool_waits > 0
    assert eng.pool.pages_in_use == 0            # all released on drain
    # identical tokens to the slab run despite the stalls
    slab, _ = _run(m, jobs, n_slots=4, max_len=32)
    assert outs == slab


def test_pool_too_small_for_one_slot_fails_at_build():
    """A pool that could never hold even one full request fails at engine
    construction (fast), not as an unreachable admission or a hung drain."""
    m = _REGISTRY.load(ARCHS[0])
    with pytest.raises(ValueError, match="one full slot"):
        InferenceEngine(m, EngineConfig(n_slots=2, max_len=32,
                                        page_size=8, n_pages=3))


def test_lru_eviction_prefers_stale_unreferenced_prefixes():
    """Three cached prefixes, capacity pressure, one refreshed by a match:
    eviction drops the stalest tree-only pages and spares both the
    refreshed prefix and pages still referenced by a live slot."""
    idx = PrefixIndex(page_size=4)
    refs = {}

    def retain(p):
        refs[p] = refs.get(p, 0) + 1

    def release(p):
        refs[p] -= 1

    t0, t1, t2 = (np.arange(8) + 100 * i for i in range(3))
    idx.insert(t0, [1, 2], retain)
    idx.insert(t1, [3, 4], retain)
    idx.insert(t2, [5, 6], retain)
    assert idx.match(t0)[0] == [1, 2]            # refresh t0: now hottest
    refs[3] += 1                                 # page 3 pinned by a "slot"
    freed = idx.evict(3, can_free=lambda p: refs[p] == 1, release=release)
    assert freed == 3
    assert idx.match(t0)[0] == [1, 2]            # refreshed prefix survives
    assert idx.match(t1)[0] == [3]               # pinned page 3 survives,
    assert refs[4] == 0 and refs[5] == 0         # its child + stale t2 gone
    assert idx.evicted == 3


def test_prefix_index_page_alignment_and_suffix_floor():
    """Matching is page-aligned and always leaves >= 1 suffix token; only
    FULL prompt pages are ever published."""
    cfg = _REGISTRY.load(ARCHS[0]).cfg
    pool = PagedCachePool(cfg, n_slots=2, max_len=32, page_size=8)
    prompt = np.arange(16)
    slot = pool.alloc()
    pool.alloc_pages(slot, 20)
    assert pool.prefix_insert(prompt, slot) == 2          # 16 // 8 pages
    # exact-multiple prompt: the match is capped one page short so the
    # suffix prefill still has a token to sample from
    matched, pages, conv = pool.prefix_match(prompt)
    assert matched == 8 and len(pages) == 1
    assert not conv                      # prompt pages, not generated ones
    # longer prompt sharing the prefix: both pages match
    matched, pages, _ = pool.prefix_match(np.arange(20))
    assert matched == 16 and len(pages) == 2
    # a 17-token prompt only has 2 full pages; partial tail never matches
    matched, _, _ = pool.prefix_match(np.arange(17))
    assert matched == 16


def test_paged_config_validation():
    m = _REGISTRY.load(ARCHS[0])
    with pytest.raises(ValueError, match="page_size"):
        InferenceEngine(m, EngineConfig(page_size=0))
    with pytest.raises(ValueError, match="device_loop"):
        InferenceEngine(m, EngineConfig(page_size=8, device_loop=False))
    with pytest.raises(ValueError, match="n_pages"):
        InferenceEngine(m, EngineConfig(n_pages=8))
    with pytest.raises(ValueError, match="one full slot"):
        PagedCachePool(m.cfg, n_slots=2, max_len=32, page_size=8, n_pages=3)


def test_metrics_aggregate_pools_prefix_and_pages():
    """Fleet pooling (satellite): hit rate over the UNION of admissions,
    skip fraction over the union of prompt tokens, page occupancy
    dispatch-weighted by each replica's own capacity — never a mean of
    per-replica rates."""
    a, b = ServeMetrics(), ServeMetrics()
    a.on_prefix(24, 30)
    a.on_prefix(0, 10)
    a.on_pages(6, 10)
    a.on_pages(8, 10)
    b.on_prefix(16, 16 + 4)
    b.on_pages(20, 40)
    b.on_pool_wait()
    agg = ServeMetrics.aggregate([a, b])
    assert agg["prefix_hit_rate"] == pytest.approx(2 / 3)
    assert agg["prefill_tokens_skipped"] == 40.0
    assert agg["prefill_skip_fraction"] == pytest.approx(40 / 60)
    assert agg["pages_in_use"] == pytest.approx((6 + 8 + 20) / 3)
    assert agg["page_occupancy"] == pytest.approx((6 + 8 + 20) / (20 + 40))
    assert agg["pool_waits"] == 1.0
    # a prefix-free fleet reports clean zeros, not NaNs
    clean = ServeMetrics.aggregate([ServeMetrics()])
    assert clean["prefix_hit_rate"] == 0.0
    assert clean["page_occupancy"] == 0.0


# ---------------------------------------------------------------------------
# sharded (8 forced CPU devices, subprocess)
# ---------------------------------------------------------------------------

def test_sharded_paged_identity_and_placement():
    """Paged greedy decode on a (data=4, model=2) mesh is token-identical
    to the local paged engine for every cache family; the store's page
    axis shards over 'data' like the slab's slot axis, kv-heads stay on
    'model', and the paged decode still carries input->output aliasing for
    store/table/state under pjit."""
    run_script("""
        import numpy as np
        from repro.serve import (EngineConfig, InferenceEngine,
                                 ModelRegistry, ShardedBackend)
        reg = ModelRegistry()
        for arch in {archs!r}:
            m = reg.load(arch)
            rng = np.random.default_rng(11)
            jobs = [(rng.integers(0, m.cfg.vocab, s0), gen)
                    for s0, gen in [(5, 6), (9, 4), (7, 5)]]
            def run(backend=None):
                eng = InferenceEngine(
                    m, EngineConfig(n_slots=4, max_len=32, decode_chunk=2,
                                    page_size=8, n_pages=24),
                    backend=backend)
                rs = [eng.submit(p, g, arrival_step=i)
                      for i, (p, g) in enumerate(jobs)]
                eng.run()
                return [r.generated for r in rs], eng
            local, _ = run()
            sh, eng = run(ShardedBackend(mesh_shape=(4, 2)))
            assert local == sh, (arch, local, sh)
            i, ls = next((j, s) for j, s in
                         enumerate(eng.pool.layout.specs)
                         if s.paged)          # resident leaves keep slab spec
            spec = eng.pool.store[i].sharding.spec
            # the page axis sits IN PLACE of the slot axis and shards the
            # same way ('data'); surrounding axes keep the slab's spec
            assert spec[ls.batch_axis] in ("data", ("data",)), (arch, spec)
            bk = eng.backend
            txt = bk._decode.lower(bk.params, eng.pool.store,
                                   eng.pool.page_table, bk.state).as_text()
            assert ("tf.aliasing_output" in txt
                    or "jax.buffer_donor" in txt), arch
            print(arch, "sharded paged identity + placement OK")
    """.format(archs=ARCHS))


def test_sharded_paged_prefix_and_speculative():
    """Shared prompts through the SHARDED suffix-prefill path, and
    speculate=K over the sharded paged pool: both token-identical to the
    local slab engine."""
    run_script("""
        import numpy as np
        from repro.serve import (DraftSpec, EngineConfig, InferenceEngine,
                                 ModelRegistry, ShardedBackend)
        reg = ModelRegistry()
        m = reg.load("nemotron-4-340b")
        rng = np.random.default_rng(0)
        sys_p = rng.integers(0, m.cfg.vocab, 16)
        jobs = [(np.concatenate([sys_p, rng.integers(0, m.cfg.vocab, 4)]), 4)
                for _ in range(4)]
        def run(model, backend=None, **kw):
            eng = InferenceEngine(model, EngineConfig(n_slots=2, max_len=32,
                                                      **kw), backend=backend)
            rs = [eng.submit(p, g, arrival_step=i)
                  for i, (p, g) in enumerate(jobs)]
            eng.run()
            return [r.generated for r in rs], eng
        slab, _ = run(m)
        sh, eng = run(m, ShardedBackend(mesh_shape=(4, 2)), page_size=8,
                      n_pages=24)
        assert slab == sh, (slab, sh)
        rep = eng.metrics.report()
        assert rep["prefix_hit_rate"] >= 0.7, rep["prefix_hit_rate"]
        assert rep["prefill_skip_fraction"] >= 0.5
        md = reg.load("nemotron-4-340b", draft_spec=DraftSpec(bits=8))
        plain, _ = run(md)
        spec, _ = run(md, ShardedBackend(mesh_shape=(4, 2)), speculate=2,
                      page_size=8, n_pages=24)
        assert plain == spec, (plain, spec)
        print("sharded prefix + speculative paged OK")
    """)
