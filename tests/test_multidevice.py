"""Multi-device semantics on 8 virtual CPU devices.

jax locks the device count at first init, so each test runs a small script
in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device_count=8 —
the same isolation discipline the dry-run uses.
"""

import os
import subprocess
import sys
import textwrap

import pytest

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src")


def run_script(body: str, timeout=240) -> str:
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, timeout=timeout,
                       env=ENV, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def test_pipeline_parallelism_matches_sequential():
    """GPipe over a 4-pod axis == sequential stage application (exact)."""
    run_script("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.distributed.pipeline import pipeline_apply, pipeline_reference
        devs = np.array(jax.devices()[:4])
        mesh = Mesh(devs, ("pod",))
        n_stages, n_micro, mb, d = 4, 8, 2, 16
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (n_stages, d, d)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))
        stage_fn = lambda w, h: jnp.tanh(h @ w)
        got = pipeline_apply(ws, x, stage_fn, mesh, axis="pod")
        want = pipeline_reference(ws, x, stage_fn)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)
        print("pipeline OK")
    """)


def test_pipeline_gradients_flow():
    """Backprop through the ppermute schedule: grads match sequential."""
    run_script("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.distributed.pipeline import pipeline_apply, pipeline_reference
        devs = np.array(jax.devices()[:4])
        mesh = Mesh(devs, ("pod",))
        ws = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 8)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 8))
        fn = lambda w, h: jnp.tanh(h @ w)
        g1 = jax.grad(lambda w: jnp.sum(pipeline_apply(w, x, fn, mesh)**2))(ws)
        g2 = jax.grad(lambda w: jnp.sum(pipeline_reference(w, x, fn)**2))(ws)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-5)
        print("pipeline grads OK")
    """)


def test_cross_pod_int8_psum():
    """int8-on-the-wire all-reduce: near-f32 psum, 4x fewer wire bytes."""
    run_script("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.distributed.compression import cross_pod_psum_int8
        devs = np.array(jax.devices()).reshape(8)
        mesh = Mesh(devs, ("pod",))
        # distinct per-pod partials, replicated layout
        def make(i):
            return jax.random.normal(jax.random.PRNGKey(i), (32, 32))
        xs = [np.asarray(make(i)) for i in range(8)]
        want = np.sum(xs, axis=0)
        # place per-device values via device_put on a sharded axis then shard_map
        x = jnp.stack(xs)                   # (8, 32, 32)
        sh = NamedSharding(mesh, P("pod"))
        xd = jax.device_put(x, sh)
        from jax.experimental.shard_map import shard_map
        import functools
        @functools.partial(shard_map, mesh=mesh, in_specs=P("pod"),
                           out_specs=P("pod"), check_rep=False)
        def reduce_fn(xx):
            from repro.distributed.compression import _quant_int8
            q, scale = _quant_int8(xx[0])
            smax = jax.lax.pmax(scale, "pod")
            qq = jnp.clip(jnp.round(xx[0] / smax), -127, 127).astype(jnp.int32)
            total = jax.lax.psum(qq, "pod")
            return (total.astype(jnp.float32) * smax)[None]
        got = np.asarray(reduce_fn(xd))[0]
        err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
        assert err < 0.05, err
        print("int8 psum OK, rel err", err)
    """)


def test_elastic_remesh_restore():
    """Checkpoint saved while sharded on mesh A restores onto mesh B."""
    run_script("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.manager import CheckpointManager
        mesh_a = jax.make_mesh((8, 1), ("data", "model"))
        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        tree = {"w": jax.device_put(
            jnp.arange(64.0).reshape(8, 8),
            NamedSharding(mesh_a, P("data", None)))}
        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d)
        mgr.save(1, tree)
        target = {"w": NamedSharding(mesh_b, P("data", "model"))}
        restored, step = mgr.restore(tree, shardings=target)
        assert step == 1
        assert restored["w"].sharding == target["w"]
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(64.0).reshape(8, 8))
        print("remesh OK")
    """)


def test_spmd_train_step_8dev_matches_1dev():
    """The sharded train step computes the same loss as single-device."""
    run_script("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs as C
        from repro.distributed import sharding as SH, steps as ST
        from repro.optim import adamw as O
        from jax.sharding import NamedSharding, PartitionSpec as P
        cfg = C.get_smoke("h2o_danube_1_8b")
        opt = O.OptimizerConfig()
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)),
                                       jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)),
                                       jnp.int32)}
        state = ST.init_train_state(jax.random.PRNGKey(0), cfg, opt)
        step = ST.make_train_step(cfg, opt)
        _, m_ref = jax.jit(step)(state, batch)     # default: 1-device exec

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with SH.use_mesh(mesh):
            psh = SH.param_shardings(mesh, state["params"])
            st_sh = {"params": psh,
                     "opt": {"m": psh, "v": psh,
                             "count": NamedSharding(mesh, P())},
                     "step": NamedSharding(mesh, P())}
            bsh = {k: NamedSharding(mesh, P(("data",), None))
                   for k in batch}
            sharded = jax.jit(step, in_shardings=(st_sh, bsh))
            _, m_spmd = sharded(state, batch)
        l1, l2 = float(m_ref["loss"]), float(m_spmd["loss"])
        assert abs(l1 - l2) / l1 < 1e-3, (l1, l2)
        print("spmd==1dev OK", l1, l2)
    """)


def test_mini_dryrun_smoke_config_on_8dev_mesh():
    """End-to-end dry-run machinery on a small mesh: lower+compile+analyze."""
    run_script("""
        import jax, numpy as np
        from repro import configs as C
        from repro.analysis import hlo as HA
        from repro.distributed import sharding as SH, steps as ST
        from repro.optim import adamw as O
        import jax.numpy as jnp
        cfg = C.get_smoke("gemma2_27b")
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        opt = O.OptimizerConfig()
        state = jax.eval_shape(
            lambda k: ST.init_train_state(k, cfg, opt),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        from jax.sharding import NamedSharding, PartitionSpec as P
        with SH.use_mesh(mesh):
            psh = SH.param_shardings(mesh, state["params"])
            st_sh = {"params": psh,
                     "opt": {"m": psh, "v": psh,
                             "count": NamedSharding(mesh, P())},
                     "step": NamedSharding(mesh, P())}
            bsh = {k: NamedSharding(mesh, P(("data",), None)) for k in batch}
            step = ST.make_train_step(cfg, opt)
            compiled = jax.jit(step, in_shardings=(st_sh, bsh)).lower(
                state, batch).compile()
        r = HA.analyze(compiled.as_text())
        assert r["flops"] > 0 and r["wire_bytes"] > 0
        assert compiled.memory_analysis() is not None
        print("mini dryrun OK flops=%.3g wire=%.3g" % (r["flops"], r["wire_bytes"]))
    """)
