"""Mesh-aware serving on 8 virtual CPU devices: ShardedBackend equivalence,
slab/state placement, donation under pjit, router over replica submeshes.

Same subprocess isolation as test_multidevice.py (jax locks the device
count at first init): every test runs a script under
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import os
import subprocess
import sys
import textwrap

import pytest

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src")

IDENTITY_SCRIPT = """
    import numpy as np
    from repro.serve import (EngineConfig, InferenceEngine, ModelRegistry,
                             ShardedBackend)
    arch = {arch!r}
    reg = ModelRegistry()
    m = reg.load(arch)
    rng = np.random.default_rng(11)
    jobs = [(rng.integers(0, m.cfg.vocab, s0), gen)
            for s0, gen in [(5, 6), (9, 4), (7, 5)]]
    def run(backend=None, k=1):
        eng = InferenceEngine(
            m, EngineConfig(n_slots=4, max_len=32, decode_chunk=k),
            backend=backend)
        rs = [eng.submit(p, g, arrival_step=i)
              for i, (p, g) in enumerate(jobs)]
        eng.run()
        return [r.generated for r in rs], eng
    local, _ = run()
    sh1, eng1 = run(backend=ShardedBackend(mesh_shape=(4, 2)), k=1)
    sh3, _ = run(backend=ShardedBackend(mesh_shape=(4, 2)), k=3)
    assert local == sh1, (local, sh1)          # token identity, K=1
    assert local == sh3, (local, sh3)          # ... and for any chunk K
    d = eng1.backend.describe()
    assert d["mesh_shape"] == [4, 2] and d["n_devices"] == 8
    print(arch, "sharded identity OK")
"""


def run_script(body: str, timeout=420) -> str:
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, timeout=timeout,
                       env=ENV, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b",     # transformer + SWA
                                  "falcon-mamba-7b",     # pure SSM
                                  "minicpm3_4b"])        # MLA
def test_sharded_backend_greedy_identity(arch):
    """Greedy decode through ShardedBackend on a (data=4, model=2) mesh is
    token-identical to LocalBackend for K=1 and K=3 — placement is not
    allowed to change outputs, per architecture family."""
    run_script(IDENTITY_SCRIPT.format(arch=arch))


def test_slab_and_state_actually_shard_over_the_mesh():
    """The slab's slot axis lands on 'data', kv-heads on 'model', the
    per-slot state vectors on 'data'; a non-divisible slot count falls back
    to a replicated slot axis instead of seq-sharding the slab."""
    run_script("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.distributed import sharding as SH, steps as ST
        from repro.serve import EngineConfig, InferenceEngine, ModelRegistry
        from repro.serve import ShardedBackend
        from repro.models import transformer as T
        from repro import configs as C

        m = ModelRegistry().load("h2o-danube-1.8b")
        eng = InferenceEngine(
            m, EngineConfig(n_slots=4, max_len=32),
            backend=ShardedBackend(mesh_shape=(4, 2)))
        k = eng.pool.caches["blocks"][0]["mixer"]["k"]   # (L, B, KV, S, dh)
        spec = k.sharding.spec
        assert spec[1] in ("data", ("data",)), spec      # slots over data
        assert spec[2] == "model", spec                  # kv heads over TP
        assert spec[3] is None, spec                     # seq NEVER sharded
        st = eng.backend.state
        assert st["tokens"].sharding.spec == P("data")
        assert st["key"].sharding.spec in (P(None), P())

        # non-divisible slots: replicated fallback, not seq-over-data
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        caches = T.make_caches(C.get_smoke("h2o_danube_1_8b"), 3, 32)
        specs = SH.cache_pspecs(caches, mesh, 3, slab=True)
        for leaf in jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, P)):
            assert all(ax not in ("data", ("data",)) for ax in leaf), leaf
        state_specs = ST.decode_state_pspecs(mesh, 3)
        assert state_specs["tokens"] == P(None)
        print("slab/state placement OK")
    """)


def test_sharded_decode_still_donates_under_pjit():
    """out_shardings pinned to the donated inputs' shardings: the lowered
    SPMD module still carries input->output aliasing for slab and state
    (no per-dispatch slab copy on donation-capable backends)."""
    run_script("""
        import jax.numpy as jnp
        from repro.serve import (EngineConfig, InferenceEngine,
                                 ModelRegistry, ShardedBackend)
        m = ModelRegistry().load("h2o-danube-1.8b")
        eng = InferenceEngine(
            m, EngineConfig(n_slots=4, max_len=32, decode_chunk=2),
            backend=ShardedBackend(mesh_shape=(4, 2)))
        bk = eng.backend
        txt = bk._decode.lower(bk.params, eng.pool.caches,
                               bk.state).as_text()
        assert "tf.aliasing_output" in txt or "jax.buffer_donor" in txt
        txt_w = eng.pool._write.lower(
            eng.pool.caches, eng.pool.single_template,
            jnp.asarray(0, jnp.int32)).as_text()
        assert "tf.aliasing_output" in txt_w or "jax.buffer_donor" in txt_w
        print("sharded donation OK")
    """)


def test_router_over_disjoint_replica_submeshes():
    """replica_meshes splits the data axis into disjoint per-replica
    submeshes; the router drives sharded replicas exactly like local ones
    and the fleet drains a bursty trace."""
    run_script("""
        import numpy as np
        from repro.launch import mesh as M
        from repro.serve import (EngineConfig, ModelRegistry, ReplicaRouter,
                                 ShardedBackend)
        meshes = M.replica_meshes(4, 2, 2)
        devs = [frozenset(d.id for d in mm.devices.ravel()) for mm in meshes]
        assert devs[0].isdisjoint(devs[1])
        assert all(len(d) == 4 for d in devs)
        m = ModelRegistry().load("h2o-danube-1.8b")
        router = ReplicaRouter.build(
            m, EngineConfig(n_slots=2, max_len=32, decode_chunk=2,
                            max_waiting=2),
            2, backend_factory=lambda i: ShardedBackend(mesh=meshes[i]))
        rng = np.random.default_rng(0)
        reqs = [router.submit(rng.integers(0, m.cfg.vocab, 6), 5,
                              arrival_step=0) for _ in range(6)]
        router.run()
        assert all(len(r.generated) == 5 for r in reqs)
        rep = router.report()
        assert rep["requests_completed"] == 6.0
        assert {e.backend.name for e in router.replicas} == {"sharded"}
        print("router over submeshes OK, spills", int(rep["spills"]))
    """)


def test_sharded_tier_swap_and_cancel_hygiene():
    """PR 7 grid, sharded leg: the QoS tier swap re-jits the decode step
    per tier on the mesh (params are a pinned non-donated operand, so the
    swap is KV-safe), mid-flight cancel + deadline shed release slots
    cleanly, and a tier-0 sharded run stays token-identical to local."""
    run_script("""
        import numpy as np
        from repro.serve import (DraftSpec, EngineConfig, InferenceEngine,
                                 ModelRegistry, QoSConfig, ShardedBackend)
        tiers = (DraftSpec.from_args(8, 0.5, 0),)
        m = ModelRegistry().load("h2o-danube-1.8b", tier_specs=tiers)
        rng = np.random.default_rng(2)
        jobs = [(rng.integers(0, m.cfg.vocab, 6), 6) for _ in range(6)]

        # degradation under load on the mesh: all complete across the swap
        eng = InferenceEngine(
            m, EngineConfig(n_slots=2, max_len=32,
                            qos=QoSConfig(demote_depth=2, promote_depth=0,
                                          hysteresis=1)),
            backend=ShardedBackend(mesh_shape=(4, 2)))
        reqs = [eng.submit(p, g) for p, g in jobs]
        eng.run()
        assert all(r.state == "done" and len(r.generated) == 6
                   for r in reqs)
        assert eng.metrics.tier_demotions >= 1
        assert eng.tier == 0                    # drained: re-promoted
        assert eng.pool.n_free == 2

        # cancel + doomed deadline on the sharded engine: explicit
        # terminal states, slots released, survivor completes
        eng2 = InferenceEngine(
            m, EngineConfig(n_slots=2, max_len=32),
            backend=ShardedBackend(mesh_shape=(4, 2)))
        keep = eng2.submit(jobs[0][0], 6)
        victim = eng2.submit(jobs[1][0], 6)
        doomed = eng2.submit(jobs[2][0], 10, deadline_steps=2)
        assert doomed.state == "shed" and doomed.shed_reason == "deadline"
        for _ in range(2):
            eng2.step()
        eng2.cancel(victim)
        assert victim.state == "shed" and victim.shed_reason == "cancel"
        eng2.run()
        assert keep.state == "done" and len(keep.generated) == 6
        assert eng2.pool.n_active == 0 and eng2.pool.n_free == 2

        # tier-0 sharded output with resident tiers == plain local output
        local = InferenceEngine(
            ModelRegistry().load("h2o-danube-1.8b"),
            EngineConfig(n_slots=2, max_len=32))
        lk = local.submit(jobs[0][0], 6)
        local.run()
        assert tuple(keep.generated) == tuple(lk.generated)
        print("sharded tier swap + cancel hygiene OK")
    """)
