"""Pallas selective-scan kernel vs oracle, and vs the model's chunked scan."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ssm_scan import ssm_scan_ref
from repro.models import ssm as S


def _inputs(key, bsz, s, di, st, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    u = jax.random.normal(ks[0], (bsz, s, di), dtype) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, di), dtype) - 1.0)
    b = jax.random.normal(ks[2], (bsz, s, st), dtype) * 0.5
    c = jax.random.normal(ks[3], (bsz, s, st), dtype) * 0.5
    a = -jnp.exp(jax.random.normal(ks[4], (di, st), jnp.float32) * 0.3)
    return u, dt, b, c, a


@pytest.mark.parametrize("bsz,s,di,st,bd,ck", [
    (1, 16, 8, 4, 8, 4),
    (2, 32, 16, 4, 8, 8),
    (2, 24, 16, 8, 16, 4),
])
def test_ssm_scan_kernel_matches_oracle(bsz, s, di, st, bd, ck):
    u, dt, b, c, a = _inputs(0, bsz, s, di, st)
    y_ref, h_ref = ssm_scan_ref(u, dt, b, c, a)
    y, h = ops.ssm_scan(u, dt, b, c, a, backend="interpret", bd=bd, ck=ck)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssm_scan_kernel_bf16_inputs():
    u, dt, b, c, a = _inputs(1, 1, 16, 8, 4, jnp.bfloat16)
    y_ref, _ = ssm_scan_ref(u, dt, b, c, a)
    y, _ = ops.ssm_scan(u, dt, b, c, a, backend="interpret", bd=8, ck=4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_ssm_scan_matches_model_chunked_scan():
    """Same recurrence as models.ssm._scan_chunked + the C contraction."""
    bsz, s, di, st = 2, 32, 8, 4
    u, dt, b, c, a = _inputs(2, bsz, s, di, st)
    dA = jnp.exp(dt[..., None] * a)
    dBx = (dt * u)[..., None] * b[:, :, None, :]
    cfg = S.MambaConfig(d_model=16, d_inner=di, d_state=st, chunk=8)
    h = S._scan_chunked(dA, dBx, cfg)
    y_model = jnp.einsum("bsdn,bsn->bsd", h, c)
    y_kernel, _ = ops.ssm_scan(u, dt, b, c, a, backend="interpret",
                               bd=8, ck=8)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               rtol=1e-4, atol=1e-4)


def test_ssm_scan_state_carries_across_chunks():
    """Final state from a 2-chunk kernel run == state after full sequence."""
    u, dt, b, c, a = _inputs(3, 1, 8, 8, 4)
    _, h_full = ssm_scan_ref(u, dt, b, c, a)
    _, h_k = ops.ssm_scan(u, dt, b, c, a, backend="interpret", bd=8, ck=4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)


def test_mamba_apply_kernel_backend_matches_ref():
    """mamba_apply(backend='interpret') routes through the Pallas kernel and
    matches the pure-XLA path, full-sequence and prefill."""
    cfg = S.MambaConfig(d_model=16, d_inner=32, d_state=4, d_conv=4, chunk=8)
    params = S.mamba_init(jax.random.PRNGKey(20), cfg)
    x = jax.random.normal(jax.random.PRNGKey(21), (2, 16, 16)) * 0.5
    y_ref, _ = S.mamba_apply(params, x, cfg, backend="ref")
    y_k, _ = S.mamba_apply(params, x, cfg, backend="interpret")
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)
    # prefill path: caches must agree too
    cache_r = S.make_mamba_cache(cfg, 2)
    cache_k = S.make_mamba_cache(cfg, 2)
    yr, cr = S.mamba_apply(params, x, cfg, backend="ref", cache=cache_r)
    yk, ck = S.mamba_apply(params, x, cfg, backend="interpret", cache=cache_k)
    np.testing.assert_allclose(np.asarray(ck["ssm"]), np.asarray(cr["ssm"]),
                               rtol=2e-3, atol=2e-3)
