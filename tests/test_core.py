"""core/: sparsity plans, quantization, KratosSpec end-to-end, conv."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import conv as KC
from repro.core import kratos as kr
from repro.core import quantize as qz
from repro.core import sparsity as sp


# ---------------------------------------------------------------------------
# sparsity plans
# ---------------------------------------------------------------------------

def test_plan_balanced_and_deterministic():
    p1 = sp.make_plan(256, 128, bk=16, bn=16, sparsity=0.5, seed=7)
    p2 = sp.make_plan(256, 128, bk=16, bn=16, sparsity=0.5, seed=7)
    np.testing.assert_array_equal(p1.indices, p2.indices)
    assert p1.nnz == 8                      # 16 k-blocks * (1 - 0.5)
    assert p1.indices.shape == (8, 8)
    assert (np.diff(p1.indices, axis=1) > 0).all()     # sorted, unique
    p3 = sp.make_plan(256, 128, bk=16, bn=16, sparsity=0.5, seed=8)
    assert not np.array_equal(p1.indices, p3.indices)  # seed matters


@pytest.mark.parametrize("sparsity", [0.0, 0.1, 0.5, 0.9])
def test_plan_flops_fraction_linear(sparsity):
    plan = sp.make_plan(1280, 1280, bk=128, bn=128, sparsity=sparsity)
    assert abs(plan.dense_flops_fraction - (1 - sparsity)) < 0.051


def test_mask_matches_plan_and_roundtrip():
    plan = sp.make_plan(64, 64, bk=8, bn=8, sparsity=0.5, seed=1)
    mask = sp.plan_mask(plan)
    assert mask.shape == (64, 64)
    assert abs(mask.mean() - 0.5) < 1e-6
    w = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)),
                    jnp.float32)
    blocks = sp.pack_blocks(w, plan)
    back = sp.unpack_blocks(blocks, plan)
    np.testing.assert_allclose(np.asarray(back),
                               np.asarray(w) * mask, rtol=1e-6)


def test_plan_gradients_flow_through_pack():
    plan = sp.make_plan(32, 32, bk=8, bn=8, sparsity=0.5, seed=0)
    w = jnp.ones((32, 32))

    def f(w):
        return jnp.sum(sp.pack_blocks(w, plan) ** 2)

    g = jax.grad(f)(w)
    mask = sp.plan_mask(plan)
    np.testing.assert_allclose(np.asarray(g), 2.0 * mask, rtol=1e-6)


# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits,max_rel_err", [(8, 0.01), (4, 0.12), (2, 0.8)])
def test_quant_dequant_error_bounds(bits, max_rel_err):
    w = jnp.asarray(np.random.default_rng(2).normal(size=(128, 64)),
                    jnp.float32)
    qt = qz.quantize(w, bits)
    back = qz.dequantize(qt)
    err = np.abs(np.asarray(back - w)).max()
    assert err <= np.abs(np.asarray(w)).max() * max_rel_err + 1e-6


def test_quant_packed_bytes_scale_with_bits():
    w = jnp.ones((128, 64))
    sizes = {b: qz.quantize(w, b).data.size for b in (8, 4, 2, 1)}
    assert sizes[8] == 2 * sizes[4] == 4 * sizes[2] == 8 * sizes[1]


def test_fake_quantize_idempotent():
    w = jnp.asarray(np.random.default_rng(3).normal(size=(64, 32)), jnp.float32)
    fq = qz.fake_quantize(w, 4)
    fq2 = qz.fake_quantize(fq, 4)
    np.testing.assert_allclose(np.asarray(fq), np.asarray(fq2),
                               rtol=1e-5, atol=1e-6)


def test_binary_quant_sign_and_scale():
    col0 = [1.0, -2.0, 3.0, -4.0, 1.0, -2.0, 3.0, -4.0]   # mean |.| = 2.5
    col1 = [-1.0] * 8                                      # mean |.| = 1.0
    w = jnp.asarray(np.stack([col0, col1], axis=1), jnp.float32)
    qt = qz.quantize(w, 1)
    back = np.asarray(qz.dequantize(qt))
    np.testing.assert_allclose(back[:, 0], np.sign(col0) * 2.5, rtol=1e-5)
    np.testing.assert_allclose(back[:, 1], [-1.0] * 8, rtol=1e-5)


# ---------------------------------------------------------------------------
# KratosSpec end-to-end (train path vs packed serving path)
# ---------------------------------------------------------------------------

SPECS = [
    kr.KratosSpec(),
    kr.KratosSpec(sparsity=0.5, bk=8, bn=8),
    kr.KratosSpec(sparsity=0.5, bk=8, bn=8, impl="systolic"),
    kr.KratosSpec(bits=8),
    kr.KratosSpec(bits=4),
    kr.KratosSpec(sparsity=0.75, bits=8, bk=8, bn=8),
    kr.KratosSpec(sparsity=0.5, bits=4, bk=8, bn=8),
    kr.KratosSpec(bits=8, act_bits=8),
]


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: f"s{s.sparsity}b{s.bits}"
                         f"{s.impl[0]}a{s.act_bits}")
def test_kratos_train_vs_packed(spec):
    """pack() + apply_packed == apply on the trained dense weight."""
    key = jax.random.PRNGKey(0)
    params = kr.init(key, 64, 32, spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    y_train = kr.apply(params, x, spec)
    packed = kr.pack(params, spec)
    y_serve = kr.apply_packed(packed, x, spec, 64, 32)
    rtol = 0.08 if spec.act_bits else 1e-4     # a8 requantizes activations
    np.testing.assert_allclose(np.asarray(y_serve), np.asarray(y_train),
                               rtol=rtol, atol=0.05)


@pytest.mark.parametrize("impl", ["tree", "systolic"])
@pytest.mark.parametrize("bits", [None, 8, 4, 2])
@pytest.mark.parametrize("sparsity", [0.0, 0.5])
def test_pack_apply_packed_roundtrip_grid(impl, bits, sparsity):
    """Full serving grid: apply_packed(pack(p)) == apply(p) within quant tol.

    The serving path re-quantizes the SAME values the QAT forward fake-
    quantizes, so dense/8/4-bit agree to float rounding; 2-bit goes through
    sub-byte two's-complement packing where the TWN threshold comparison
    (|w| > 0.7 mean|w|) can flip codes for borderline weights — element
    tolerance stays loose but quantization-scale-bounded.
    """
    spec = kr.KratosSpec(sparsity=sparsity, bits=bits, impl=impl, bk=8, bn=8)
    params = kr.init(jax.random.PRNGKey(42), 64, 32, spec)
    x = jax.random.normal(jax.random.PRNGKey(43), (8, 64))
    y_train = kr.apply(params, x, spec)
    packed = kr.pack(params, spec)
    y_serve = kr.apply_packed(packed, x, spec, 64, 32)
    # expected buffer layout: {w | qt} for dense-compute, {blocks | qblocks}
    # for the gathered-tree path
    if sparsity == 0.0 or impl == "systolic":
        assert ("w" in packed) == (bits is None)
        assert ("qt" in packed) == (bits is not None)
    else:
        assert ("blocks" in packed) == (bits is None)
        assert ("qblocks" in packed) == (bits is not None)
    atol = 0.05 if bits != 2 else 0.15
    np.testing.assert_allclose(np.asarray(y_serve), np.asarray(y_train),
                               rtol=1e-4, atol=atol)


def test_kratos_tree_equals_systolic_math():
    """Same plan: tree (gathered) and systolic (masked dense) agree exactly."""
    spec_t = kr.KratosSpec(sparsity=0.5, bk=8, bn=8, impl="tree")
    spec_s = spec_t.with_(impl="systolic")
    params = kr.init(jax.random.PRNGKey(2), 64, 48, spec_t)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 64))
    np.testing.assert_allclose(np.asarray(kr.apply(params, x, spec_t)),
                               np.asarray(kr.apply(params, x, spec_s)),
                               rtol=1e-4, atol=1e-5)


def test_kratos_sparse_init_stays_sparse_under_sgd():
    """Pruned blocks receive zero gradient through the tree path."""
    spec = kr.KratosSpec(sparsity=0.5, bk=8, bn=8)
    params = kr.init(jax.random.PRNGKey(4), 32, 32, spec)
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 32))

    def loss(p):
        return jnp.sum(kr.apply(p, x, spec) ** 2)

    g = jax.grad(loss)(params)["w"]
    plan = kr.plan_for(32, 32, spec)
    mask = sp.plan_mask(plan)
    np.testing.assert_allclose(np.asarray(g) * (1 - mask), 0.0, atol=1e-6)


def test_cost_report_linear_in_sparsity_quadratic_story():
    """C1/C2 analytics: tree MACs ∝ (1-s); systolic flat; bytes ∝ bits."""
    n = 1280
    base = kr.cost_report(n, n, kr.KratosSpec())
    half = kr.cost_report(n, n, kr.KratosSpec(sparsity=0.5))
    assert abs(half["mac_fraction"] - 0.5) < 0.06
    sysl = kr.cost_report(n, n, kr.KratosSpec(sparsity=0.5, impl="systolic"))
    assert sysl["mac_fraction"] == 1.0
    w4 = kr.cost_report(n, n, kr.KratosSpec(bits=4))
    assert abs(w4["weight_bytes_fraction"] - 0.25) < 1e-6
    w8a8 = kr.cost_report(n, n, kr.KratosSpec(bits=8, act_bits=8))
    assert w8a8["equiv_compute_time_fraction"] == 0.5
    assert base["mac_fraction"] == 1.0


# ---------------------------------------------------------------------------
# conv via im2col onto Kratos GEMM
# ---------------------------------------------------------------------------

def test_conv1d_matches_lax_conv():
    key = jax.random.PRNGKey(6)
    fw, ic, oc = 3, 8, 16
    p = KC.conv1d_init(key, fw, ic, oc)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 32, ic))
    got = KC.conv1d(p, x)
    w = p["w"].reshape(fw, ic, oc)
    want = KC.conv1d_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_conv2d_matches_lax_conv():
    key = jax.random.PRNGKey(8)
    fw, fh, ic, oc = 3, 3, 4, 8
    p = KC.conv2d_init(key, fw, fh, ic, oc)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 12, 10, ic))
    got = KC.conv2d(p, x)
    w = p["w"].reshape(fw, fh, ic, oc)
    want = KC.conv2d_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_conv2d_sparse_quantized():
    """The paper's headline combination on a conv: prune + quantize."""
    spec = kr.KratosSpec(sparsity=0.5, bits=8, bk=4, bn=4)
    key = jax.random.PRNGKey(10)
    fw, fh, ic, oc = 3, 3, 4, 8
    p = KC.conv2d_init(key, fw, fh, ic, oc, spec)
    x = jax.random.normal(jax.random.PRNGKey(11), (1, 8, 8, ic))
    got = KC.conv2d(p, x, spec)
    # oracle: dense conv on the masked+fake-quantized filter
    plan = kr.plan_for(fw * fh * ic, oc, spec)
    wm = p["w"] * jnp.asarray(sp.plan_mask(plan))
    wq = qz.fake_quantize(wm, 8)
    want = KC.conv2d_ref(x, wq.reshape(fw, fh, ic, oc))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)
