"""ServeMetrics unit coverage: percentile edges, report/aggregate schema
parity, fleet pooling discipline, monotonic interval clocks.

These are pure-python tests (no engine, no jax) — the metric layer's
contracts that serve_bench and the QoR gates build on:

  * `percentile` behaves at the edges (empty -> nan, single element,
    q=0/100 pin to min/max);
  * `aggregate()` exposes EXACTLY `report()`'s key set plus the documented
    fleet-only keys, so a bench gate that reads a key off one engine's
    report can never miss it on the fleet report;
  * fleet percentiles pool the UNION of per-request records — on a skewed
    fixture the pooled p99 provably differs from the mean of per-replica
    p99s (the wrong aggregation this test exists to forbid);
  * latency/TTFT intervals are measured on time.perf_counter(): a wall
    clock jumping BACKWARDS (NTP slew) between submit and finish must not
    produce a negative latency.
"""

import time

import pytest

from repro.serve.metrics import RequestRecord, ServeMetrics, percentile

# fleet-only keys aggregate() may expose beyond report()'s schema —
# documented in ServeMetrics.aggregate; everything else must be in parity
FLEET_ONLY_KEYS = {"n_replicas"}


# ------------------------------------------------------------- percentile

def test_percentile_empty_is_nan():
    assert percentile([], 50) != percentile([], 50)  # NaN

def test_percentile_single_element_any_q():
    for q in (0, 1, 50, 99, 100):
        assert percentile([7.5], q) == 7.5

def test_percentile_q0_q100_pin_min_max():
    xs = [5.0, 1.0, 9.0, 3.0]
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 100) == 9.0

def test_percentile_median_nearest_rank():
    assert percentile([1.0, 2.0, 3.0], 50) == 2.0
    assert percentile([4.0, 1.0], 100) == 4.0
    assert percentile([4.0, 1.0], 0) == 1.0


# ----------------------------------------------------------- test fixtures

def _metrics_with_latencies(lat_steps, start_id=0):
    """A ServeMetrics whose finished records have the given step latencies
    (arrival 0, finish at `lat`), with enough token/dispatch traffic to
    exercise every derived rate."""
    m = ServeMetrics()
    for i, lat in enumerate(lat_steps):
        rid = start_id + i
        m.on_submit(rid, 0, n_prompt=4)
        m.on_start(rid, 0)
        m.on_token(rid, 0)
        m.on_finish(rid, int(lat))
        m.records[rid].finish_step = int(lat)
        m.on_decode_step(1, 2)
        m.on_host_sync("decode")
        m.on_host_sync("prefill")
    return m


# ---------------------------------------------------------- schema parity

def test_aggregate_schema_matches_report():
    """aggregate() keys == report() keys + documented fleet-only keys.
    This is the drift this PR fixed (host_syncs_prefill and the
    tokens_per_step alias were missing from the fleet report)."""
    m1 = _metrics_with_latencies([3, 5])
    m2 = _metrics_with_latencies([4], start_id=10)
    rep = m1.report()
    agg = ServeMetrics.aggregate([m1, m2])
    assert set(agg) - set(rep) == FLEET_ONLY_KEYS
    assert set(rep) - set(agg) == set()

def test_aggregate_has_fixed_keys():
    agg = ServeMetrics.aggregate([_metrics_with_latencies([2])])
    for key in ("host_syncs_prefill", "tokens_per_step",
                "tokens_per_dispatch", "host_syncs_decode"):
        assert key in agg
    assert agg["tokens_per_step"] == agg["tokens_per_dispatch"]


# ------------------------------------------------------ pooling discipline

def test_fleet_percentile_pools_records_not_means():
    """Skewed fixture: replica A has 9 fast requests, replica B has 1 slow
    one. The fleet p99 over the pooled union is the slow request; the mean
    of per-replica p99s is far lower. aggregate() must produce the former."""
    fast = _metrics_with_latencies([1] * 9)
    slow = _metrics_with_latencies([100], start_id=50)
    pooled = ServeMetrics.aggregate([fast, slow])
    p99_fast = fast.report()["latency_steps_p99"]
    p99_slow = slow.report()["latency_steps_p99"]
    mean_of_p99s = (p99_fast + p99_slow) / 2          # 50.5 — the WRONG way
    assert pooled["latency_steps_p99"] == 100.0
    assert pooled["latency_steps_p99"] != pytest.approx(mean_of_p99s)
    # p50 of the pooled union is still a fast request
    assert pooled["latency_steps_p50"] == 1.0

def test_aggregate_counters_sum():
    a = _metrics_with_latencies([1, 2])
    b = _metrics_with_latencies([3], start_id=20)
    agg = ServeMetrics.aggregate([a, b])
    assert agg["tokens_generated"] == 3.0
    assert agg["requests_completed"] == 3.0
    assert agg["host_syncs_prefill"] == 3.0
    assert agg["n_replicas"] == 2.0


# ------------------------------------------------------- monotonic clocks

def test_latency_monotonic_under_wall_clock_jump(monkeypatch):
    """time.time() jumping BACKWARDS between submit and finish must not
    yield a negative latency: intervals are perf_counter-based."""
    m = ServeMetrics()
    walls = iter([1e9, 1e9 - 3600.0])     # submit, then a 1h backwards slew
    monkeypatch.setattr(time, "time", lambda: next(walls))
    m.on_submit(0, 0, n_prompt=2)
    m.on_start(0, 0)
    m.on_token(0, 1)
    m.on_finish(0, 2)
    rep = m.report()
    assert rep["latency_s_p50"] >= 0.0
    assert rep["latency_s_p99"] >= 0.0

def test_submit_wall_timestamp_still_wall_clock(monkeypatch):
    """The human-readable submit_time log anchor stays time.time()."""
    m = ServeMetrics()
    monkeypatch.setattr(time, "time", lambda: 1234.5)
    m.on_submit(0, 0, n_prompt=1)
    assert m.records[0].submit_time == 1234.5
    # ... while the interval baseline is a separate monotonic stamp
    assert m.records[0].submit_mono != 1234.5

def test_record_fields_document_clock_split():
    rec = RequestRecord(request_id=0, arrival_step=0)
    assert hasattr(rec, "submit_mono") and hasattr(rec, "submit_time")
