"""Speculative decode (serve.speculative): self-draft registry artifacts,
greedy token-identity vs plain decode (local + sharded), rollback across
positional and recurrent caches, per-request caps, metrics.

Sharded cases use the same subprocess isolation as test_serve_sharded.py
(jax locks the device count at first init): they run a script under
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.serve import (DraftSpec, EngineConfig, InferenceEngine,
                         ModelRegistry, ServeMetrics)

# the three cache families the rollback machinery must cover: positional
# full-attention KV, recurrent SSM state, positional compressed MLA latents
ARCHS = ["nemotron-4-340b", "falcon-mamba-7b", "minicpm3_4b"]

_REGISTRY = ModelRegistry()

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src")


def _drafted(arch, dspec=DraftSpec(bits=8)):
    return _REGISTRY.load(arch, draft_spec=dspec)


def _jobs(model, seed=11, lens=((5, 7), (9, 4), (7, 6))):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, model.cfg.vocab, s0), gen) for s0, gen in lens]


def _run(model, jobs, *, n_slots=4, max_len=32, **kw):
    eng = InferenceEngine(model, EngineConfig(n_slots=n_slots,
                                              max_len=max_len, **kw))
    reqs = [eng.submit(p, g, arrival_step=i)
            for i, (p, g) in enumerate(jobs)]
    eng.run()
    return [r.generated for r in reqs], eng


# ---------------------------------------------------------------------------
# registry: draft artifacts
# ---------------------------------------------------------------------------

def test_registry_draft_artifact_and_key_isolation():
    """A drafted artifact and its plain twin never collide: distinct cache
    keys AND distinct default names (the draft-spec fields are part of
    `_spec_tag`)."""
    plain = _REGISTRY.load(ARCHS[0])
    drafted = _REGISTRY.load(ARCHS[0], draft_spec=DraftSpec(bits=8))
    assert plain is not drafted
    assert plain.name != drafted.name
    assert "draft[" in drafted.name and "w8" in drafted.name
    assert _REGISTRY.get(plain.name) is plain
    assert _REGISTRY.get(drafted.name) is drafted
    assert drafted.has_draft and drafted.draft_packed > 0
    assert not plain.has_draft
    # different draft specs are different artifacts too
    other = _REGISTRY.load(ARCHS[0], draft_spec=DraftSpec(bits=4))
    assert other is not drafted and other.name != drafted.name
    # ... including drafts differing ONLY in block geometry
    g8 = _REGISTRY.load(ARCHS[0],
                        draft_spec=DraftSpec(bits=8, sparsity=0.5,
                                             bk=8, bn=8))
    g16 = _REGISTRY.load(ARCHS[0],
                         draft_spec=DraftSpec(bits=8, sparsity=0.5,
                                              bk=16, bn=16))
    assert g8 is not g16 and g8.name != g16.name


def test_draft_truncation_and_cost_fraction():
    m = _REGISTRY.load(ARCHS[0], draft_spec=DraftSpec(bits=8, keep_layers=2))
    assert m.draft_cfg.n_layers == 2 and m.cfg.n_layers == 4
    assert 0.0 < m.draft_cost_fraction() < 1.0
    stack = m.draft_params["blocks"][0]
    import jax
    assert all(l.shape[0] == 2 for l in jax.tree_util.tree_leaves(stack))
    with pytest.raises(ValueError):
        DraftSpec(keep_layers=0)
    with pytest.raises(ValueError):          # must keep whole scan periods
        _REGISTRY.load(ARCHS[0], draft_spec=DraftSpec(keep_layers=99))


def test_speculate_validation():
    drafted = _drafted(ARCHS[0])
    plain = _REGISTRY.load(ARCHS[0])
    with pytest.raises(ValueError):          # no draft artifact
        InferenceEngine(plain, EngineConfig(speculate=2))
    with pytest.raises(ValueError):          # speculate replaces chunking
        InferenceEngine(drafted, EngineConfig(speculate=2, decode_chunk=2))
    with pytest.raises(ValueError):          # host loop can't speculate
        InferenceEngine(drafted, EngineConfig(speculate=2,
                                              device_loop=False))
    # circular sliding-window caches cannot roll back
    swa = _REGISTRY.load("h2o-danube-1.8b", draft_spec=DraftSpec(bits=8))
    with pytest.raises(ValueError, match="window"):
        InferenceEngine(swa, EngineConfig(n_slots=2, max_len=32, speculate=2))


# ---------------------------------------------------------------------------
# greedy token-identity (the speculative-decode contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_speculative_greedy_identity_local(arch):
    """Greedy speculative decode is token-identical to plain decode for
    every cache family and K in {1, 2, 4} — correctness never depends on
    the draft."""
    m = _drafted(arch)
    jobs = _jobs(m)
    plain, _ = _run(m, jobs)
    for k in (1, 2, 4):
        spec, eng = _run(m, jobs, speculate=k)
        assert spec == plain, (arch, k)
        rep = eng.metrics.report()
        assert rep["spec_dispatches"] > 0
        assert 0.0 <= rep["acceptance_rate"] <= 1.0


def test_speculative_identity_under_a_bad_draft():
    """A draft that almost always disagrees (layer-truncated on random
    weights) forces rollback on nearly every cycle — output must STILL be
    token-identical, just slower."""
    m = _REGISTRY.load(ARCHS[0], draft_spec=DraftSpec(bits=8, keep_layers=2))
    jobs = _jobs(m, seed=3, lens=((5, 12), (9, 8), (7, 10)))
    plain, _ = _run(m, jobs, max_len=48)
    spec, eng = _run(m, jobs, max_len=48, speculate=4)
    assert spec == plain
    rep = eng.metrics.report()
    assert rep["draft_rolled_back"] > 0      # rejections actually happened
    assert rep["acceptance_rate"] < 0.5


def test_speculative_eos_truncates_commit_on_device():
    m = _drafted(ARCHS[0])
    prompt = np.arange(6) % m.cfg.vocab
    free, _ = _run(m, [(prompt, 8)], n_slots=2)
    eos = free[0][2]                         # forces a stop mid-commit
    expect = free[0][:free[0].index(eos) + 1]

    def run_eos(**kw):
        eng = InferenceEngine(m, EngineConfig(n_slots=2, max_len=32, **kw))
        r = eng.submit(prompt, 8, eos_id=eos)
        eng.run()
        return r.generated, eng

    pe, _ = run_eos()
    se, eng = run_eos(speculate=4)
    assert pe == se == expect
    assert eng.requests[0].done and eng.pool.n_free == 2


def test_per_request_speculate_cap_and_opt_out():
    """Request.speculate caps (or disables) drafting per slot on a
    speculating engine without changing greedy output."""
    m = _drafted(ARCHS[0])
    jobs = _jobs(m)
    plain, _ = _run(m, jobs)

    eng = InferenceEngine(m, EngineConfig(n_slots=4, max_len=32, speculate=4))
    reqs = [eng.submit(p, g, arrival_step=i,
                       speculate=(0 if i == 0 else 1 if i == 1 else None))
            for i, (p, g) in enumerate(jobs)]
    eng.run()
    assert [r.generated for r in reqs] == plain
    # the proposed-token denominators respect per-slot caps: the opt-out
    # slot proposes nothing, the capped slot proposes 1/dispatch — with a
    # near-lossless w8 draft the pooled acceptance stays high instead of
    # being diluted by phantom k-token proposals
    rep = eng.metrics.report()
    assert rep["acceptance_rate"] > 0.8
    assert all(prop <= rep["draft_proposed"]
               for _, prop in eng.metrics.slot_acceptance.values())


def test_speculative_sampling_reproducible_and_seeded():
    """temperature>0: rejection-sampled output is reproducible for a fixed
    seed and moves with it (the rng key threads through draft + verify)."""
    m = _drafted(ARCHS[0])
    prompt = np.arange(5) % m.cfg.vocab

    def run_t(seed):
        eng = InferenceEngine(m, EngineConfig(n_slots=2, max_len=48,
                                              seed=seed, speculate=4))
        r = eng.submit(prompt, 9, temperature=1.0)
        eng.run()
        return r.generated

    a, b, c = run_t(7), run_t(7), run_t(8)
    assert a == b and len(a) == 9
    assert a != c


# ---------------------------------------------------------------------------
# metrics + donation
# ---------------------------------------------------------------------------

def test_spec_metrics_in_report_and_aggregate():
    m = _drafted(ARCHS[0])
    _, eng = _run(m, _jobs(m), speculate=4)
    rep = eng.metrics.report()
    for key in ("acceptance_rate", "draft_rolled_back", "draft_proposed",
                "draft_accepted", "spec_dispatches", "tokens_per_dispatch",
                "draft_verify_flop_ratio"):
        assert key in rep
    assert rep["draft_proposed"] > 0
    assert rep["tokens_per_dispatch"] > 1.0  # speculation amortized
    # per-slot acceptance is tracked for the example / tuning loop
    assert eng.metrics.slot_acceptance
    agg = ServeMetrics.aggregate([eng.metrics, ServeMetrics()])
    assert agg["draft_proposed"] == rep["draft_proposed"]
    assert agg["acceptance_rate"] == pytest.approx(rep["acceptance_rate"])
    assert agg["draft_rolled_back"] == rep["draft_rolled_back"]
    assert agg["spec_dispatches"] == rep["spec_dispatches"]


def test_spec_step_and_draft_slab_donate_buffers():
    """The propose-then-verify dispatch donates (target slab, draft slab,
    state): the lowered module carries input->output aliasing for all
    three, and the draft slot install donates like the target's."""
    m = _drafted(ARCHS[0])
    eng = InferenceEngine(m, EngineConfig(n_slots=2, max_len=24, speculate=2))
    bk = eng.backend
    txt = bk._spec_decode.lower(bk.params, bk.draft_params, eng.pool.caches,
                                bk.draft_pool.caches, bk.state).as_text()
    assert "tf.aliasing_output" in txt or "jax.buffer_donor" in txt
    import jax.numpy as jnp
    txt_w = bk.draft_pool._write.lower(
        bk.draft_pool.caches, bk.draft_pool.single_template,
        jnp.asarray(0, jnp.int32)).as_text()
    assert "tf.aliasing_output" in txt_w or "jax.buffer_donor" in txt_w


# ---------------------------------------------------------------------------
# sharded: 8 forced CPU devices (subprocess isolation)
# ---------------------------------------------------------------------------

SHARDED_SCRIPT = """
    import numpy as np
    from repro.serve import (DraftSpec, EngineConfig, InferenceEngine,
                             ModelRegistry, ShardedBackend)
    arch = {arch!r}
    reg = ModelRegistry()
    m = reg.load(arch, draft_spec=DraftSpec(bits=8))
    rng = np.random.default_rng(11)
    jobs = [(rng.integers(0, m.cfg.vocab, s0), gen)
            for s0, gen in [(5, 6), (9, 4), (7, 5)]]
    def run(backend=None, k=0):
        eng = InferenceEngine(
            m, EngineConfig(n_slots=4, max_len=32, speculate=k),
            backend=backend)
        rs = [eng.submit(p, g, arrival_step=i)
              for i, (p, g) in enumerate(jobs)]
        eng.run()
        return [r.generated for r in rs], eng
    plain, _ = run()
    for k in (1, 2, 4):
        sharded, eng = run(backend=ShardedBackend(mesh_shape=(4, 2)), k=k)
        assert sharded == plain, (k, plain, sharded)
    d = eng.backend.describe()
    assert d["mesh_shape"] == [4, 2]
    # donation aliasing of the sharded spec step (slab + draft slab + state)
    bk = eng.backend
    txt = bk._spec_decode.lower(
        bk.params, bk.draft_params, eng.pool.caches,
        bk.draft_pool.caches, bk.state).as_text()
    assert "tf.aliasing_output" in txt or "jax.buffer_donor" in txt
    # draft params are REPLICATED on the mesh
    import jax
    for leaf in jax.tree_util.tree_leaves(bk.draft_params):
        spec = leaf.sharding.spec
        assert all(ax is None for ax in spec), spec
    print(arch, "sharded speculative identity OK")
"""


def run_script(body: str, timeout=420) -> str:
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, timeout=timeout,
                       env=ENV, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


@pytest.mark.parametrize("arch", ARCHS)
def test_sharded_speculative_greedy_identity(arch):
    """Greedy speculative decode through ShardedBackend on a (data=4,
    model=2) mesh is token-identical to plain local decode for K in
    {1, 2, 4}, with draft params replicated and donation aliasing intact."""
    run_script(SHARDED_SCRIPT.format(arch=arch))
