"""analysis.hlo on the serving steps: loop-aware scan-multiplier accounting.

The analyzer's reason to exist is that `lax.scan` bodies must be
multiplied by their trip count; the serving hot path is where that
matters most — the K-micro-step decode dispatch lowers as a scan of the
full forward, and the speculative dispatch nests the draft's micro-scan
inside it. These tests gate the accounting against the steps the engine
actually compiles, not synthetic while-loops.
"""

import numpy as np
import pytest

from repro.analysis import hlo as HA
from repro.core import kratos as kr
from repro.serve import (DraftSpec, EngineConfig, InferenceEngine,
                         ModelRegistry)

ARCH = "nemotron-4-340b"     # full attention: speculative-safe
_REGISTRY = ModelRegistry()


def _decode_flops(model, decode_chunk: int, speculate: int = 0):
    eng = InferenceEngine(model, EngineConfig(
        n_slots=2, max_len=32, decode_chunk=decode_chunk,
        speculate=speculate))
    bk = eng.backend
    if speculate:
        lowered = bk._spec_decode.lower(bk.params, bk.draft_params,
                                        eng.pool.caches,
                                        bk.draft_pool.caches, bk.state)
    else:
        lowered = bk._decode.lower(bk.params, eng.pool.caches, bk.state)
    return HA.analyze(lowered.compile().as_text())


def test_decode_chunk_scan_multiplies_flops():
    """The K-micro-step dispatch is one lax.scan over the full forward:
    the analyzer must multiply the body by the trip count, so FLOPs scale
    ~linearly in K (fixed dispatch overhead allows slack below, not
    above: an un-multiplied body would read as ~1/K)."""
    model = _REGISTRY.load(ARCH)
    f1 = _decode_flops(model, 1)["flops"]
    f4 = _decode_flops(model, 4)["flops"]
    ratio = f4 / f1
    assert 3.0 <= ratio <= 4.6, f"K=4 / K=1 flops ratio {ratio:.2f}"


def test_decode_chunk_scan_multiplies_bytes():
    model = _REGISTRY.load(ARCH)
    b1 = _decode_flops(model, 1)["bytes"]
    b4 = _decode_flops(model, 4)["bytes"]
    assert b4 / b1 >= 2.5, f"K=4 / K=1 bytes ratio {b4 / b1:.2f}"


def test_spec_step_scan_accounts_draft_micro_steps():
    """The speculative dispatch runs K draft micro-steps + a (K+1)-token
    verify: measured FLOPs must scale with K like the analytic model
    K * draft + (K+1) * target predicts (the draft here is the SAME
    weights at bits=8, so draft flops == target flops)."""
    model = _REGISTRY.load(ARCH, draft_spec=DraftSpec(bits=8))
    f_plain = _decode_flops(model, 1)["flops"]
    f2 = _decode_flops(model, 1, speculate=2)["flops"]
    f4 = _decode_flops(model, 1, speculate=4)["flops"]
    df = model.draft_cost_fraction()
    pred = {k: (k * df + (k + 1)) * f_plain for k in (2, 4)}
    for k, f in ((2, f2), (4, f4)):
        rel = f / pred[k]
        assert 0.7 <= rel <= 1.35, \
            f"spec K={k}: measured {f:.3g} vs predicted {pred[k]:.3g} " \
            f"({rel:.2f}x)"
    # and the K-scaling itself: going 2 -> 4 adds ~2 draft + ~2 verify
    # forwards, so the increment ratio must track the analytic slope
    slope = (f4 - f2) / f_plain
    pred_slope = 2 * df + 2
    assert abs(slope - pred_slope) / pred_slope < 0.35, \
        f"spec slope {slope:.2f} vs {pred_slope:.2f}"


def test_draft_vs_target_flops_match_cost_fraction():
    """`draft_cost_fraction` is the engine's analytic draft/target ratio
    (the ledger accounts the draft's cost with it rather than probing
    draft forwards). Gate it against MEASURED HLO FLOPs: the same arch
    packed at the draft's sparsity point, full decode step each, must
    show a FLOP ratio that tracks the analytic fraction. The analytic
    model discounts ALL active params by (1 - sparsity) while only the
    packed projections actually thin out, so the measured ratio sits at
    or above the analytic one — never more than the dense 1.0."""
    target = _REGISTRY.load(ARCH)
    draft_spec = kr.KratosSpec(sparsity=0.5, impl="tree", bk=8, bn=8)
    draft_like = _REGISTRY.load(ARCH, draft_spec)
    f_t = _decode_flops(target, 1)["flops"]
    f_d = _decode_flops(draft_like, 1)["flops"]
    measured = f_d / f_t

    model = _REGISTRY.load(ARCH, draft_spec=DraftSpec.from_args(0, 0.5, 0))
    analytic = model.draft_cost_fraction()
    assert analytic == pytest.approx(0.5, abs=0.01)
    assert analytic - 0.05 <= measured <= 1.0, \
        f"measured draft/target flops {measured:.3f} vs analytic {analytic:.3f}"
    assert measured == pytest.approx(analytic, abs=0.25)


def test_spec_hlo_has_counted_trip_loops():
    """The analyzer should not be guessing: the lowered spec step's scan
    loops carry known_trip_count, so no unknown-trip warnings fire."""
    model = _REGISTRY.load(ARCH, draft_spec=DraftSpec(bits=8))
    r = _decode_flops(model, 1, speculate=3)
    unknown = [w for w in r["warnings"] if "unknown trip count" in w]
    assert unknown == [], unknown
