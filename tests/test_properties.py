"""Seeded property grids over system invariants (hypothesis-style, no
external deps): sharding-spec sanity, attention masking laws, quantization
monotonicity, plan-balance across the whole Table-II space."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import bench_specs as BS
from repro.core import kratos as kr
from repro.core import quantize as qz
from repro.core import sparsity as sp
from repro.models import attention as A


def test_every_table2_sweep_point_has_consistent_analytics():
    """All 800 design points: effective MACs <= dense, fraction in (0,1],
    bytes consistent with bits, systolic always full-MACs."""
    for base in BS.TABLE_II:
        for spec in BS.sweep(base):
            r = spec.resource_report()
            assert 0 < r["mac_fraction"] <= 1.0 + 1e-9, spec
            assert r["effective_macs"] <= r["dense_macs"] + 1e-9
            bits = spec.bits or 16
            m, n, p = spec.gemm_dims()
            dense_bytes = n * p * bits / 8.0
            assert r["weight_bytes"] <= dense_bytes + 1e-6, spec
            if spec.kernel == "gemms":
                assert r["mac_fraction"] == 1.0, "systolic must not prune"
            elif spec.sparsity >= 0.5:
                assert r["mac_fraction"] <= 0.6, spec


def test_balanced_plans_are_balanced_everywhere():
    rng = np.random.default_rng(7)
    for _ in range(12):
        bk = int(rng.choice([8, 16, 32]))
        bn = int(rng.choice([8, 16, 32]))
        n_in = bk * int(rng.integers(2, 12))
        n_out = bn * int(rng.integers(2, 12))
        s = float(rng.uniform(0, 0.95))
        plan = sp.make_plan(n_in, n_out, bk=bk, bn=bn, sparsity=s,
                            seed=int(rng.integers(0, 1000)))
        # every output block keeps exactly nnz k-blocks (static grid)
        assert plan.indices.shape == (plan.n_pb, plan.nnz)
        assert (plan.indices >= 0).all() and (plan.indices < plan.n_kb).all()
        for j in range(plan.n_pb):
            assert len(set(plan.indices[j].tolist())) == plan.nnz


def test_quant_error_monotone_in_bits():
    w = jnp.asarray(np.random.default_rng(8).normal(size=(64, 32)),
                    jnp.float32)
    errs = []
    for bits in (8, 4, 2, 1):
        back = qz.dequantize(qz.quantize(w, bits))
        errs.append(float(jnp.mean(jnp.abs(back - w))))
    assert errs == sorted(errs), f"error must grow as bits shrink: {errs}"


def test_attention_window_subset_law():
    """window=inf == plain causal; smaller windows only remove attention."""
    b, h, s, d = 1, 2, 24, 8
    q, k, v = (jax.random.normal(jax.random.PRNGKey(i), (b, h, s, d))
               for i in (30, 31, 32))
    pos = jnp.arange(s)
    full = A.attention_positional(q, k, v, pos, pos, causal=True, window=s)
    plain = A.attention_positional(q, k, v, pos, pos, causal=True)
    np.testing.assert_allclose(np.asarray(full), np.asarray(plain),
                               rtol=1e-5, atol=1e-6)
    w1 = A.attention_positional(q, k, v, pos, pos, causal=True, window=1)
    # window=1: each position attends only to itself => output = v row-wise
    np.testing.assert_allclose(np.asarray(w1), np.asarray(v),
                               rtol=1e-4, atol=1e-5)


def test_softcap_bounds_logits_effect():
    """softcap -> attention scores bounded => output changes continuously."""
    b, h, s, d = 1, 1, 16, 8
    q = jax.random.normal(jax.random.PRNGKey(33), (b, h, s, d)) * 10
    k = jax.random.normal(jax.random.PRNGKey(34), (b, h, s, d)) * 10
    v = jax.random.normal(jax.random.PRNGKey(35), (b, h, s, d))
    pos = jnp.arange(s)
    big = A.attention_positional(q, k, v, pos, pos, causal=True,
                                 softcap=1e9)
    none = A.attention_positional(q, k, v, pos, pos, causal=True)
    np.testing.assert_allclose(np.asarray(big), np.asarray(none),
                               rtol=1e-4, atol=1e-5)
    capped = A.attention_positional(q, k, v, pos, pos, causal=True,
                                    softcap=1.0)
    assert np.isfinite(np.asarray(capped)).all()


def test_kratos_identity_spec_is_exact_dense():
    params = kr.init(jax.random.PRNGKey(36), 32, 16, kr.DENSE)
    x = jax.random.normal(jax.random.PRNGKey(37), (4, 32))
    y = kr.apply(params, x, kr.DENSE)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(x @ params["w"]),
                               rtol=1e-5, atol=1e-5)
    assert kr.DENSE.is_identity


def test_pack_apply_roundtrip_under_sharded_context_is_pure():
    """plan_for is a pure cached function of (shape, spec): calling it from
    two sites yields the identical object (trace-stability invariant)."""
    spec = kr.KratosSpec(sparsity=0.5, bk=8, bn=8, seed=3)
    p1 = kr.plan_for(64, 32, spec)
    p2 = kr.plan_for(64, 32, spec)
    assert p1 is p2
    assert p1 is not kr.plan_for(64, 32, dataclasses.replace(spec, seed=4))
