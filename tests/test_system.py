"""End-to-end system behaviour: learning, serving, optimizer, benchmarks."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.core import bench_specs as BS
from repro.data.pipeline import DataConfig
from repro.optim import adamw as O
from repro.train import TrainLoopConfig, run_training


def test_training_learns_markov_task():
    cfg = C.get_smoke("h2o_danube_1_8b")
    out = run_training(
        cfg, O.OptimizerConfig(lr=2e-3, warmup_steps=10, total_steps=60),
        DataConfig(vocab=cfg.vocab, batch=8, seq=32, seed=1),
        TrainLoopConfig(steps=60, log_every=0))
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])


def test_grad_accum_equivalent_gradients():
    from repro.distributed import steps as ST
    cfg = C.get_smoke("h2o_danube_1_8b")
    opt = O.OptimizerConfig(lr=0.0, weight_decay=0.0, clip_norm=None)
    state = ST.init_train_state(jax.random.PRNGKey(0), cfg, opt)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)),
                                   jnp.int32)}
    _, m1 = jax.jit(ST.make_train_step(cfg, opt, grad_accum=1))(state, batch)
    _, m4 = jax.jit(ST.make_train_step(cfg, opt, grad_accum=4))(state, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    rel = abs(float(m1["grad_norm"]) - float(m4["grad_norm"])) \
        / float(m1["grad_norm"])
    assert rel < 1e-3


def test_adamw_converges_on_quadratic():
    opt_cfg = O.OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=200,
                                weight_decay=0.0, clip_norm=None,
                                min_lr_ratio=1.0)
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = O.adamw_init(params, opt_cfg)
    target = jnp.asarray([1.0, 2.0, 3.0])

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda q: jnp.sum((q["w"] - target) ** 2))(p)
        return O.adamw_update(g, s, p, opt_cfg)[:2]

    for _ in range(200):
        params, state = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_schedule_and_clip():
    cfg = O.OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(O.warmup_cosine(cfg, jnp.int32(s))) for s in (0, 5, 10, 100)]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] == pytest.approx(1e-4, rel=1e-2)     # min_lr_ratio * lr
    g = {"w": jnp.full((4,), 10.0)}
    clipped, gn = O.clip_by_global_norm(g, 1.0)
    assert gn == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["w"])) == pytest.approx(1.0, rel=1e-4)


def test_bench_specs_table2_grid():
    assert len(BS.TABLE_II) == 16                      # 8 kernels x S/L
    assert len(BS.SPARSITIES) == 10 and len(BS.PRECISIONS) == 4
    swept = BS.sweep(BS.BY_NAME["gemmt-RP-S"])
    assert len(swept) == 10 * 5                        # + bf16 baseline row
    for spec in BS.TABLE_II:
        m, n, p = spec.gemm_dims()
        assert m > 0 and n > 0 and p > 0
        assert spec.ops_per_invocation() <= m * n * p
        r = spec.resource_report()
        assert r["mac_fraction"] == 1.0                # base grid is dense


def test_bench_kernel_instantiations_execute():
    import dataclasses
    for name in ("gemmt-RP-S", "gemms-RP-S", "conv1d-FU-S", "conv2d-RP-S"):
        spec = dataclasses.replace(BS.BY_NAME[name], sparsity=0.5)
        params, x, fn = BS.instantiate(spec)
        y = jax.jit(fn)(params, x)
        assert np.isfinite(np.asarray(y)).all(), name


def test_frontend_stubs_shapes():
    from repro.models import frontends as F
    wav = np.random.default_rng(0).standard_normal((2, 48000)).astype(np.float32)
    frames = F.whisper_frames(wav, d_model=64)
    assert frames.shape == (2, 1500, 64)
    img = np.random.default_rng(1).random((2, 336, 336, 3)).astype(np.float32)
    patches = F.llava_patches(img, d_model=64)
    assert patches.shape == (2, 2880, 64)
    # determinism (fixed projections)
    np.testing.assert_array_equal(np.asarray(F.llava_patches(img, 64)),
                                  np.asarray(patches))
