"""Fault tolerance: crash/resume bitwise-equivalence, atomic checkpoints,
deterministic data, failure injection."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, make_pipeline
from repro.optim import adamw as O
from repro.train import SimulatedFailure, TrainLoopConfig, run_training


def _setup(tmp_path=None, steps=9, fail_at=None, ckpt_every=3):
    cfg = C.get_smoke("h2o_danube_1_8b")
    opt = O.OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    data = DataConfig(vocab=cfg.vocab, batch=2, seq=16, seed=5)
    loop = TrainLoopConfig(
        steps=steps, ckpt_dir=str(tmp_path) if tmp_path else None,
        ckpt_every=ckpt_every, log_every=0, async_checkpoint=False,
        fail_at_step=fail_at)
    return cfg, opt, data, loop


def test_data_pipeline_deterministic_and_step_indexable():
    d = DataConfig(vocab=100, batch=4, seq=8, seed=1)
    p1, p2 = make_pipeline(d), make_pipeline(d)
    for step in (0, 7, 123456):
        b1, b2 = p1.batch(step), p2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])
    # different steps -> different batches
    assert not np.array_equal(p1.batch(0)["tokens"], p1.batch(1)["tokens"])
    # markov structure: labels are mostly succ(tokens)
    pl = make_pipeline(DataConfig(vocab=100, batch=16, seq=128, seed=1))
    succ = pl._succ
    b = pl.batch(3)
    frac = np.mean(b["labels"] == succ[b["tokens"]])
    assert 0.82 < frac < 0.98   # noise = 0.1


def test_crash_resume_bitwise_equals_uninterrupted(tmp_path):
    """THE fault-tolerance invariant: fail at step 5, resume, final params
    match a never-failed run bit-for-bit."""
    # uninterrupted reference
    cfg, opt, data, loop = _setup(None, steps=9)
    ref = run_training(cfg, opt, data, loop)

    # crashed-and-resumed run
    ck = tmp_path / "ck"
    cfg, opt, data, loop = _setup(ck, steps=9, fail_at=5, ckpt_every=3)
    with pytest.raises(SimulatedFailure):
        run_training(cfg, opt, data, loop)
    mgr = CheckpointManager(str(ck))
    assert mgr.latest_step() == 3          # crashed between ckpt 3 and 6

    cfg, opt, data, loop = _setup(ck, steps=9)   # no injection this time
    out = run_training(cfg, opt, data, loop)
    assert out["resumed_from"] == 3

    for a, b in zip(jax.tree_util.tree_leaves(ref["state"]["params"]),
                    jax.tree_util.tree_leaves(out["state"]["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # optimizer state too
    for a, b in zip(jax.tree_util.tree_leaves(ref["state"]["opt"]),
                    jax.tree_util.tree_leaves(out["state"]["opt"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_orphan_tmp_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(4.0), "b": {"c": jnp.ones((2, 2))}}
    mgr.save(1, tree)
    # simulate a crashed writer: orphan tmp dir with partial content
    os.makedirs(tmp_path / "tmp-99")
    (tmp_path / "tmp-99" / "arrays.npz").write_bytes(b"garbage")
    # and a step dir without manifest (partially renamed is impossible, but
    # a manifest-less dir must not be treated as a checkpoint)
    os.makedirs(tmp_path / "step-50")
    assert mgr.latest_step() == 1
    restored, step = mgr.restore(tree)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    steps = sorted(int(n.split("-")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step-"))
    assert steps == [3, 4]


def test_async_checkpoint_equivalent(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "a"))
    tree = {"x": jnp.arange(10.0)}
    mgr.save(7, tree, blocking=False)
    mgr.wait()
    restored, step = mgr.restore(tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["x"]),
                                  np.asarray(tree["x"]))


def test_restore_applies_target_shardings(tmp_path):
    """Elastic re-mesh on one device: restore with explicit shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(1, 1)
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.ones((8, 8))}
    mgr.save(1, tree)
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    restored, _ = mgr.restore(tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]


def test_grad_compression_run_converges(tmp_path):
    """int8 EF compression: training still learns (markov loss drops)."""
    from repro.distributed.compression import ef_int8_compress
    cfg, opt, data, loop = _setup(None, steps=30)
    out_c = run_training(cfg, opt, data, loop, compress_fn=ef_int8_compress)
    losses = [h["loss"] for h in out_c["history"]]
    assert losses[-1] < losses[0] - 0.3   # real learning under compression
    assert "comp" in out_c["state"]       # EF residual state rode along
