"""Fleet serving integration (PR 10): LocalProcess fleets must be
token-identical to a single engine — including across snapshot delay and
mid-stream process death — and DistributedBackend must be placement-only
(identical tokens to the single-process backends it generalizes).

Subprocess fleets (launch.fleet spawn path) are exercised by the CI
serve-fleet job's smoke + bench gates; everything here is in-process and
deterministic on the step clock."""

import numpy as np
import pytest

from repro.serve import (DistributedBackend, EngineConfig, FleetConfig,
                         FleetRouter, InferenceEngine, LocalProcess,
                         ModelRegistry, ReplicaRouter, ServeMetrics)
from repro.serve.telemetry import TelemetryRegistry
from repro.launch import mesh as M

ARCH = "h2o-danube-1.8b"
_REGISTRY = ModelRegistry()


def _model():
    return _REGISTRY.load(ARCH)


def _prompts(model, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, model.cfg.vocab, int(rng.integers(4, 9)))
            for _ in range(n)]


def _ecfg(**kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("max_waiting", 16)
    return EngineConfig(**kw)


def _reference(model, prompts, gen):
    eng = InferenceEngine(model, _ecfg())
    reqs = [eng.submit(p, gen) for p in prompts]
    eng.run()
    return [list(r.generated) for r in reqs]


def _local_fleet(model, n_processes, fcfg=None, delay=0):
    fcfg = fcfg or FleetConfig(heartbeat_every=1, staleness=8.0,
                               heartbeat_timeout=25.0)
    procs = [LocalProcess(ReplicaRouter.build(model, _ecfg(), 1),
                          process_index=i, cfg=fcfg, delay=delay)
             for i in range(n_processes)]
    return FleetRouter(procs, cfg=fcfg)


# ------------------------------------------------------------ token identity

def test_two_process_fleet_token_identical_to_single_engine():
    model = _model()
    prompts, gen = _prompts(model, 6), 6
    ref = _reference(model, prompts, gen)
    fleet = _local_fleet(model, 2)
    reqs = [fleet.submit(p, gen) for p in prompts]
    fleet.run()
    fleet.stop()
    assert [list(r.tokens) for r in reqs] == ref
    # and the work actually spread: both processes served something
    assert len({r.process for r in reqs}) == 2
    rep = fleet.report()
    assert rep["n_processes"] == 2.0
    assert rep["fleet_requests_completed"] == 6.0
    assert rep["fleet_tokens"] == float(sum(len(t) for t in ref))
    assert rep["fleet_steps"] > 0
    assert rep["tokens_per_fleet_step"] > 0
    assert rep["fleet_failovers"] == 0.0


def test_delayed_snapshots_fleet_still_token_identical():
    """Satellite (b) at integration level: with every control message
    lagged 2 pumps, placement decisions run on stale snapshots + credits
    — tokens must not change, and admission must not collapse onto one
    process."""
    model = _model()
    prompts, gen = _prompts(model, 6, seed=1), 6
    ref = _reference(model, prompts, gen)
    fleet = _local_fleet(model, 2, delay=2)
    reqs = [fleet.submit(p, gen) for p in prompts]
    fleet.run()
    fleet.stop()
    assert [list(r.tokens) for r in reqs] == ref
    assert len({r.process for r in reqs}) == 2


# ------------------------------------------------------------------ failover

def test_process_death_fails_over_token_identical():
    """Kill one process mid-generation: silence crosses the heartbeat
    horizon, its unfinished requests re-prefill (prompt + accumulated
    progress deltas) on the survivor, and greedy decode makes the final
    streams token-identical to a single engine. Late messages from the
    corpse are counted ignored, never folded in."""
    model = _model()
    prompts, gen = _prompts(model, 6, seed=2), 12
    ref = _reference(model, prompts, gen)
    fcfg = FleetConfig(heartbeat_every=1, staleness=4.0,
                       heartbeat_timeout=6.0)
    fleet = _local_fleet(model, 2, fcfg=fcfg)
    reqs = [fleet.submit(p, gen) for p in prompts]
    victim = None
    for _ in range(200):
        fleet.step()
        mid = [r.process for r in reqs
               if r.process >= 0 and not r.finished and r.tokens]
        if mid and len({r.process for r in reqs if r.process >= 0}) == 2:
            victim = max(mid)
            break
    assert victim is not None, "fleet never reached mid-generation state"
    fleet.processes[victim].kill()
    fleet.run()
    fleet.stop()
    assert [list(r.tokens) for r in reqs] == ref
    rep = fleet.report()
    assert rep["fleet_failovers"] >= 1
    assert rep["processes_dead"] == 1.0
    assert victim in fleet.state.dead
    # resurrection: a zombie status from the dead index is dropped+counted
    from repro.serve.control import ProcessStatus
    zombie = ProcessStatus(process_index=victim, seq=10_000, step=0,
                           replica_loads=[0], n_free_slots=4, n_waiting=0,
                           page_occupancy=0.0, qos_tier=0, submits_seen=0,
                           progress={str(reqs[0].rid): [1, 2, 3]})
    before = [list(r.tokens) for r in reqs]
    fleet._handle(victim, zombie.to_wire())
    assert [list(r.tokens) for r in reqs] == before
    assert fleet.state.resurrections_ignored >= 1


# ------------------------------------------------- distributed backend/mesh

def test_distributed_backend_token_identical_single_process():
    """DistributedBackend is placement-only: on one process with no
    coordinator it is ShardedBackend over process_meshes of the local
    devices — tokens must match the default backend exactly."""
    model = _model()
    prompts, gen = _prompts(model, 3, seed=3), 6
    ref = _reference(model, prompts, gen)
    eng = InferenceEngine(model, _ecfg(),
                          backend=DistributedBackend(mesh_shape=(1, 1)))
    reqs = [eng.submit(p, gen) for p in prompts]
    eng.run()
    assert [list(r.generated) for r in reqs] == ref


def test_process_meshes_matches_replica_meshes_degenerate():
    import jax
    pm = M.process_meshes(1, 1, 1)
    rm = M.replica_meshes(1, 1, 1)
    assert len(pm) == len(rm) == 1
    assert pm[0].devices.ravel().tolist() == [jax.local_devices()[0]]
    assert pm[0].axis_names == rm[0].axis_names == ("data", "model")


def test_plan_fleet_topology_validates_and_describes():
    plan = M.plan_fleet_topology(2, 2, data=2, model=1, n_replicas=2)
    assert plan["num_processes"] == 2
    assert plan["global_device_count"] == 4
    assert len(plan["processes"]) == 2
    p0 = plan["processes"][0]
    assert len(p0["local_devices"]) == 2
    assert len(p0["replica_meshes"]) == 2
    assert p0["replica_meshes"][0]["shape"] == {"data": 1, "model": 1}
    with pytest.raises(ValueError, match="does not divide"):
        M.plan_fleet_topology(2, 4, data=2, model=1, n_replicas=3)
    with pytest.raises(ValueError):
        M.plan_fleet_topology(0, 1, data=1, model=1, n_replicas=1)
    with pytest.raises(ValueError):    # 1 device cannot host a 2x1 mesh
        M.plan_fleet_topology(2, 1, data=2, model=1, n_replicas=1)


# ---------------------------------------------------- fleet-pooled metrics

def test_metrics_payload_roundtrip_and_aggregate():
    model = _model()
    eng = InferenceEngine(model, _ecfg())
    for p in _prompts(model, 3, seed=4):
        eng.submit(p, 4)
    eng.run()
    back = ServeMetrics.from_payload(eng.metrics.to_payload())
    a, b = eng.metrics.report(), back.report()
    for k in ("tokens_generated", "requests_completed", "decode_steps"):
        assert a[k] == b[k], k
    agg = ServeMetrics.aggregate([eng.metrics, back])
    assert agg["tokens_generated"] == 2 * a["tokens_generated"]


def test_telemetry_process_index_label():
    def fill(reg):
        reg.counter("tokens").inc(5)
        reg.gauge("occupancy").set(0.5)
        reg.histogram("latency", buckets=(1.0, 2.0)).observe(1.5)
        return reg.render_prometheus()

    plain = fill(TelemetryRegistry(prefix="serve"))
    assert "process_index" not in plain          # byte-identical w/o fleet
    assert "serve_tokens 5" in plain
    labeled = fill(TelemetryRegistry(prefix="serve", process_index=3))
    assert 'serve_tokens{process_index="3"} 5' in labeled
    assert 'process_index="3",le="1"' in labeled
    assert 'serve_latency_count{process_index="3"}' in labeled
    # same metric set, only the label differs
    assert len(plain.splitlines()) == len(labeled.splitlines())
