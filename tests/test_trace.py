"""serve.trace + serve.telemetry: zero-cost disabled path, span/metrics
reconciliation, Chrome + JSONL exports, ring buffer, page events, live
telemetry registry + Prometheus endpoint."""

import gc
import json
import sys
import urllib.request

import numpy as np
import pytest

from repro.serve import (EngineConfig, InferenceEngine, ModelRegistry,
                         NULL_TRACER, ReplicaRouter, TelemetryConfig,
                         TelemetryExporter, TelemetryRegistry, TraceConfig,
                         Tracer, engine_sample, export_chrome, export_jsonl,
                         router_sample)
from repro.serve.trace import NullTracer, chrome_events

ARCH = "h2o-danube-1.8b"
_REGISTRY = ModelRegistry()


def _model():
    return _REGISTRY.load(ARCH)


def _engine(**kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    return InferenceEngine(_model(), EngineConfig(**kw))


# ---------------------------------------------------------------------------
# disabled path: zero cost
# ---------------------------------------------------------------------------

def test_engine_defaults_to_null_tracer():
    eng = _engine()
    assert eng.trace is NULL_TRACER
    assert not eng.trace.enabled
    assert eng.pool.tracer is NULL_TRACER


def test_null_tracer_zero_alloc():
    """The disabled hot path allocates NOTHING per dispatch: fixed-arity
    no-op methods, no *args packing, call sites pass pre-existing values.
    The first measured pass may warm CPython's adaptive specialization, so
    the assertion is on the steady-state (last) measurement."""
    t = NULL_TRACER

    def hot_path():
        # one dispatch's worth of disabled-tracer traffic
        t.step = 7
        t.dispatch_begin()
        t.decode_dispatch(4, 2, 2)
        t.host_sync("decode", 32)
        t.first_token(1, 0, 3)
        t.finish(1, 0, 9, 6)
        t.submit(1, 2, 3)
        t.admit(1, 0, 0, 4)
        t.prefill(1, 0, 4, 8, False)
        t.pool_wait()
        t.page_alloc(0, 1, 2)
        t.page_free(0, 3)
        t.page_evict(1)
        t.spec_dispatch(4, 2, 2)
        t.spec_slot(0, 3, 4, 4)
        t.reject(5)
        # resilience hooks (PR 7) ride the same zero-alloc contract
        t.tier_change(0, 1, 9)
        t.req_tier(1, 1)
        t.shed(1, 0, "deadline", 3)
        t.failover(1, 0)
        t.fault("crash", "injected")

    deltas = []
    for _ in range(3):
        hot_path()
        gc.collect()
        before = sys.getallocatedblocks()
        hot_path()
        deltas.append(sys.getallocatedblocks() - before)
    assert deltas[-1] == 0, f"disabled tracer allocated: deltas={deltas}"


def test_null_tracer_returns_empty_views():
    assert NULL_TRACER.request_spans() == {}
    assert NULL_TRACER.export() is None
    assert isinstance(NULL_TRACER, NullTracer)
    assert Tracer(TraceConfig()).enabled       # and the real one is on


# ---------------------------------------------------------------------------
# spans reconcile exactly with ServeMetrics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("decode_chunk", [1, 3])
def test_spans_reconcile_with_metrics(decode_chunk):
    """Step-clock span fields == the metrics records, including at K>1
    where micro-steps advance the emission clock between dispatches."""
    eng = _engine(decode_chunk=decode_chunk, trace=TraceConfig())
    reqs = [eng.submit([1, 2, 3, 4, 5], 6),
            eng.submit([7, 8, 9], 4, arrival_step=1),
            eng.submit([5, 4, 3, 2], 5, arrival_step=2)]
    eng.run()
    spans = eng.trace.request_spans()
    assert len(spans) == len(reqs)
    for r in reqs:
        s, rec = spans[r.id], eng.metrics.records[r.id]
        assert s["ttft_steps"] == rec.first_token_step - rec.arrival_step
        assert s["latency_steps"] == rec.finish_step - rec.arrival_step
        assert s["queue_steps"] == rec.start_step - rec.arrival_step
        assert s["tokens"] == rec.n_generated == len(r.generated)
        assert s["n_prompt"] == rec.n_prompt
        assert s["first_token_step"] == rec.first_token_step
        assert s["finish_step"] == rec.finish_step
        # wall spans are intervals on the monotonic clock: non-negative
        assert s["ttft_s"] >= 0.0 and s["latency_s"] >= s["ttft_s"]


def test_trace_counts_match_metrics_counters():
    eng = _engine(decode_chunk=2, trace=TraceConfig())
    eng.submit([1, 2, 3], 4)
    eng.submit([4, 5], 3, arrival_step=1)
    eng.run()
    evs = list(eng.trace.events)
    by_kind = {}
    for ev in evs:
        by_kind.setdefault(ev["ev"], []).append(ev)
    assert len(by_kind["decode"]) == eng.metrics.decode_steps
    assert len(by_kind["submit"]) == 2
    assert len(by_kind["admit"]) == eng.metrics.prefills
    assert len(by_kind["finish"]) == 2
    syncs = sum(1 for e in by_kind["host_sync"] if e["kind"] == "decode")
    assert syncs == eng.metrics.host_syncs["decode"]
    # every decode dispatch recorded its duration and occupancy
    for e in by_kind["decode"]:
        assert e["dur"] >= 0.0 and 0.0 < e["occupancy"] <= 1.0
        assert e["k"] == 2


def test_format_timeline_mentions_the_numbers():
    eng = _engine(trace=TraceConfig())
    r = eng.submit([1, 2, 3], 4)
    eng.run()
    text = eng.trace.format_timeline(r.id)
    assert f"req{r.id}" in text
    assert "ttft" in text and "generated 4 tokens" in text
    assert "no events retained" in eng.trace.format_timeline(999)


# ---------------------------------------------------------------------------
# exports
# ---------------------------------------------------------------------------

def test_export_jsonl_schema(tmp_path):
    eng = _engine(trace=TraceConfig())
    eng.submit([1, 2, 3], 3)
    eng.run()
    path = str(tmp_path / "trace.jsonl")
    n = export_jsonl([eng.trace], path)
    lines = [json.loads(l) for l in open(path)]
    assert lines[0]["ev"] == "meta"
    assert lines[0]["dropped"] == 0 and "clocks" in lines[0]
    assert len(lines) == n + 1                      # meta + events
    for ev in lines[1:]:
        assert "step" in ev and "t" in ev and ev["replica"] == 0


def test_export_chrome_reconciles_with_metrics(tmp_path):
    """The Chrome trace's per-request span args carry the SAME step-clock
    numbers ServeMetrics reports — the acceptance criterion that the trace
    is a richer view of the same events, not a second bookkeeping."""
    eng = _engine(decode_chunk=2, trace=TraceConfig())
    reqs = [eng.submit([1, 2, 3, 4], 5), eng.submit([9, 8], 4,
                                                    arrival_step=1)]
    eng.run()
    path = str(tmp_path / "trace.json")
    n = export_chrome([eng.trace], path)
    doc = json.load(open(path))
    assert n == len(doc["traceEvents"]) > 0
    req_spans = {e["name"]: e for e in doc["traceEvents"]
                 if e.get("cat") == "request"}
    assert len(req_spans) == len(reqs)
    for r in reqs:
        rec = eng.metrics.records[r.id]
        args = req_spans[f"req{r.id}"]["args"]
        assert args["ttft_steps"] == rec.first_token_step - rec.arrival_step
        assert args["latency_steps"] == rec.finish_step - rec.arrival_step
        assert args["tokens"] == len(r.generated)
        assert args["n_prompt"] == rec.n_prompt
    # structure: process metadata + dispatch track + occupancy counters
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert {"M", "X", "C", "i"} <= phases


def test_chrome_events_one_process_per_replica():
    eng0 = _engine(trace=TraceConfig())
    eng1 = _engine(trace=TraceConfig())
    router = ReplicaRouter([eng0, eng1])
    router.submit([1, 2, 3], 3)
    router.submit([4, 5, 6], 3)
    router.run()
    assert [t.replica for t in router.tracers] == [0, 1]
    evs = [e for t in router.tracers for e in chrome_events(t)]
    assert {e["pid"] for e in evs} == {0, 1}


def test_ring_buffer_caps_and_counts_drops():
    tr = Tracer(TraceConfig(capacity=4))
    for i in range(10):
        tr.host_sync("decode", 4)
    assert len(tr.events) == 4
    assert tr.dropped == 6
    # a request whose submit edge fell off the ring is omitted from spans
    tr2 = Tracer(TraceConfig(capacity=2))
    tr2.submit(1, 3, 0)
    for _ in range(3):
        tr2.host_sync("decode", 4)
    assert tr2.request_spans() == {}


def test_tracer_export_uses_config_paths(tmp_path):
    out = str(tmp_path / "a.jsonl")
    chrome = str(tmp_path / "b.json")
    eng = _engine(trace=TraceConfig(out=out, chrome=chrome))
    eng.submit([1, 2], 3)
    eng.run()
    eng.trace.export()
    assert json.loads(open(out).readline())["ev"] == "meta"
    assert "traceEvents" in json.load(open(chrome))


# ---------------------------------------------------------------------------
# page events (paged pool)
# ---------------------------------------------------------------------------

def test_paged_engine_emits_page_events():
    eng = InferenceEngine(_model(), EngineConfig(
        n_slots=2, max_len=64, page_size=8, trace=TraceConfig()))
    assert eng.pool.tracer is eng.trace
    eng.submit([1, 2, 3, 4, 5, 6, 7, 8, 9], 4)
    eng.run()
    kinds = {e["ev"] for e in eng.trace.events}
    assert "page_alloc" in kinds and "page_free" in kinds
    allocs = [e for e in eng.trace.events if e["ev"] == "page_alloc"]
    assert all(e["fresh"] >= 0 and e["shared"] >= 0 for e in allocs)


# ---------------------------------------------------------------------------
# speculative events
# ---------------------------------------------------------------------------

def test_speculative_engine_emits_spec_events():
    from repro.serve import DraftSpec
    model = _REGISTRY.load("nemotron-4-340b", draft_spec=DraftSpec(bits=8))
    eng = InferenceEngine(model, EngineConfig(
        n_slots=2, max_len=48, speculate=3, trace=TraceConfig()))
    r = eng.submit([1, 2, 3], 6)
    eng.run()
    kinds = {e["ev"] for e in eng.trace.events}
    assert "spec" in kinds and "spec_slot" in kinds
    slots = [e for e in eng.trace.events if e["ev"] == "spec_slot"]
    committed = sum(e["committed"] for e in slots
                    if e["slot"] == 0)
    assert committed >= len(r.generated) - 1    # first token from prefill
    for e in slots:
        assert 0 <= e["accepted"] <= e["proposed"]
        assert e["rolled_back"] == e["proposed"] - e["accepted"]


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_registry_counter_gauge_histogram_render():
    reg = TelemetryRegistry(prefix="t")
    reg.counter("toks").set(42)
    reg.gauge("depth").set(3.5)
    h = reg.histogram("lat", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = reg.render_prometheus()
    assert "# TYPE t_toks counter" in text and "t_toks 42" in text
    assert "t_depth 3.5" in text
    assert 't_lat_bucket{le="0.1"} 1' in text
    assert 't_lat_bucket{le="1"} 2' in text
    assert 't_lat_bucket{le="+Inf"} 3' in text and "t_lat_count 3" in text
    snap = reg.snapshot()
    assert snap["toks"] == 42 and snap["lat_count"] == 3.0
    with pytest.raises(AssertionError):
        reg.gauge("toks")                  # kind mismatch refuses


def test_engine_sample_and_jsonl(tmp_path):
    eng = _engine()
    eng.submit([1, 2, 3], 4)
    eng.run()
    jsonl = str(tmp_path / "tele.jsonl")
    exp = TelemetryExporter(lambda: engine_sample(eng),
                            TelemetryConfig(jsonl=jsonl))
    s = exp.sample()
    assert s["tokens_generated"] == 4.0
    assert s["n_slots"] == 2.0 and s["n_active"] == 0.0
    line = json.loads(open(jsonl).readline())
    assert line["sample"] == 1 and line["tokens_generated"] == 4.0
    # counter keys landed as counters, point-in-time keys as gauges
    assert exp.registry.counter("tokens_generated").value == 4.0
    assert exp.registry.gauge("mean_occupancy").value > 0.0


def test_router_sample_exposes_replica_depths():
    router = ReplicaRouter([_engine(), _engine()])
    router.submit([1, 2], 3)
    router.run()
    s = router_sample(router)
    assert s["n_replicas"] == 2.0
    assert "replica0_n_waiting" in s and "replica1_n_active" in s
    assert s["overflow_depth"] == 0.0


def test_prometheus_http_endpoint():
    eng = _engine()
    eng.submit([1, 2, 3], 3)
    eng.run()
    exp = TelemetryExporter(lambda: engine_sample(eng),
                            TelemetryConfig(interval=30.0, port=0))
    exp.start()
    try:
        assert exp.port and exp.port > 0
        url = f"http://127.0.0.1:{exp.port}/metrics"
        body = urllib.request.urlopen(url, timeout=10).read().decode()
        assert "serve_tokens_generated 3" in body
        assert "# TYPE serve_tokens_generated counter" in body
        with pytest.raises(Exception):
            urllib.request.urlopen(
                f"http://127.0.0.1:{exp.port}/nope", timeout=10)
    finally:
        exp.stop()
    # stop() tore the server down
    with pytest.raises(Exception):
        urllib.request.urlopen(url, timeout=2)


# ---------------------------------------------------------------------------
# traced run stays token-identical
# ---------------------------------------------------------------------------

def test_tracing_does_not_change_tokens():
    prompts = [([1, 2, 3, 4], 5), ([9, 8, 7], 4)]
    outs = []
    for trace in (None, TraceConfig()):
        eng = _engine(decode_chunk=2, trace=trace)
        reqs = [eng.submit(p, g, arrival_step=i)
                for i, (p, g) in enumerate(prompts)]
        eng.run()
        outs.append([list(r.generated) for r in reqs])
    assert outs[0] == outs[1]
