"""Resilience layer (PR 7): QoS tier ladder + hysteresis controller,
deadline semantics (admission doom-shed, in-flight expiry), mid-flight
cancellation hygiene across serving modes, pool-wait backoff + shedding.

The hygiene contract under test: every shed/cancel path releases its slot
(and pages, on a paged pool) so the pool drains to PRISTINE — no leaked
refcounts, no stranded slots — and the terminal state is explicit
(`Request.state == "shed"` with a `shed_reason`), never a hang.
"""

import numpy as np
import pytest

from repro.serve import (DraftSpec, EngineConfig, InferenceEngine,
                         ModelRegistry, QoSConfig, QoSController,
                         check_tier_spec, parse_tiers)

ARCH = "h2o-danube-1.8b"
_REGISTRY = ModelRegistry()
TIERS = (DraftSpec.from_args(8, 0.5, 0), DraftSpec.from_args(8, 0.75, 0))


def _model(**kw):
    return _REGISTRY.load(ARCH, **kw)


def _prompt(model, n=6, seed=0):
    return np.random.default_rng(seed).integers(0, model.cfg.vocab, n)


def _assert_pristine(eng):
    """After a full drain every pool resource is back: all slots free, and
    on a paged pool every surviving page reference is tree retention —
    finished requests publish their conversation into the prefix tree
    (PR 8), so retained pages must exactly match the tree's node count,
    and clearing the tree must hand every page back to the free list."""
    assert eng.pool.n_active == 0
    assert eng.pool.n_free == eng.cfg.n_slots
    if hasattr(eng.pool, "_free_pages"):
        if getattr(eng.pool, "index", None) is not None:
            assert eng.pool.pages_in_use == eng.pool.index.n_nodes
            eng.pool.index.clear(eng.pool._release)
        assert int(np.asarray(eng.pool.refs)[1:].sum()) == 0
        assert len(eng.pool._free_pages) == eng.pool.n_usable_pages


# ---------------------------------------------------------------------------
# QoS controller unit behavior
# ---------------------------------------------------------------------------

def test_qos_config_validates():
    with pytest.raises(ValueError, match="promote_depth"):
        QoSConfig(demote_depth=2, promote_depth=2)
    with pytest.raises(ValueError, match="hysteresis"):
        QoSConfig(hysteresis=0)


def test_qos_controller_needs_a_ladder():
    with pytest.raises(ValueError, match="2 resident tiers"):
        QoSController(QoSConfig(), n_tiers=1)


def test_qos_hysteresis_demote_promote_and_dead_band():
    cfg = QoSConfig(demote_depth=4, promote_depth=1, hysteresis=2)
    c = QoSController(cfg, n_tiers=3)
    # demotion needs `hysteresis` CONSECUTIVE over-watermark steps
    assert c.observe(9) == 0
    assert c.observe(9) == 1
    # the streak resets on a change: one more pair demotes again, then the
    # ladder clamps at its cheapest tier
    assert c.observe(9) == 1
    assert [c.observe(9) for _ in range(4)] == [2, 2, 2, 2]
    # dead band (between the watermarks) resets BOTH streaks: an
    # oscillating queue never flaps the tier
    assert c.observe(0) == 2
    assert c.observe(3) == 2              # dead band wipes the under-streak
    assert c.observe(0) == 2
    assert c.observe(0) == 1              # two consecutive idle steps
    assert c.observe(0) == 1
    assert c.observe(0) == 0
    assert c.observe(0) == 0              # clamped at tier 0


def test_qos_page_pressure_also_demotes():
    c = QoSController(QoSConfig(demote_depth=50, hysteresis=1,
                                page_pressure=0.9), n_tiers=2)
    assert c.observe(0, page_frac=0.5) == 0
    assert c.observe(0, page_frac=0.95) == 1    # full pool, empty queue
    # a full pool also BLOCKS re-promotion even at zero queue depth
    assert c.observe(0, page_frac=0.95) == 1
    assert c.observe(0, page_frac=0.1) == 0


def test_check_tier_spec_refuses_cache_shape_changes():
    with pytest.raises(ValueError, match="keep_layers"):
        check_tier_spec(DraftSpec.from_args(8, 0.5, 2))
    with pytest.raises(ValueError, match="cache_dtype"):
        check_tier_spec(DraftSpec(cache_dtype="bfloat16"))
    ts = DraftSpec.from_args(8, 0.5, 0)
    assert check_tier_spec(ts) is ts


def test_parse_tiers():
    tiers = parse_tiers("8:0.5,8:0.75")
    assert len(tiers) == 2
    assert tiers[0].sparsity == 0.5 and tiers[1].sparsity == 0.75
    assert all(t.bits == 8 for t in tiers)
    with pytest.raises(ValueError, match="no tiers"):
        parse_tiers(" , ")


# ---------------------------------------------------------------------------
# tier swaps on a live engine
# ---------------------------------------------------------------------------

def test_registry_keeps_tiers_resident():
    m = _model(tier_specs=TIERS)
    assert m.n_tiers == 3
    assert m.tier_tree(0) is m.params
    assert m.tier_tree(1) is m.tier_params[0]
    assert "tiers[" in m.name
    # tiers re-pack the SAME dense weights; packed trees are distinct
    assert m.tier_params[0] is not m.params


def test_engine_degrades_and_recovers_under_load():
    """Saturating submit burst -> the engine demotes down the ladder;
    streams keep decoding across the swap (token continuity: every request
    completes its full budget); queue drain re-promotes back to tier 0.
    Tier churn lands in metrics, per-request tiers in trace-visible
    Request.tier."""
    m = _model(tier_specs=TIERS)
    eng = InferenceEngine(
        m, EngineConfig(n_slots=2, max_len=48,
                        qos=QoSConfig(demote_depth=3, promote_depth=0,
                                      hysteresis=2)))
    rng = np.random.default_rng(1)
    reqs = [eng.submit(rng.integers(0, m.cfg.vocab, 6), 8)
            for _ in range(8)]
    eng.run()
    assert all(r.state == "done" and len(r.generated) == 8 for r in reqs)
    assert eng.metrics.tier_demotions >= 1
    assert eng.metrics.tier_promotions >= 1
    assert eng.tier == 0                         # drained: recovered
    # the burst's tail rode a degraded window; Request.tier records the
    # cheapest tier each request ever decoded on
    assert max(r.tier for r in reqs) >= 1
    _assert_pristine(eng)


def test_tier_zero_run_is_unchanged_by_resident_tiers():
    """Loading tiers must not perturb tier-0 serving: greedy outputs match
    a model loaded without tiers, token for token."""
    plain = _model()
    tiered = _model(tier_specs=TIERS)

    def run(m):
        eng = InferenceEngine(m, EngineConfig(n_slots=2, max_len=48))
        rs = [eng.submit(_prompt(m, seed=s), 6) for s in range(3)]
        eng.run()
        return [tuple(r.generated) for r in rs]

    assert run(plain) == run(tiered)


# ---------------------------------------------------------------------------
# deadlines: admission doom-shed + in-flight expiry
# ---------------------------------------------------------------------------

def test_doomed_at_admission_is_shed_before_occupying_a_slot():
    m = _model()
    eng = InferenceEngine(m, EngineConfig(n_slots=2, max_len=48))
    r = eng.submit(_prompt(m), 12, deadline_steps=3)   # needs >= 12 steps
    assert r.state == "shed" and r.shed_reason == "deadline"
    assert eng.n_waiting == 0                # never queued
    assert eng.metrics.shed == 1 and eng.metrics.deadline_missed == 1
    # a feasible deadline admits normally and completes
    ok = eng.submit(_prompt(m), 4, deadline_steps=50)
    eng.run()
    assert ok.state == "done" and len(ok.generated) == 4
    _assert_pristine(eng)


def test_queued_request_expires_when_backlog_dooms_it():
    """A request whose deadline was feasible at submit but is overtaken by
    queue wait is shed IN THE QUEUE (not after wasting a slot)."""
    m = _model()
    eng = InferenceEngine(m, EngineConfig(n_slots=1, max_len=48))
    front = eng.submit(_prompt(m), 10)
    late = eng.submit(_prompt(m, seed=1), 10, deadline_steps=12)
    eng.run()
    assert front.state == "done"
    assert late.state == "shed" and late.shed_reason == "deadline"
    assert eng.metrics.deadline_missed == 1
    _assert_pristine(eng)


def test_completions_never_served_past_deadline():
    """decode_chunk=1 makes the per-step doom check exact: every request
    either finishes by its deadline or sheds — no late completions."""
    m = _model()
    eng = InferenceEngine(m, EngineConfig(n_slots=2, max_len=48))
    rng = np.random.default_rng(3)
    D = 14
    reqs = [eng.submit(rng.integers(0, m.cfg.vocab, 5), 8,
                       deadline_steps=D) for _ in range(6)]
    eng.run()
    assert all(r.state in ("done", "shed") for r in reqs)
    for r in reqs:
        if r.state == "done":
            fin = eng.metrics.records[r.id].finish_step
            assert fin <= r.arrival_step + D
    assert any(r.state == "done" for r in reqs)
    _assert_pristine(eng)


# ---------------------------------------------------------------------------
# cancellation hygiene across serving modes
# ---------------------------------------------------------------------------

def _mk_engine(mode, paging):
    kw = dict(n_slots=2, max_len=48)
    model_kw = {}
    if mode == "spec":
        model_kw["draft_spec"] = DraftSpec.from_args(8, 0.0, 0)
        kw["speculate"] = 3
        # full-attention arch: the speculative verify block needs a
        # non-circular cache
        arch = "nemotron-4-340b"
    else:
        arch = ARCH
    if paging == "paged":
        kw["page_size"] = 8
    m = _REGISTRY.load(arch, **model_kw)
    return m, InferenceEngine(m, EngineConfig(**kw))


@pytest.mark.parametrize("mode", ["plain", "spec"])
@pytest.mark.parametrize("paging", ["slab", "paged"])
def test_midflight_cancel_is_clean(mode, paging):
    """Cancel one running and one queued request mid-decode: both get the
    explicit terminal state, the survivors complete their full budgets,
    and the pool drains to pristine (slots AND page refcounts)."""
    m, eng = _mk_engine(mode, paging)
    reqs = [eng.submit(_prompt(m, seed=s), 8, arrival_step=0)
            for s in range(4)]
    for _ in range(3):                   # two running, two queued
        eng.step()
    running = next(r for r in reqs if r.state == "running")
    queued = next(r for r in reqs if r.state == "waiting")
    eng.cancel(running)
    eng.cancel(queued)
    assert running.state == "shed" and running.shed_reason == "cancel"
    assert queued.state == "shed" and queued.shed_reason == "cancel"
    eng.cancel(running)                  # idempotent on terminal requests
    eng.run()
    survivors = [r for r in reqs if r not in (running, queued)]
    assert all(r.state == "done" and len(r.generated) == 8
               for r in survivors)
    assert eng.metrics.shed == 2
    _assert_pristine(eng)


def test_cancel_does_not_change_survivor_tokens():
    """Greedy tokens of surviving requests are identical with and without
    a mid-flight cancellation next to them."""
    m = _model()

    def run(cancel):
        eng = InferenceEngine(m, EngineConfig(n_slots=2, max_len=48))
        keep = eng.submit(_prompt(m, seed=0), 8)
        victim = eng.submit(_prompt(m, seed=1), 8)
        if cancel:
            for _ in range(2):
                eng.step()
            eng.cancel(victim)
        eng.run()
        return tuple(keep.generated)

    assert run(cancel=False) == run(cancel=True)


# ---------------------------------------------------------------------------
# PoolExhausted backoff + pool-pressure shedding
# ---------------------------------------------------------------------------

def test_pool_wait_backoff_then_shed():
    """With `pool_wait_retries`, a request that keeps finding the page pool
    full retries on an exponential backoff schedule (no head-of-line
    spinning every step) and is shed with reason 'pool' past the cap.

    Full-attention arch: an SWA cache is circular/resident, so only here
    does the paged pool actually budget pages per token."""
    m = _REGISTRY.load("nemotron-4-340b")
    # page pool sized so one long resident starves the second admission
    eng = InferenceEngine(
        m, EngineConfig(n_slots=2, max_len=48, page_size=8, n_pages=7,
                        pool_wait_retries=2))
    hog = eng.submit(_prompt(m), 20)
    starved = eng.submit(_prompt(m, seed=1), 20)
    eng.run()
    assert hog.state == "done" and len(hog.generated) == 20
    assert starved.state == "shed" and starved.shed_reason == "pool"
    assert starved.pool_retries == 3      # cap+1 attempts, then shed
    assert eng.metrics.shed_pool_pressure == 1
    assert eng.metrics.pool_waits >= 3
    _assert_pristine(eng)


def test_pool_wait_unbounded_legacy_waits_it_out():
    """pool_wait_retries=None (default) preserves the pre-PR-7 behavior:
    the starved request waits at the deque front and runs when pages
    free — nothing is shed."""
    m = _REGISTRY.load("nemotron-4-340b")
    eng = InferenceEngine(
        m, EngineConfig(n_slots=2, max_len=48, page_size=8, n_pages=7))
    hog = eng.submit(_prompt(m), 20)
    starved = eng.submit(_prompt(m, seed=1), 20)
    eng.run()
    assert hog.state == "done" and starved.state == "done"
    assert len(starved.generated) == 20
    assert eng.metrics.shed == 0
    _assert_pristine(eng)
