"""Per-kernel validation: every Pallas kernel against its pure-jnp oracle.

The Pallas TPU kernels are executed with interpret=True (the kernel body
runs step-by-step on CPU), swept over shapes / dtypes / sparsities /
precisions, and asserted allclose against ref.py — the Modelsim-vs-ground-
truth workflow of the paper (§III-D), applied to the TPU artifacts.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import quantize as qz
from repro.core import sparsity as sp
from repro.kernels import ops
from repro.kernels import ref as R


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# dense_matmul ('gemms' systolic analogue)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,p,bm,bk,bn", [
    (16, 32, 16, 8, 16, 8),
    (32, 64, 48, 16, 32, 16),
    (64, 128, 128, 32, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dense_matmul(m, n, p, bm, bk, bn, dtype):
    x, w = rand(0, (m, n), dtype), rand(1, (n, p), dtype)
    got = ops.matmul(x, w, backend="interpret", bm=bm, bk=bk, bn=bn)
    want = R.dense_matmul_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# bsr_matmul ('gemmt' tree analogue): sparsity sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sparsity", [0.0, 0.3, 0.5, 0.75, 0.9])
@pytest.mark.parametrize("bk,bn", [(8, 8), (16, 16)])
def test_bsr_matmul_sparsity(sparsity, bk, bn):
    n_in, n_out, m = 64, 48, 16
    plan = sp.make_plan(n_in, n_out, bk=bk, bn=bn, sparsity=sparsity, seed=3)
    w = rand(2, (n_in, n_out)) * np.asarray(sp.plan_mask(plan))
    x = rand(3, (m, n_in))
    blocks = sp.pack_blocks(jnp.asarray(w), plan)
    got = ops.bsr_matmul(x, blocks, jnp.asarray(plan.indices),
                         backend="interpret", bm=8)
    want = x @ w                      # dense ground truth on the masked weight
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # the scan ref and the einsum ref agree too
    got_ref = R.bsr_matmul_scan_ref(x, blocks, plan.indices)
    np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_bsr_matmul_skips_zero_blocks():
    """The packed representation holds only (1-s) of the weight bytes."""
    plan = sp.make_plan(128, 128, bk=16, bn=16, sparsity=0.75, seed=0)
    w = rand(0, (128, 128))
    blocks = sp.pack_blocks(w, plan)
    assert blocks.size == int(128 * 128 * 0.25)


# ---------------------------------------------------------------------------
# quant_matmul: every precision
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4, 2, 1])
def test_quant_matmul(bits):
    n, p, m = 64, 32, 16
    w = rand(4, (n, p), scale=0.5)
    x = rand(5, (m, n))
    qt = qz.quantize(w, bits)
    got = ops.quant_matmul(x, qt, backend="interpret", bm=8, bk=16, bn=16)
    want = R.quant_matmul_ref(x, qt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_quant_matmul_w8a8():
    n, p, m = 64, 32, 16
    w = rand(6, (n, p), scale=0.5)
    x = rand(7, (m, n))
    qt = qz.quantize(w, 8)
    got = ops.quant_matmul_w8a8(x, qt, backend="interpret", bm=8, bk=16, bn=16)
    want = R.quant_matmul_w8a8_ref(x, qt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # and both are close to the float product
    dense = x @ w
    err = np.abs(np.asarray(got) - np.asarray(dense)).mean()
    assert err < 0.05 * np.abs(np.asarray(dense)).mean() + 0.05


@pytest.mark.parametrize("bits", [8, 4, 2])
@pytest.mark.parametrize("sparsity", [0.5, 0.75])
def test_bsr_quant_matmul(bits, sparsity):
    """Kratos point-3: pruning x quantization compounded, kernel vs ref."""
    n_in, n_out, m, bk, bn = 64, 32, 16, 16, 16
    plan = sp.make_plan(n_in, n_out, bk=bk, bn=bn, sparsity=sparsity, seed=9)
    w = rand(8, (n_in, n_out), scale=0.5)
    x = rand(9, (m, n_in))
    scale = qz.compute_scale(w, bits)
    codes = qz.quantize_values(w, scale, bits)
    cblocks = sp.pack_blocks(codes, plan)
    n_pb, nnz, _, _ = cblocks.shape
    vpb = qz.VALUES_PER_BYTE[bits]
    packed = jax.vmap(lambda b: qz.pack_codes(b, bits))(
        cblocks.reshape(n_pb * nnz, bk, bn)).reshape(n_pb, nnz, bk // vpb, bn)
    scales = jnp.asarray(scale, jnp.float32).reshape(n_pb, bn)
    got = ops.bsr_quant_matmul(x, packed, scales, jnp.asarray(plan.indices),
                               bits, backend="interpret", bm=8)
    want = R.bsr_quant_matmul_ref(x, packed, scales, plan.indices, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# skinny-m path: decode-shaped GEMMs (m = n_slots, far below one MXU tile)
# ---------------------------------------------------------------------------

from repro.kernels import pallas_compat as PC


@pytest.mark.parametrize("m", [1, 3, 4, 5, 13])
def test_dense_matmul_skinny_m(m):
    """Row counts that divide no block: padded to the sublane multiple,
    computed, sliced back — bitwise-equal to the oracle."""
    x, w = rand(20, (m, 64)), rand(21, (64, 32))
    PC.SKINNY_M_EVENTS.clear()
    got = ops.matmul(x, w, backend="interpret")
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(R.dense_matmul_ref(x, w)),
                               rtol=1e-5, atol=1e-5)
    assert got.shape == (m, 32)
    assert any(e[0] == "dense_matmul" and e[1] == m
               for e in PC.SKINNY_M_EVENTS)
    PC.SKINNY_M_EVENTS.clear()


@pytest.mark.parametrize("m", [1, 4])
def test_bsr_matmul_skinny_m(m):
    plan = sp.make_plan(64, 48, bk=8, bn=8, sparsity=0.5, seed=3)
    w = rand(22, (64, 48)) * jnp.asarray(sp.plan_mask(plan), jnp.float32)
    x = rand(23, (m, 64))
    blocks = sp.pack_blocks(w, plan)
    got = ops.bsr_matmul(x, blocks, jnp.asarray(plan.indices),
                         backend="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bits", [8, 4])
def test_quant_matmul_skinny_m(bits):
    w, x = rand(24, (64, 32), scale=0.5), rand(25, (4, 64))
    qt = qz.quantize(w, bits)
    got = ops.quant_matmul(x, qt, backend="interpret", bk=16, bn=16)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(R.quant_matmul_ref(x, qt)),
                               rtol=1e-4, atol=1e-4)


def test_quant_matmul_w8a8_skinny_m():
    """w8a8 pads AFTER per-row activation quantization (zero pad rows would
    poison the row-scale), to the int8 sublane multiple of 32."""
    w, x = rand(26, (64, 32), scale=0.5), rand(27, (4, 64))
    qt = qz.quantize(w, 8)
    PC.SKINNY_M_EVENTS.clear()
    got = ops.quant_matmul_w8a8(x, qt, backend="interpret", bk=16, bn=16)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(R.quant_matmul_w8a8_ref(x, qt)),
                               rtol=1e-4, atol=1e-4)
    assert any(e[0] == "quant_matmul_w8a8" and e[2] == 32
               for e in PC.SKINNY_M_EVENTS)
    PC.SKINNY_M_EVENTS.clear()


def test_bsr_quant_matmul_skinny_m():
    bits, m = 4, 4
    plan = sp.make_plan(64, 32, bk=16, bn=16, sparsity=0.5, seed=9)
    w = rand(28, (64, 32), scale=0.5)
    x = rand(29, (m, 64))
    scale = qz.compute_scale(w, bits)
    codes = qz.quantize_values(w, scale, bits)
    cblocks = sp.pack_blocks(codes, plan)
    n_pb, nnz, bk, bn = cblocks.shape
    vpb = qz.VALUES_PER_BYTE[bits]
    packed = jax.vmap(lambda b: qz.pack_codes(b, bits))(
        cblocks.reshape(n_pb * nnz, bk, bn)).reshape(n_pb, nnz, bk // vpb, bn)
    scales = jnp.asarray(scale, jnp.float32).reshape(n_pb, bn)
    got = ops.bsr_quant_matmul(x, packed, scales, jnp.asarray(plan.indices),
                               bits, backend="interpret")
    want = R.bsr_quant_matmul_ref(x, packed, scales, plan.indices, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_skinny_bm_sublane_alignment():
    """The adaptive row block respects the per-dtype sublane minimum and
    never pads when an exact sublane-aligned grid exists."""
    assert PC.skinny_bm(4, 128, jnp.float32) == 8
    assert PC.skinny_bm(4, 128, jnp.bfloat16) == 16
    assert PC.skinny_bm(4, 128, jnp.int8) == 32
    assert PC.skinny_bm(64, 128, jnp.float32) == 64    # exact, no pad
    assert PC.skinny_bm(200, 128, jnp.float32) == 8    # exact grid: 25 x 8
    assert PC.skinny_bm(16, 8, jnp.float32) == 8       # divisible bm wins
    assert PC.skinny_bm(4, 8, jnp.bfloat16) == 16      # pad path clamps up
    assert PC.skinny_bm(12, 128, jnp.float32) == 16    # 12 -> one 16-row pad


def test_dense_matmul_large_m_keeps_exact_grid():
    """m=200 picks the exact 8-row grid — no pad rows, no skinny event."""
    x, w = rand(30, (200, 64)), rand(31, (64, 32))
    PC.SKINNY_M_EVENTS.clear()
    got = ops.matmul(x, w, backend="interpret")
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(R.dense_matmul_ref(x, w)),
                               rtol=1e-5, atol=1e-5)
    assert not PC.SKINNY_M_EVENTS


# ---------------------------------------------------------------------------
# flash attention: causal / window / softcap / GQA
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal,window,softcap", [
    (True, None, None),
    (True, 32, None),
    (True, None, 30.0),
    (False, None, None),
])
def test_flash_attention(causal, window, softcap):
    b, h, s, d = 2, 4, 128, 32
    q, k, v = (rand(i, (b, h, s, d)) for i in (10, 11, 12))
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, backend="interpret",
                              bq=64, bkv=64)
    want = R.attention_ref(q, k, v, causal=causal, window=window,
                           softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_gqa():
    b, h, kv, s, d = 2, 8, 2, 128, 16
    q = rand(13, (b, h, s, d))
    k, v = rand(14, (b, kv, s, d)), rand(15, (b, kv, s, d))
    got = ops.flash_attention(q, k, v, causal=True, backend="interpret",
                              bq=64, bkv=64)
    kk = jnp.repeat(k, h // kv, axis=1)
    vv = jnp.repeat(v, h // kv, axis=1)
    want = R.attention_ref(q, kk, vv, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_q_offset_matches_decode_semantics():
    """q_offset: the flash kernel on a suffix equals the suffix of full attn."""
    b, h, s, d, tail = 1, 2, 128, 16, 64
    q, k, v = (rand(i, (b, h, s, d)) for i in (16, 17, 18))
    full = R.attention_ref(q, k, v, causal=True)
    got = ops.flash_attention(q[:, :, -tail:], k, v, causal=True,
                              q_offset=s - tail, backend="interpret",
                              bq=32, bkv=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full[:, :, -tail:]),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# property-style sweeps (seeded random "hypothesis" grids)
# ---------------------------------------------------------------------------

def test_bsr_property_grid():
    """Invariant: tree kernel == dense matmul on the masked weight, over a
    random grid of (shape, block, sparsity, seed)."""
    rng = np.random.default_rng(0)
    for trial in range(8):
        bk = int(rng.choice([8, 16]))
        bn = int(rng.choice([8, 16]))
        n_in = bk * int(rng.integers(2, 6))
        n_out = bn * int(rng.integers(2, 6))
        m = 8 * int(rng.integers(1, 3))
        s = float(rng.uniform(0, 0.9))
        plan = sp.make_plan(n_in, n_out, bk=bk, bn=bn, sparsity=s,
                            seed=int(rng.integers(0, 99)))
        w = rand(trial, (n_in, n_out)) * np.asarray(sp.plan_mask(plan))
        x = rand(trial + 50, (m, n_in))
        blocks = sp.pack_blocks(jnp.asarray(w), plan)
        got = R.bsr_matmul_scan_ref(x, blocks, plan.indices)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                   rtol=1e-4, atol=1e-4)


def test_quant_roundtrip_property_grid():
    """Invariant: pack->unpack is the identity on codes, all bits/shapes."""
    rng = np.random.default_rng(1)
    for trial in range(10):
        bits = int(rng.choice([8, 4, 2, 1]))
        vpb = qz.VALUES_PER_BYTE[bits]
        n = vpb * int(rng.integers(1, 9))
        p = int(rng.integers(1, 17))
        w = rand(trial + 100, (n, p), scale=float(rng.uniform(0.1, 3.0)))
        scale = qz.compute_scale(w, bits)
        codes = qz.quantize_values(w, scale, bits)
        packed = qz.pack_codes(codes, bits)
        assert packed.shape[0] == n // vpb
        out = qz.unpack_codes(packed, bits)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))
