"""benchmarks/qor.py: direction-aware QoR gates against golden records.

Pure-python (no engine): gates must fail on regressions past tolerance,
pass on improvements and within-tolerance noise, treat exact metrics as
behavior identity, never gate wall-clock/info metrics, and fail loudly
when a gated metric or a whole golden record silently disappears."""

import json

import pytest

from benchmarks import qor


def _rec(**over):
    rec = {
        "arch": "h2o-danube-1.8b", "spec": "dense", "mode": "device",
        "decode_chunk": 4, "n_replicas": 1,
        "tokens_generated": 91.0, "decode_steps": 10.0,
        "tokens_per_step": 9.1, "tokens_per_dispatch": 9.1,
        "mean_occupancy": 0.6, "host_syncs_per_dispatch": 1.0,
        "host_syncs_per_token": 0.12, "latency_steps_p50": 25.0,
        "wall_tok_s": 5.2,
    }
    rec.update(over)
    return rec


def _files(golden_recs, new_recs):
    return {"records": golden_recs}, {"records": new_recs}


# ------------------------------------------------------------ compare_metric

def test_higher_metric_regression_fails():
    assert qor.compare_metric("tokens_per_step", 10.0, 9.0) is not None

def test_higher_metric_within_tolerance_passes():
    # tokens_per_step tol is 2%
    assert qor.compare_metric("tokens_per_step", 10.0, 9.81) is None

def test_higher_metric_improvement_passes():
    assert qor.compare_metric("tokens_per_step", 10.0, 14.0) is None

def test_lower_metric_regression_fails():
    assert qor.compare_metric("latency_steps_p50", 20.0, 23.0) is not None

def test_lower_metric_improvement_passes():
    assert qor.compare_metric("latency_steps_p50", 20.0, 12.0) is None

def test_exact_metric_any_drift_fails():
    assert qor.compare_metric("tokens_generated", 91.0, 92.0) is not None
    assert qor.compare_metric("tokens_generated", 91.0, 90.9999) is not None
    assert qor.compare_metric("tokens_generated", 91.0, 91.0) is None

def test_info_and_unknown_metrics_never_gate():
    assert qor.compare_metric("wall_tok_s", 100.0, 1.0) is None
    assert qor.compare_metric("some_future_metric", 5.0, -5.0) is None

def test_tol_scale_widens_gates():
    # 5% regression fails at tol 2% but passes with --tol-scale 3
    assert qor.compare_metric("tokens_per_step", 10.0, 9.5) is not None
    assert qor.compare_metric("tokens_per_step", 10.0, 9.5,
                              tol_scale=3.0) is None


# ----------------------------------------------------------- compare_records

def test_degraded_record_fails_with_named_metric():
    fails = qor.compare_records(_rec(), _rec(tokens_per_step=7.0,
                                             tokens_per_dispatch=7.0))
    assert fails
    assert any("tokens_per_step" in m for m in fails)

def test_identical_record_passes():
    assert qor.compare_records(_rec(), _rec()) == []

def test_missing_gated_metric_fails():
    new = _rec()
    del new["mean_occupancy"]
    fails = qor.compare_records(_rec(), new)
    assert any("mean_occupancy" in m and "missing" in m for m in fails)

def test_missing_info_metric_is_fine():
    new = _rec()
    del new["wall_tok_s"]
    assert qor.compare_records(_rec(), new) == []


# ------------------------------------------------------------- compare_files

def test_record_matching_by_identity_key():
    g, n = _files([_rec(), _rec(mode="host", decode_steps=25.0,
                                tokens_per_step=3.6)],
                  [_rec(mode="host", decode_steps=25.0, tokens_per_step=3.6),
                   _rec()])           # order must not matter
    assert qor.compare_files(g, n) == []

def test_vanished_golden_record_fails():
    g, n = _files([_rec(), _rec(mode="host")], [_rec()])
    fails = qor.compare_files(g, n)
    assert len(fails) == 1 and "no match" in fails[0]

def test_extra_new_records_pass():
    g, n = _files([_rec()], [_rec(), _rec(mode="static")])
    assert qor.compare_files(g, n) == []

def test_mesh_shape_list_vs_tuple_normalized():
    assert qor.record_key(_rec(mesh_shape=[2, 2])) \
        == qor.record_key(_rec(mesh_shape=(2, 2)))


# ----------------------------------------------------------------- main/CLI

def _write(path, recs):
    with open(path, "w") as f:
        json.dump({"records": recs}, f)
    return str(path)

def test_main_pass_and_fail_exit_codes(tmp_path):
    golden = _write(tmp_path / "golden.json", [_rec()])
    good = _write(tmp_path / "good.json", [_rec(tokens_per_step=9.2,
                                                tokens_per_dispatch=9.2)])
    bad = _write(tmp_path / "bad.json", [_rec(tokens_generated=90.0)])
    assert qor.main([good, "--golden", golden]) == 0
    assert qor.main([bad, "--golden", golden]) == 1

def test_main_missing_golden_fails(tmp_path):
    bench = _write(tmp_path / "b.json", [_rec()])
    assert qor.main([bench, "--golden", str(tmp_path / "nope.json")]) == 1

def test_main_unreadable_bench_fails(tmp_path):
    assert qor.main([str(tmp_path / "missing.json")]) == 1

def test_main_update_seeds_golden(tmp_path):
    bench = _write(tmp_path / "b.json", [_rec()])
    golden = str(tmp_path / "g.json")
    assert qor.main([bench, "--golden", golden, "--update"]) == 0
    assert json.load(open(golden))["records"] == [_rec()]
    # and the seeded golden now gates
    assert qor.main([bench, "--golden", golden]) == 0

def test_gated_metrics_lists_only_gated(tmp_path):
    names = qor.gated_metrics({"records": [_rec()]})
    assert "tokens_generated" in names and "tokens_per_step" in names
    assert "wall_tok_s" not in names
