"""repro.serve: registry packing, cache pool, scheduler, engine invariance."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.core import kratos as kr
from repro.models import transformer as T
from repro.serve import (CachePool, ContinuousScheduler, EngineConfig,
                         EngineSaturated, InferenceEngine, LocalBackend,
                         ModelRegistry, PoolExhausted, ReplicaRouter,
                         Request, ShardedBackend, StaticScheduler,
                         pack_model_params, replica_load)

ARCH = "h2o-danube-1.8b"
_REGISTRY = ModelRegistry()


def _model(spec=None):
    return _REGISTRY.load(ARCH, spec)


# ---------------------------------------------------------------------------
# registry / packing
# ---------------------------------------------------------------------------

def test_registry_packs_and_caches():
    spec = kr.KratosSpec(sparsity=0.5, bits=8, bk=8, bn=8)
    m1 = _REGISTRY.load(ARCH, spec)
    m2 = _REGISTRY.load(ARCH, spec)
    assert m1 is m2                       # keyed by (arch, spec)
    assert m1.n_packed > 0
    assert m1.compression > 4.0           # 0.5 sparsity x int8 ~ 7x
    leaves = [l for l in jax.tree_util.tree_leaves(
        m1.params, is_leaf=lambda x: isinstance(x, kr.PackedLinear))
        if isinstance(l, kr.PackedLinear)]
    assert len(leaves) == m1.n_packed
    assert any("qblocks" in l.buffers for l in leaves)


def test_pack_model_params_skips_non_projections():
    cfg = C.get_smoke("deepseek-v2-lite-16b")
    params = T.init(jax.random.PRNGKey(0), cfg)
    packed, n = pack_model_params(params, kr.KratosSpec(bits=8))
    assert n > 0

    def find(node, name):
        if isinstance(node, dict):
            for k, v in node.items():
                if k == name:
                    yield v
                yield from find(v, name)
        elif isinstance(node, list):
            for v in node:
                yield from find(v, name)

    for router in find(packed, "router"):     # consumed by a raw einsum
        assert isinstance(router, dict) and "w" in router
    for ffn in find(packed, "ffn"):
        if isinstance(ffn, dict) and "w_gate" in ffn \
                and not isinstance(ffn["w_gate"], kr.PackedLinear):
            # routed expert stack stays raw: (E, d, f), +1 layer-stacked dim
            assert ffn["w_gate"].ndim in (3, 4)


# ---------------------------------------------------------------------------
# cache pool
# ---------------------------------------------------------------------------

def test_cache_pool_slot_reuse_and_exhaustion():
    cfg = C.get_smoke(ARCH)
    pool = CachePool(cfg, n_slots=3, max_len=16)
    slots = [pool.alloc() for _ in range(3)]
    assert sorted(slots) == [0, 1, 2]
    with pytest.raises(PoolExhausted):
        pool.alloc()
    pool.free(slots[1])
    assert pool.n_free == 1
    assert pool.alloc() == slots[1]       # LIFO reuse of the freed slot
    with pytest.raises(ValueError):
        pool.free(99)
    pool.free(slots[0])
    with pytest.raises(ValueError):
        pool.free(slots[0])               # double free


def test_cache_pool_write_slot_isolates_rows():
    cfg = C.get_smoke(ARCH)
    pool = CachePool(cfg, n_slots=3, max_len=16)
    single = jax.tree_util.tree_map(lambda l: jnp.full_like(l, 7.0),
                                    pool.single_template)
    pool.write_slot(1, single)

    def rows(tree, axis):
        return jax.tree_util.tree_leaves(jax.tree_util.tree_map(
            lambda l: np.asarray(jnp.moveaxis(l, axis, 0)), tree))

    for leaf in rows(pool.caches["prelude"], 0) + rows(pool.caches["blocks"], 1):
        np.testing.assert_allclose(leaf[1], 7.0)      # written row
        np.testing.assert_allclose(leaf[0], 0.0)      # neighbors untouched
        np.testing.assert_allclose(leaf[2], 0.0)


# ---------------------------------------------------------------------------
# schedulers
# ---------------------------------------------------------------------------

def _reqs(n):
    return [Request(id=i, prompt=np.zeros(4, np.int32), max_new_tokens=4)
            for i in range(n)]


def test_continuous_scheduler_fills_free_slots():
    s = ContinuousScheduler(max_prefills_per_step=2)
    waiting = _reqs(5)
    assert s.admissible(waiting, n_active=1, n_free=3) == waiting[:2]
    assert s.admissible(waiting, n_active=4, n_free=0) == []


def test_static_scheduler_drains_before_refill():
    s = StaticScheduler()
    waiting = _reqs(5)
    assert s.admissible(waiting, n_active=2, n_free=2) == []
    assert s.admissible(waiting, n_active=0, n_free=4) == waiting[:4]


# ---------------------------------------------------------------------------
# engine: batch invariance + packed routing + policy comparison
# ---------------------------------------------------------------------------

def test_engine_batch_invariance_mixed_lengths():
    """Unequal prompt/gen lengths batched continuously == each run alone."""
    model = _model()
    rng = np.random.default_rng(3)
    jobs = [(rng.integers(0, model.cfg.vocab, s0), gen)
            for s0, gen in [(5, 7), (11, 3), (8, 5)]]

    eng = InferenceEngine(model, EngineConfig(n_slots=3, max_len=32))
    batched = [eng.submit(p, g, arrival_step=i)
               for i, (p, g) in enumerate(jobs)]
    eng.run()
    for r, (p, g) in zip(batched, jobs):
        solo_eng = InferenceEngine(model, EngineConfig(n_slots=1, max_len=32))
        solo = solo_eng.submit(p, g)
        solo_eng.run()
        assert len(r.generated) == g
        assert r.generated == solo.generated, (r.generated, solo.generated)


def test_engine_decode_routes_through_apply_packed(monkeypatch):
    model = _model(kr.KratosSpec(sparsity=0.5, bits=8, bk=8, bn=8))
    hits = []
    orig = kr.apply_packed
    monkeypatch.setattr(kr, "apply_packed",
                        lambda *a, **k: (hits.append(1), orig(*a, **k))[1])
    eng = InferenceEngine(model, EngineConfig(n_slots=2, max_len=24))
    r = eng.submit(np.arange(4) % model.cfg.vocab, 3)
    eng.run()
    assert len(r.generated) == 3
    assert hits, "decode/prefill compiled without touching apply_packed"


def test_continuous_at_least_matches_static_throughput():
    model = _model()
    rng = np.random.default_rng(5)
    jobs = [(rng.integers(0, model.cfg.vocab, int(rng.integers(3, 12))),
             int(rng.integers(3, 10)), i) for i in range(6)]

    def run_with(sched):
        eng = InferenceEngine(model, EngineConfig(n_slots=3, max_len=32),
                              scheduler=sched)
        for p, g, at in jobs:
            eng.submit(p, g, arrival_step=at)
        eng.run()
        return eng.metrics.report()

    stat = run_with(StaticScheduler())
    cont = run_with(None)
    assert cont["tokens_generated"] == stat["tokens_generated"]
    assert cont["tokens_per_step"] >= stat["tokens_per_step"]
    assert cont["mean_occupancy"] >= stat["mean_occupancy"]


def test_engine_streaming_and_limits():
    model = _model()
    eng = InferenceEngine(model, EngineConfig(n_slots=2, max_len=24))
    seen = []
    r = eng.submit(np.arange(5) % model.cfg.vocab, 4,
                   on_token=lambda req, tok: seen.append(tok))
    eng.run()
    assert seen == r.generated and len(seen) == 4
    # danube is uniformly windowed -> circular cache serves beyond max_len
    assert not eng._len_bounded
    long_r = eng.submit(np.arange(30) % model.cfg.vocab, 4)
    eng.run()
    assert len(long_r.generated) == 4
    with pytest.raises(ValueError):
        eng.submit(np.zeros(4, np.int32), 0)


def test_engine_bounds_full_attention_requests():
    """MLA caches are linear in S: requests must fit the slab."""
    model = _REGISTRY.load("minicpm3_4b")
    eng = InferenceEngine(model, EngineConfig(n_slots=1, max_len=16))
    assert eng._len_bounded
    with pytest.raises(ValueError):
        eng.submit(np.zeros(12, np.int32), 10)    # 22 > max_len


# ---------------------------------------------------------------------------
# device-resident decode loop (PR 2): equivalence, syncs, donation
# ---------------------------------------------------------------------------

def _run_jobs(model, jobs, *, n_slots=3, max_len=32, device_loop=True,
              decode_chunk=1, seed=0, temperature=0.0, eos_id=None):
    eng = InferenceEngine(model, EngineConfig(
        n_slots=n_slots, max_len=max_len, device_loop=device_loop,
        decode_chunk=decode_chunk, seed=seed))
    reqs = [eng.submit(p, g, arrival_step=i, temperature=temperature,
                       eos_id=eos_id)
            for i, (p, g) in enumerate(jobs)]
    eng.run()
    return [r.generated for r in reqs], eng


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b",     # transformer + SWA
                                  "falcon-mamba-7b",     # pure SSM
                                  "minicpm3_4b"])        # MLA
def test_device_loop_matches_host_loop_greedy(arch):
    """At temperature=0 the fused on-device sampler (K=1) AND the multi-step
    K>1 decode emit token-for-token what the PR-1 host loop emitted."""
    model = _REGISTRY.load(arch)
    rng = np.random.default_rng(11)
    jobs = [(rng.integers(0, model.cfg.vocab, s0), gen)
            for s0, gen in [(5, 7), (9, 4), (7, 6)]]
    host, _ = _run_jobs(model, jobs, device_loop=False)
    dev1, _ = _run_jobs(model, jobs, decode_chunk=1)
    dev3, _ = _run_jobs(model, jobs, decode_chunk=3)
    assert host == dev1
    assert host == dev3


def test_gumbel_sampling_reproducible_across_chunk_sizes():
    """One rng split per MICRO-step: a single sampled request is identical
    for any K grouping of the same steps, and moves with the seed."""
    model = _model()
    job = [(np.arange(5) % model.cfg.vocab, 9)]
    outs = [_run_jobs(model, job, n_slots=2, max_len=48, decode_chunk=k,
                      temperature=1.0, seed=7)[0][0] for k in (1, 2, 4)]
    assert outs[0] == outs[1] == outs[2]
    assert len(outs[0]) == 9
    reseeded = _run_jobs(model, job, n_slots=2, max_len=48, decode_chunk=1,
                         temperature=1.0, seed=8)[0][0]
    assert reseeded != outs[0]          # astronomically unlikely to collide


def test_multistep_eos_masks_on_device():
    """EOS mid-K-block: the device freezes the slot and the host emission
    stops at the same token the host loop stops at."""
    model = _model()
    prompt = np.arange(6) % model.cfg.vocab
    free, _ = _run_jobs(model, [(prompt, 8)], n_slots=2)
    eos = free[0][2]                    # forces a stop mid-block
    expect = free[0][:free[0].index(eos) + 1]
    host, _ = _run_jobs(model, [(prompt, 8)], n_slots=2, device_loop=False,
                        eos_id=eos)
    dev4, eng = _run_jobs(model, [(prompt, 8)], n_slots=2, decode_chunk=4,
                          eos_id=eos)
    assert host == dev4 == [expect]
    assert eng.requests[0].done and eng.pool.n_free == 2


def test_host_syncs_per_token_bound():
    """CI guard: the multi-step device loop syncs <= 1/K per decoded token
    (exactly 1/K for a lone request whose decode count divides K)."""
    model = _model()
    k = 4
    _, eng = _run_jobs(model, [(np.arange(5) % model.cfg.vocab, 17)],
                       n_slots=2, max_len=48, decode_chunk=k)
    rep = eng.metrics.report()
    decoded = rep["tokens_generated"] - eng.metrics.prefills
    assert decoded == 16
    assert rep["host_syncs_decode"] == decoded / k
    assert rep["host_syncs_per_token"] <= 1.0 / k + 1e-9
    # the PR-1 loop costs 3 crossings per decode step
    _, eng_h = _run_jobs(model, [(np.arange(5) % model.cfg.vocab, 17)],
                         n_slots=2, max_len=48, device_loop=False)
    rep_h = eng_h.metrics.report()
    assert rep_h["host_syncs_decode"] == 3 * rep_h["decode_steps"]
    assert rep_h["host_syncs_per_token"] > rep["host_syncs_per_token"]


def test_decode_and_slab_write_donate_buffers():
    """The decode dispatch donates (caches, state) and the slot install
    donates (slab, single): the lowered modules carry input->output aliasing,
    so on TPU/GPU the slab updates in place instead of being copied."""
    model = _model()
    eng = InferenceEngine(model, EngineConfig(n_slots=2, max_len=24))
    bk = eng.backend
    txt = bk._decode.lower(bk.params, eng.pool.caches, bk.state).as_text()
    assert "tf.aliasing_output" in txt or "jax.buffer_donor" in txt
    pool = eng.pool
    import jax.numpy as jnp
    txt_w = pool._write.lower(pool.caches, pool.single_template,
                              jnp.asarray(0, jnp.int32)).as_text()
    assert "tf.aliasing_output" in txt_w or "jax.buffer_donor" in txt_w


def test_admission_is_single_pass_and_order_preserving():
    """Bursty arrivals: every waiting request is admitted in FIFO order and
    the waiting deque is re-partitioned (no per-request remove)."""
    model = _model()
    eng = InferenceEngine(model, EngineConfig(n_slots=2, max_len=24))
    reqs = [eng.submit(np.arange(4) % model.cfg.vocab, 2, arrival_step=0)
            for _ in range(6)]
    eng.run()
    starts = [eng.metrics.records[r.id].start_step for r in reqs]
    assert starts == sorted(starts)     # FIFO admission
    assert all(len(r.generated) == 2 for r in reqs)


def test_decode_chunk_validation():
    model = _model()
    with pytest.raises(ValueError):
        InferenceEngine(model, EngineConfig(decode_chunk=0))
    with pytest.raises(ValueError):
        InferenceEngine(model, EngineConfig(decode_chunk=2,
                                            device_loop=False))


# ---------------------------------------------------------------------------
# execution backends (PR 3): engine/backend split, sharded equivalence
# ---------------------------------------------------------------------------

def test_explicit_local_backend_matches_default():
    model = _model()
    job = [(np.arange(6) % model.cfg.vocab, 5)]
    default, _ = _run_jobs(model, job, n_slots=2, max_len=24)
    eng = InferenceEngine(model, EngineConfig(n_slots=2, max_len=24),
                          backend=LocalBackend())
    r = eng.submit(*job[0])
    eng.run()
    assert [r.generated] == default
    assert eng.backend.describe()["mesh_shape"] == [1, 1]


def test_sharded_backend_single_device_identity():
    """ShardedBackend on a trivial (1, 1) mesh: same pjit machinery
    (NamedShardings, donated out_shardings, use_mesh tracing), greedy
    outputs identical to LocalBackend. The real multi-device assertions
    live in tests/test_serve_sharded.py on 8 forced CPU devices."""
    model = _model()
    rng = np.random.default_rng(2)
    jobs = [(rng.integers(0, model.cfg.vocab, 6), 5),
            (rng.integers(0, model.cfg.vocab, 9), 4)]
    local, _ = _run_jobs(model, jobs, n_slots=2, max_len=32, decode_chunk=2)
    eng = InferenceEngine(
        model, EngineConfig(n_slots=2, max_len=32, decode_chunk=2),
        backend=ShardedBackend(mesh_shape=(1, 1)))
    reqs = [eng.submit(p, g, arrival_step=i)
            for i, (p, g) in enumerate(jobs)]
    eng.run()
    assert [r.generated for r in reqs] == local
    assert eng.pool.shardings is not None     # slab placed via cache_pspecs


def test_sharded_backend_requires_device_loop():
    model = _model()
    with pytest.raises(ValueError):
        InferenceEngine(model,
                        EngineConfig(n_slots=2, max_len=24,
                                     device_loop=False),
                        backend=ShardedBackend(mesh_shape=(1, 1)))


# ---------------------------------------------------------------------------
# backpressure (PR 3): bounded waiting deque
# ---------------------------------------------------------------------------

def test_bounded_waiting_rejects_and_counts():
    model = _model()
    eng = InferenceEngine(model, EngineConfig(n_slots=1, max_len=24,
                                              max_waiting=2))
    prompt = np.arange(4) % model.cfg.vocab
    kept = [eng.submit(prompt, 2) for _ in range(2)]
    with pytest.raises(EngineSaturated):
        eng.submit(prompt, 2)
    assert eng.metrics.rejected == 1
    assert eng.n_waiting == 2                 # the bounce left no residue
    eng.run()
    assert all(len(r.generated) == 2 for r in kept)
    assert eng.metrics.report()["rejected"] == 1.0
    # draining freed the deque: submits are accepted again
    r = eng.submit(prompt, 2)
    eng.run()
    assert len(r.generated) == 2


def test_steal_waiting_preserves_handles_and_order():
    model = _model()
    a = InferenceEngine(model, EngineConfig(n_slots=1, max_len=24))
    b = InferenceEngine(model, EngineConfig(n_slots=1, max_len=24))
    prompt = np.arange(4) % model.cfg.vocab
    rs = [a.submit(prompt, 2, arrival_step=0) for _ in range(4)]
    stolen = a.steal_waiting(2)               # tail of the deque, FIFO order
    assert stolen == rs[2:]
    assert a.n_waiting == 2 and all(r.id not in a.requests for r in stolen)
    for r in stolen:
        b.adopt(r)
    a.run()
    b.run()
    assert all(len(r.generated) == 2 for r in rs)    # handles survived


# ---------------------------------------------------------------------------
# replica router (PR 3)
# ---------------------------------------------------------------------------

def test_replica_load_signal():
    assert replica_load(n_active=0, n_free=4, n_waiting=0) == -4
    assert replica_load(n_active=4, n_free=0, n_waiting=3) == 7


def test_router_least_loaded_spreads_and_drains():
    model = _model()
    router = ReplicaRouter.build(
        model, EngineConfig(n_slots=1, max_len=24), 2)
    prompt = np.arange(4) % model.cfg.vocab
    reqs = [router.submit(prompt, 3, arrival_step=0) for _ in range(4)]
    counts = [len(e.requests) for e in router.replicas]
    assert counts == [2, 2]                   # least-loaded + rr tiebreak
    router.run()
    assert all(len(r.generated) == 3 for r in reqs)
    rep = router.report()
    assert rep["requests_completed"] == 4.0
    assert rep["tokens_generated"] == 12.0
    assert rep["n_replicas"] == 2.0


def test_router_spills_on_saturated_replica_and_holds_overflow():
    model = _model()
    router = ReplicaRouter.build(
        model, EngineConfig(n_slots=1, max_len=24, max_waiting=1), 2)
    prompt = np.arange(4) % model.cfg.vocab
    # pre-step capacity: slots fill only at step(), so each replica holds
    # max_waiting=1 queued request -> 2 placed, 4 parked in the overflow
    reqs = [router.submit(prompt, 2, arrival_step=0) for _ in range(6)]
    assert router.spills > 0                  # bounced replica -> sibling
    assert len(router._overflow) == 4         # fleet-wide saturation parks
    assert router.overflowed == 4
    router.run()                              # overflow drains as slots free
    assert all(len(r.generated) == 2 for r in reqs)
    assert router.report()["rejected"] >= 2.0


def test_router_rebalances_skewed_queues():
    model = _model()
    router = ReplicaRouter.build(
        model, EngineConfig(n_slots=1, max_len=24), 2)
    a, b = router.replicas
    prompt = np.arange(4) % model.cfg.vocab
    # skew replica a directly (bypassing least-loaded placement)
    rs = [a.submit(prompt, 2, arrival_step=0) for _ in range(4)]
    router.requests.extend(rs)
    router.step()
    assert router.rebalanced > 0              # tail moved to the idle sibling
    assert b.metrics.tokens_generated > 0     # ... and b served it this step
    router.run()
    assert all(len(r.generated) == 2 for r in rs)
    assert a.metrics.tokens_generated < 8     # a did not serve the whole burst


def test_router_throughput_scales_on_saturated_trace():
    """Aggregate tokens per router step must beat the single engine on the
    same dense trace (2 replicas, target well above 1x; the serve_bench CI
    gate checks >= 1.5x on the bigger trace)."""
    from benchmarks.serve_bench import poisson_trace, run_router
    model = _model()
    trace = poisson_trace(8, 0.75, (4, 10), (6, 12), model.cfg.vocab, seed=3)
    single, routed = run_router(model, trace, 2, 32, 2, 2)
    assert routed["tokens_generated"] == single["tokens_generated"]
    assert routed["tokens_per_router_step"] > single["tokens_per_step"]
