"""Fig. 5 reproduction: resource utilization vs sparsity.

Paper claim (C1): the multiply-adder-tree kernels (gemmt / conv1d / conv2d)
show ~linear ALM reduction with sparsity; the systolic gemms barely improves
(-46%/-31% at 0.9 sparsity) because its structural registers cannot prune.

TPU restatement: effective MACs measured from the COMPILED HLO of each
kernel configuration. The tree implementation gathers only live blocks so
its compiled FLOPs fall linearly; the systolic (dense-masked) implementation
compiles to the same dense GEMM at every sparsity.

  PYTHONPATH=src python -m benchmarks.fig5_sparsity [--kernels k1,k2] [--quick]
"""

from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from benchmarks.common import CSV, hlo_cost
from repro.core import bench_specs as BS

DEFAULT = ("gemmt-RP-S", "gemmt-FU-S", "gemms-RP-S",
           "conv1d-PW-S", "conv2d-PW-S", "conv2d-FU-S")
FULL = tuple(BS.BY_NAME)


def run(kernels=DEFAULT, sparsities=BS.SPARSITIES) -> dict:
    csv = CSV(["kernel", "sparsity", "hlo_macs", "mac_fraction",
               "analytic_fraction", "ideal_fraction"])
    results = {}
    for name in kernels:
        base = BS.BY_NAME[name]
        dense_macs = None
        fracs = []
        for s in sparsities:
            spec = dataclasses.replace(base, sparsity=s)
            params, x, fn = BS.instantiate(spec)
            macs = hlo_cost(fn, params, x)["macs"]
            if dense_macs is None:
                dense_macs = macs
            frac = macs / dense_macs
            fracs.append(frac)
            rep = spec.resource_report()
            csv.row(name, s, macs, frac, rep["mac_fraction"], 1.0 - s)
        results[name] = fracs
    # C1 summary: linearity of tree kernels, flatness of systolic
    print("\n# C1 check:")
    for name, fracs in results.items():
        ideal = np.array([1.0 - s for s in sparsities])
        got = np.array(fracs)
        if name.startswith("gemms"):
            print(f"#   {name}: frac at 0.9 sparsity = {got[-1]:.2f} "
                  f"(systolic: expected ~1.0, paper FPGA saw 0.54-0.69)")
        else:
            err = np.abs(got - ideal).max()
            print(f"#   {name}: max |frac - (1-s)| = {err:.3f} "
                  f"({'LINEAR ok' if err < 0.12 else 'NOT linear'})")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernels", default=None)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    kernels = (a.kernels.split(",") if a.kernels
               else FULL if a.full else DEFAULT)
    sp = (0.0, 0.5, 0.9) if a.quick else BS.SPARSITIES
    run(kernels, sp)


if __name__ == "__main__":
    main()
