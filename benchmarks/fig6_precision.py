"""Fig. 6 reproduction: resource utilization vs precision.

Paper claim (C2): FPGA area falls SUPER-linearly with bit-width (multipliers
are quadratic in bits) — e.g. conv2d-FU-L drops 2.9x from 8-bit to 4-bit,
comparable to 80-90% sparsity.

TPU restatement (DESIGN.md §assumptions): on fixed silicon the quadratic
area win degrades to a LINEAR weight-byte win (packed int codes) plus a 2x
MXU-rate credit for w8a8. We measure packed weight bytes per config and the
roofline time of the weight-stationary GEMM at each precision, and report
the sparsity level that buys the same reduction (the paper's comparison).

  PYTHONPATH=src python -m benchmarks.fig6_precision
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from benchmarks.common import CSV, roofline_seconds
from repro.core import bench_specs as BS
from repro.core import kratos as kr
from repro.core import quantize as qz

DEFAULT = ("gemmt-RP-L", "conv2d-FU-L", "conv1d-PW-L")
BITS = (None, 8, 4, 2, 1)


def run(kernels=DEFAULT, sparsities=(0.0, 0.5, 0.9)) -> None:
    csv = CSV(["kernel", "sparsity", "bits", "weight_bytes",
               "bytes_fraction", "time_fraction", "equiv_sparsity"])
    for name in kernels:
        base = BS.BY_NAME[name]
        m, n, p = base.gemm_dims()
        dense_bytes = 2.0 * n * p           # bf16 reference
        for s in sparsities:
            for bits in BITS:
                spec = dataclasses.replace(base, sparsity=s, bits=bits)
                ks = spec.kratos_spec()
                rep = kr.cost_report(n, p, ks, m=m)
                wb = rep["weight_bytes"]
                # roofline time of one application at this precision
                t = roofline_seconds(2 * rep["effective_macs"],
                                     wb + 2.0 * m * (n + p),
                                     int8=(ks.act_bits == 8))
                t_dense = roofline_seconds(2 * m * n * p,
                                           dense_bytes + 2.0 * m * (n + p))
                tf = t["t"] / t_dense["t"]
                # sparsity that would buy the same byte reduction at bf16
                equiv_s = 1.0 - min(1.0, wb / dense_bytes)
                csv.row(name, s, bits or 16, wb, wb / dense_bytes, tf, equiv_s)
    print("\n# C2 check: paper sees 2.9x AREA 8->4bit (quadratic); on fixed")
    print("# TPU silicon the same step buys exactly 2x weight BYTES (linear)")
    print("# — the degradation DESIGN.md predicts. 8-bit + act8 additionally")
    print("# gets the 2x MXU-rate credit (time_fraction 0.5 when compute-bound).")


def verify_packed_sizes() -> None:
    """Cross-check the analytic byte counts against real packed buffers."""
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 128))
    for bits in (8, 4, 2, 1):
        qt = qz.quantize(w, bits)
        expect = 256 * 128 * bits / 8
        assert qt.data.size == expect, (bits, qt.data.size, expect)
    print("# packed-size cross-check ok (8/4/2/1-bit)")


def main() -> None:
    argparse.ArgumentParser().parse_args()
    verify_packed_sizes()
    run()


if __name__ == "__main__":
    main()
