"""Run the full benchmark suite: one section per paper table/figure,
plus the roofline table if a dry-run ledger exists.

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import os
import time


def section(title: str) -> None:
    print(f"\n{'='*72}\n== {title}\n{'='*72}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    t0 = time.time()
    os.makedirs("results", exist_ok=True)

    section("Fig. 1 — compounding on a 64x64 GEMM (C5)")
    from benchmarks import fig1_unrolled_area
    fig1_unrolled_area.run()

    section("Fig. 5 — utilization vs sparsity (C1)")
    from benchmarks import fig5_sparsity
    from repro.core import bench_specs as BS
    fig5_sparsity.run(sparsities=(0.0, 0.3, 0.5, 0.7, 0.9) if a.quick
                      else BS.SPARSITIES)

    section("Fig. 6 — utilization vs precision (C2)")
    from benchmarks import fig6_precision
    fig6_precision.verify_packed_sizes()
    fig6_precision.run()

    section("Fig. 7 — throughput vs unroll factor (C3)")
    from benchmarks import fig7_throughput
    fig7_throughput.run(quick=a.quick)

    section("Table III / Fig. 8 — granularity sweep (C4)")
    from benchmarks import table3_tilesweep
    table3_tilesweep.run(quick=a.quick)

    section("Serving — device-resident decode loop (smoke trace)")
    from benchmarks import serve_bench
    serve_ok = serve_bench.run(
        n_requests=8, prompt_range=(4, 16), gen_range=(8, 16),
        mean_interarrival=1.5, smoke=True, out="results/BENCH_serve.json")

    ledger = "results/dryrun.jsonl"
    if os.path.exists(ledger):
        section("§Roofline — 40-cell dry-run table (single-pod)")
        from benchmarks import roofline
        print(roofline.render(roofline.load_ledger(ledger), multi_pod=False))

    print(f"\n== benchmarks done in {time.time()-t0:.0f}s")
    if not serve_ok:
        raise SystemExit("serve_bench FAILED (see section above)")


if __name__ == "__main__":
    main()
