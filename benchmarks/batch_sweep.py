"""The paper's §III-D batch-job workflow: sweep the full design space
(kernel x size x sparsity x precision) and emit one CSV row per
configuration — the Quartus/VTR batch launcher, re-targeted.

Default mode is the analytic resource model (instant, 800 rows); --compile
additionally lowers+compiles every configuration and records measured HLO
MACs (the full-fidelity mode, a few minutes on this host).

  PYTHONPATH=src python -m benchmarks.batch_sweep \
      [--out results/kratos_design_space.csv] [--compile]
"""

from __future__ import annotations

import argparse
import dataclasses

from repro.core import bench_specs as BS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/kratos_design_space.csv")
    ap.add_argument("--compile", action="store_true")
    a = ap.parse_args()

    header = ["kernel", "unroll", "size", "sparsity", "bits",
              "dense_macs", "effective_macs", "mac_fraction",
              "weight_bytes", "weight_bytes_fraction", "mxu_rate",
              "ops_per_invocation"]
    if a.compile:
        header.append("hlo_macs")
    rows = [",".join(header)]
    for base in BS.TABLE_II:
        for spec in BS.sweep(base):
            r = spec.resource_report()
            row = [spec.kernel, spec.unroll, spec.size,
                   f"{spec.sparsity:g}", str(spec.bits or 16),
                   f"{r['dense_macs']:g}", f"{r['effective_macs']:g}",
                   f"{r['mac_fraction']:g}", f"{r['weight_bytes']:g}",
                   f"{r['weight_bytes_fraction']:g}", f"{r['mxu_rate']:g}",
                   str(spec.ops_per_invocation())]
            if a.compile:
                from benchmarks.common import hlo_cost
                params, x, fn = BS.instantiate(spec)
                row.append(f"{hlo_cost(fn, params, x)['macs']:g}")
            rows.append(",".join(row))
    out = "\n".join(rows) + "\n"
    with open(a.out, "w") as f:
        f.write(out)
    print(f"wrote {len(rows)-1} design points to {a.out}")


if __name__ == "__main__":
    main()
