"""Fig. 1 reproduction: the compounding story on a 64x64 GEMM.

Paper claim (C5): naive unrolling of a 64x64x64 matmul eats 63% of an
Arria-10; specialization (1) cuts ~4x, and specialization + pruning (2) +
quantization (3) compounds to ~600x (0.1% of the device).

TPU restatement: 'area' is effective resource-seconds. We measure, from
compiled HLO, the MAC count and weight traffic of:
    naive      generic dense GEMM, f32 weights (no specialization)
    spec       weight-stationary bf16 (constants baked: the weight tensor
               is a compile-time-planned resident — no quadratic win on a
               fixed MXU, the honest degradation)
    spec+prune tree kernel at 90% balanced sparsity
    +quant     tree kernel, 90% sparse, 4-bit packed weights
and report the compounded reduction in (MACs, weight bytes, roofline time).

  PYTHONPATH=src python -m benchmarks.fig1_unrolled_area
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import CSV, hlo_cost, roofline_seconds
from repro.core import kratos as kr

N = 64


def run() -> None:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (N, N))
    csv = CSV(["config", "hlo_macs", "weight_bytes", "t_roofline_ns",
               "mac_reduction", "byte_reduction", "time_reduction"])

    configs = [
        ("naive_f32", kr.KratosSpec(), jnp.float32, 4),
        ("specialized_bf16", kr.KratosSpec(), jnp.bfloat16, 2),
        ("spec+prune0.9", kr.KratosSpec(sparsity=0.9, bk=4, bn=4), jnp.bfloat16, 2),
        ("spec+prune0.9+4bit", kr.KratosSpec(sparsity=0.9, bits=4, bk=4, bn=4),
         jnp.bfloat16, 0.5),
    ]
    base = None
    for name, spec, dtype, bytes_per_w in configs:
        params = kr.init(key, N, N, spec, jnp.float32)
        packed = kr.pack(params, spec)

        def fn(pk, xx, _spec=spec):
            return kr.apply_packed(pk, xx.astype(dtype), _spec, N, N)

        cost = hlo_cost(fn, packed, x)
        rep = kr.cost_report(N, N, spec, m=N)
        wb = rep["weight_bytes_fraction"] * 2 * N * N * (bytes_per_w / 2) \
            if name == "naive_f32" else rep["weight_bytes"]
        t = roofline_seconds(cost["flops"], wb + 2 * 2 * N * N)["t"]
        if base is None:
            base = (cost["macs"], wb, t)
        csv.row(name, cost["macs"], wb, t * 1e9,
                base[0] / max(cost["macs"], 1), base[1] / max(wb, 1e-9),
                base[2] / t)
    print("\n# C5 check: paper compounds ~600x FPGA area on this GEMM; the")
    print("# fixed-silicon restatement compounds MACs x bytes as measured")
    print("# above (pruning is linear; precision is linear-in-bytes — the")
    print("# quadratic multiplier shrink has no MXU analogue, per DESIGN.md).")


def main() -> None:
    argparse.ArgumentParser().parse_args()
    run()


if __name__ == "__main__":
    main()
