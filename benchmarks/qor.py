"""QoR regression gate: diff BENCH_*.json records against committed goldens.

The VTR flow the Kratos paper benchmarks against keeps golden QoR files
per task and fails the run when a metric drifts past its per-metric
tolerance; this is the serving-stack analogue. A golden is simply an
earlier `--out` file from benchmarks/serve_bench.py checked in under
`benchmarks/golden/`; this checker matches its records to a fresh run's
records by identity key and applies DIRECTION-AWARE gates per metric:

  * `higher` — the metric may improve freely but regress only within
    `tol` (relative): new >= golden * (1 - tol). Throughput-like.
  * `lower`  — the mirror: new <= golden * (1 + tol). Syncs, latency.
  * `exact`  — token-identity class. The synthetic bench traces submit
    without an EOS id, so every request generates exactly its budget and
    counts like `tokens_generated` are platform-independent integers; a
    mismatch means the engine CHANGED BEHAVIOR, not that the machine was
    slow. No tolerance.
  * `info`   — recorded, never gated. All wall-clock metrics live here:
    CI machines differ, and gating on seconds makes flaky gates. The
    deterministic step-clock metrics carry the regression signal instead.

Unknown metrics default to `info`, so adding a new field to serve_bench
never breaks the gate; removing a gated field from the new run DOES fail
(a metric that silently disappears is itself a regression). A golden
record with no matching new record fails for the same reason; extra new
records (new modes, new specs) pass — they will be gated once the golden
is refreshed with `--update`.

  PYTHONPATH=src python -m benchmarks.qor results/BENCH_serve.json \
      [--golden benchmarks/golden/BENCH_serve.json] [--update] [--tol-scale S]

Exit status: 0 = all gates pass, 1 = any regression / missing record /
unreadable input. `--update` rewrites the golden from the new file
(reviewed like any diff). `--golden` defaults to benchmarks/golden/<same
basename>.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional, Sequence, Tuple

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden")

# identity: which golden record corresponds to which new record. Absent
# fields compare equal (None == None), so slim records match slim records.
KEY_FIELDS = ("arch", "spec", "mode", "decode_chunk", "speculate",
              "draft_spec", "page_size", "n_replicas", "mesh_shape",
              "n_processes")

# metric -> (direction, relative tolerance). Directions per the module
# docstring; tolerances sized to observed CPU-CI jitter on the step-clock
# metrics (occupancy/acceptance shift slightly with FP-sensitive accept
# decisions at different BLAS backends).
POLICY: Dict[str, Tuple[str, float]] = {
    # deterministic step-clock integers: behavior identity
    "tokens_generated": ("exact", 0.0),
    "apply_packed_hits": ("exact", 0.0),
    "skinny_m_dispatches": ("exact", 0.0),
    # deterministic throughput (step clock)
    "tokens_per_step": ("higher", 0.02),
    "tokens_per_dispatch": ("higher", 0.05),
    "tokens_per_router_step": ("higher", 0.05),
    "router_vs_single": ("higher", 0.05),
    "decode_steps": ("lower", 0.05),
    "mean_occupancy": ("higher", 0.05),
    # sync economy: the device loop's 1-sync-per-dispatch invariant makes
    # per-dispatch syncs essentially exact; per-token tracks occupancy
    "host_syncs_per_dispatch": ("lower", 0.001),
    "host_syncs_per_token": ("lower", 0.02),
    # latency (step clock)
    "latency_steps_p50": ("lower", 0.10),
    # speculative economy
    "acceptance_rate": ("higher", 0.05),
    "spec_vs_plain_dispatch": ("higher", 0.05),
    "draft_verify_flop_ratio": ("lower", 0.02),
    "draft_rolled_back": ("lower", 0.25),
    # resilience economy (overload trace): all step-clock deterministic —
    # shedding/demotion decisions ride the engine-step clock, so the
    # counts are behavior identity, and goodput/step is the gated win
    "goodput_tokens": ("exact", 0.0),
    "served_in_deadline": ("exact", 0.0),
    "deadline_missed_completions": ("exact", 0.0),
    "shed": ("exact", 0.0),
    "deadline_missed": ("exact", 0.0),
    "shed_pool_pressure": ("exact", 0.0),
    "tier_demotions": ("exact", 0.0),
    "tier_promotions": ("exact", 0.0),
    "goodput_tok_per_step": ("higher", 0.02),
    "resilient_vs_baseline_goodput": ("higher", 0.02),
    # prefix economy
    "prefix_hit_rate": ("higher", 0.02),
    "prefill_skip_fraction": ("higher", 0.02),
    "prefill_tokens_skipped": ("higher", 0.02),
    "pool_waits": ("lower", 0.25),
    # page-table-native decode + whole-conversation reuse (PR 8):
    # conversation hits/reuse ride the same deterministic token clock as
    # tokens_generated; gather events on the native hot path must stay
    # EXACTLY zero, and the avoided-traffic ledger may only grow (it is
    # bytes/dispatch * decode_steps, so it inherits dispatch-count drift)
    "conversation_prefix_hits": ("exact", 0.0),
    "conversation_tokens_reused": ("exact", 0.0),
    "decode_gather_events": ("exact", 0.0),
    "gather_bytes_avoided": ("higher", 0.05),
    # ineffectual-work ledger (PR 9): probe counts accumulate on the
    # step clock from deterministic traffic, so every counter — including
    # the per-layer zero-histogram checksum — is behavior identity; the
    # quality shadow of a single-tier engine is exact by construction
    "ledger_dispatches": ("exact", 0.0),
    "host_syncs_decode": ("exact", 0.0),
    "act_probe_elems": ("exact", 0.0),
    "act_zeros": ("exact", 0.0),
    "act_near_zeros": ("exact", 0.0),
    "act_kblocks": ("exact", 0.0),
    "act_dead_kblocks": ("exact", 0.0),
    "act_hist_checksum": ("exact", 0.0),
    "quality_probes": ("exact", 0.0),
    "quality_top1_rate": ("exact", 0.0),
    "quality_logit_mad": ("exact", 0.0),
    "trace_dropped": ("exact", 0.0),
    "act_zero_fraction": ("info", 0.0),
    "effective_flop_fraction": ("info", 0.0),
    # multi-process fleet (PR 10): coordinator-accumulated token counts
    # and failover/resurrection events are step-clock deterministic on a
    # healthy fleet (behavior identity); the throughput ratio is the
    # gated win. fleet_steps inherits wall-paced pump scheduling (which
    # process happens to step while waiting for arrivals varies run to
    # run), so it gets slack rather than exactness.
    "fleet_tokens": ("exact", 0.0),
    "fleet_requests_completed": ("exact", 0.0),
    "fleet_failovers": ("exact", 0.0),
    "resurrections_ignored": ("exact", 0.0),
    "token_identical": ("exact", 0.0),
    "tokens_per_fleet_step": ("higher", 0.10),
    "fleet_vs_single": ("higher", 0.10),
    "fleet_steps": ("lower", 0.15),
    # overflow parking depends on wall-paced heartbeat arrival order —
    # a canary worth printing, too timing-coupled to gate
    "fleet_overflowed": ("info", 0.0),
    "single_tokens_per_step": ("higher", 0.02),
    # wall clock: never gated (CI hardware varies run to run)
    "wall_tok_s": ("info", 0.0),
    "admitted_tok_s": ("info", 0.0),
    "paged_vs_slab_admitted": ("info", 0.0),
    "spec_vs_plain_wall": ("info", 0.0),
}


def record_key(rec: Dict[str, Any]) -> Tuple:
    def norm(v):
        return tuple(v) if isinstance(v, list) else v
    return tuple(norm(rec.get(k)) for k in KEY_FIELDS)


def fmt_key(rec: Dict[str, Any]) -> str:
    parts = [f"{k}={rec[k]}" for k in KEY_FIELDS
             if rec.get(k) not in (None, 0)]
    return "/".join(parts) or "<record>"


def compare_metric(name: str, golden: float, new: float,
                   tol_scale: float = 1.0) -> Optional[str]:
    """None = pass; a message = the regression. Unknown metrics are info."""
    direction, tol = POLICY.get(name, ("info", 0.0))
    if direction == "info":
        return None
    tol *= tol_scale
    if direction == "exact":
        if new != golden:
            return (f"{name}: exact metric changed {golden!r} -> {new!r} "
                    "(behavior change, not noise)")
        return None
    if direction == "higher":
        floor = golden * (1.0 - tol) if golden >= 0 else golden * (1.0 + tol)
        if new < floor - 1e-12:
            return (f"{name}: {new:g} < {golden:g} - {tol:.1%} "
                    f"(floor {floor:g})")
        return None
    if direction == "lower":
        ceil = golden * (1.0 + tol) if golden >= 0 else golden * (1.0 - tol)
        if new > ceil + 1e-12:
            return (f"{name}: {new:g} > {golden:g} + {tol:.1%} "
                    f"(ceiling {ceil:g})")
        return None
    raise ValueError(f"unknown direction {direction!r} for {name}")


def compare_records(golden: Dict[str, Any], new: Dict[str, Any],
                    tol_scale: float = 1.0) -> List[str]:
    fails = []
    for name, gval in golden.items():
        if name in KEY_FIELDS or not isinstance(gval, (int, float)) \
                or isinstance(gval, bool):
            continue
        direction, _ = POLICY.get(name, ("info", 0.0))
        if direction == "info":
            continue
        if name not in new:
            fails.append(f"{name}: gated metric missing from new record")
            continue
        msg = compare_metric(name, float(gval), float(new[name]), tol_scale)
        if msg:
            fails.append(msg)
    return fails


def compare_files(golden: Dict[str, Any], new: Dict[str, Any],
                  tol_scale: float = 1.0) -> List[str]:
    """All failures across the two files' record lists (empty = pass)."""
    fails: List[str] = []
    new_by_key: Dict[Tuple, Dict] = {}
    for rec in new.get("records", []):
        new_by_key[record_key(rec)] = rec
    for g in golden.get("records", []):
        n = new_by_key.get(record_key(g))
        if n is None:
            fails.append(f"[{fmt_key(g)}] golden record has no match in the "
                         "new run (a mode/spec disappeared)")
            continue
        fails.extend(f"[{fmt_key(g)}] {m}"
                     for m in compare_records(g, n, tol_scale))
    return fails


def gated_metrics(golden: Dict[str, Any]) -> List[str]:
    names = set()
    for rec in golden.get("records", []):
        for name, v in rec.items():
            if name in KEY_FIELDS or not isinstance(v, (int, float)) \
                    or isinstance(v, bool):
                continue
            if POLICY.get(name, ("info", 0.0))[0] != "info":
                names.add(name)
    return sorted(names)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Gate a serve_bench JSON against its committed golden.")
    ap.add_argument("bench", help="fresh BENCH_*.json from serve_bench --out")
    ap.add_argument("--golden", default="",
                    help="golden path (default: benchmarks/golden/<basename "
                         "of bench>)")
    ap.add_argument("--update", action="store_true",
                    help="adopt the new file as the golden instead of gating")
    ap.add_argument("--tol-scale", type=float, default=1.0,
                    help="scale every relative tolerance (exact stays exact);"
                         " e.g. 2.0 on a noisy substrate")
    args = ap.parse_args(argv)

    golden_path = args.golden or os.path.join(
        GOLDEN_DIR, os.path.basename(args.bench))
    try:
        with open(args.bench) as f:
            new = json.load(f)
    except (OSError, ValueError) as e:
        print(f"qor: cannot read bench file {args.bench}: {e}")
        return 1

    if args.update:
        os.makedirs(os.path.dirname(golden_path) or ".", exist_ok=True)
        with open(golden_path, "w") as f:
            json.dump(new, f, indent=2)
            f.write("\n")
        print(f"qor: golden updated -> {golden_path} "
              f"({len(new.get('records', []))} records)")
        return 0

    try:
        with open(golden_path) as f:
            golden = json.load(f)
    except (OSError, ValueError) as e:
        print(f"qor: cannot read golden {golden_path}: {e} "
              f"(seed it with: python -m benchmarks.qor {args.bench} "
              f"--update)")
        return 1

    fails = compare_files(golden, new, args.tol_scale)
    n_golden = len(golden.get("records", []))
    n_new = len(new.get("records", []))
    gates = gated_metrics(golden)
    print(f"qor: {args.bench} vs {golden_path}: {n_golden} golden records, "
          f"{n_new} new, gating {len(gates)} metrics "
          f"({', '.join(gates[:6])}{', ...' if len(gates) > 6 else ''})")
    if fails:
        print(f"qor: FAIL — {len(fails)} regression(s):")
        for m in fails:
            print(f"  {m}")
        return 1
    print("qor: PASS — no gated metric regressed past tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
