"""Table III / Fig. 8 reproduction: the granularity case study.

Paper claim (C4): sweeping FPGA LUT size K in {3..6} shows ~2x silicon-area
saving at K=3, and K=3 wins the area-delay product for nearly all kernels —
the fabric granularity should match the workload.

TPU restatement (DESIGN.md): the granularity knob is the sparsity BLOCK /
kernel tile size g. For a weight with true unstructured (element-level)
sparsity, a g x g block must be kept if ANY element in it is nonzero, so:

    coarse g  -> more dead weights ride along inside kept blocks
                 (wasted MACs/bytes — the 'big LUT' waste);
    fine g    -> tighter coverage, but each block-GEMM pads the MXU's
                 128x128 systolic tile (g<128 wastes (128/g)^2 of the array)
                 and burns more grid/VMEM overhead — the 'many small LUTs'
                 cost.

We measure kept-block coverage EMPIRICALLY from magnitude-pruned weights,
model the MXU padding analytically (documented hardware model — CPU cannot
measure it), and report footprint ('area'), latency ('delay') and their
product (ADP). The interior ADP optimum — and its drift toward finer g at
higher sparsity — is the paper's K=3 conclusion restated for the MXU.

  PYTHONPATH=src python -m benchmarks.table3_tilesweep
"""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import CSV
from repro.launch import mesh as M

GRAIN = (8, 16, 32, 64, 128, 256)
SPARSITY = (0.7, 0.8, 0.9, 0.95, 0.98)
BM = 128                       # activation rows per grid step


def kept_fraction(mask: np.ndarray, g: int) -> float:
    """Fraction of g x g blocks containing at least one nonzero."""
    n, p = mask.shape
    blocks = mask[:n // g * g, :p // g * g].reshape(n // g, g, p // g, g)
    alive = blocks.any(axis=(1, 3))
    return float(alive.mean())


def mxu_pad(g: int) -> float:
    """Hardware-model MXU inflation for a g-granular block GEMM.

    Sub-128 tiles occupy a full 128-lane pass in both the contraction and
    output dims of the 128x128 systolic array: inflation = (128/g)^2 for
    g < 128, 1 otherwise. (Documented model — the dry-run host cannot
    measure MXU occupancy.)
    """
    return (128.0 / g) ** 2 if g < 128 else 1.0


def run(n: int = 2048, p: int = 2048, bits: int = 8, seed: int = 0,
        quick: bool = False) -> None:
    if quick:
        n, p = min(n, 1024), min(p, 1024)
    sparsities = SPARSITY[::2] if quick else SPARSITY
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((n, p)).astype(np.float32)
    csv = CSV(["sparsity", "grain", "kept_frac", "eff_macs_frac",
               "hw_macs_frac", "vmem_bytes", "t_model_us", "adp",
               "adp_norm"])
    best = {}
    for s in sparsities:
        thr = np.quantile(np.abs(w), s)
        mask = np.abs(w) > thr            # magnitude pruning -> unstructured
        rows = []
        for g in GRAIN:
            kf = kept_fraction(mask, g)
            hw_frac = kf * mxu_pad(g)
            macs = hw_frac * BM * n * p
            wbytes = kf * n * p * bits / 8.0
            t_c = 2.0 * macs / M.PEAK_BF16_FLOPS
            t_m = (wbytes + 2.0 * BM * (n + p)) / M.HBM_BW
            t = max(t_c, t_m)
            vmem = BM * g * 2 + g * g * bits // 8 + BM * g * 4
            adp = vmem * t
            rows.append((g, kf, kf, hw_frac, vmem, t * 1e6, adp))
        min_adp = min(r[-1] for r in rows)
        for g, kf, eff, hw, vmem, t_us, adp in rows:
            csv.row(s, g, kf, eff, hw, vmem, t_us, adp, adp / min_adp)
        best[s] = min(rows, key=lambda r: r[-1])[0]
    print("\n# C4 check: ADP-optimal grain per sparsity:",
          {s: g for s, g in best.items()})
    print("# paper: smallest LUT (K=3) wins ADP for sparse kernels; here the")
    print("# optimum sits at the finest grain whose MXU padding is amortized,")
    print("# and coarse 256-grain blocks pay up to "
          f"{kept_fraction(np.abs(w) > np.quantile(np.abs(w), 0.95), 256) / (1 - 0.95):.1f}x"
          " the ideal MACs at 95% sparsity — the 'big LUT' waste.")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=8)
    a = ap.parse_args()
    run(bits=a.bits)


if __name__ == "__main__":
    main()
