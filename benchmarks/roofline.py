"""§Roofline table generator: reads the dry-run ledger and renders the
per-(arch x shape) three-term roofline table (markdown + CSV).

  PYTHONPATH=src python -m benchmarks.roofline [--ledger results/dryrun.jsonl]
      [--md results/roofline.md] [--multi-pod]
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict

from repro.launch.dryrun import roofline_terms


def load_ledger(path: str) -> Dict:
    recs = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            recs[(r["arch"], r["shape"], r.get("multi_pod", False))] = r
    return recs


def fmt(x, unit=""):
    if x is None:
        return "-"
    for scale, suf in ((1, "s"), (1e3, "ms"), (1e6, "us"), (1e9, "ns")):
        if x * scale >= 1:
            return f"{x*scale:.2f}{suf}"
    return f"{x:.2e}s"


def render(recs: Dict, multi_pod: bool = False) -> str:
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | dominant "
        "| model/HLO flops | roofline frac | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    shapes = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
    archs = sorted({k[0] for k in recs})
    for arch in archs:
        for shape in shapes:
            r = recs.get((arch, shape, multi_pod))
            if r is None:
                continue
            if r["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | — | — | — | skipped: "
                             f"{r['reason'][:48]}... | — | — | — |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | ERROR | | | | | | |")
                continue
            t = r.get("roofline") or roofline_terms(r)
            mem = (r.get("mem_temp_size_in_bytes", 0)
                   + r.get("mem_argument_size_in_bytes", 0)) / 2 ** 30
            uf = t.get("useful_flops_fraction")
            lines.append(
                f"| {arch} | {shape} | {fmt(t['t_compute_s'])} "
                f"| {fmt(t['t_memory_s'])} | {fmt(t['t_collective_s'])} "
                f"| **{t['dominant']}** "
                f"| {uf:.2f} | {t['roofline_fraction']:.4f} "
                f"| {'Y' if mem <= 16 else f'{mem:.0f}G'} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ledger", default="results/dryrun.jsonl")
    ap.add_argument("--md", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    a = ap.parse_args()
    if not os.path.exists(a.ledger):
        print(f"# no ledger at {a.ledger} — run repro.launch.dryrun_all first")
        return
    recs = load_ledger(a.ledger)
    out = render(recs, a.multi_pod)
    print(out)
    if a.md:
        with open(a.md, "w") as f:
            f.write(out + "\n")


if __name__ == "__main__":
    main()
