"""Serving benchmark: static vs continuous batching under a Poisson trace.

The serving claim of the Kratos stack: (1) continuous batching keeps the
decode slab full under mixed-length traffic, where the lock-step baseline
drains to the longest member of each batch; (2) the decode hot path runs on
PACKED weights (kratos.pack once at load, apply_packed per step), so the
sparsity/precision savings of the paper exist at serving time, not just in
the training graph.

Method: one Poisson arrival trace (exponential inter-arrival steps, mixed
prompt/generation lengths) is replayed against the SAME engine configuration
under both schedulers, for each KratosSpec. The primary comparison metric is
tokens/decode-step — the deterministic, compile-noise-free clock the
scheduler actually controls — with wall tok/s reported alongside.
`apply_packed` routing is verified by instrumenting the dispatcher and
counting hot-path hits during trace compilation.

  PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] [--arch ...]
      [--requests N] [--slots K] [--seed S]
"""

from __future__ import annotations

import argparse
import sys

import numpy as np

from benchmarks.common import CSV
from repro.core import kratos as kr
from repro.serve import (EngineConfig, InferenceEngine, ModelRegistry,
                         StaticScheduler)

SPECS = (
    ("dense", kr.KratosSpec()),
    ("sparse-tree", kr.KratosSpec(sparsity=0.5, bk=8, bn=8)),
    ("w8a8", kr.KratosSpec(bits=8, act_bits=8)),
    ("sparse0.5-w8", kr.KratosSpec(sparsity=0.5, bits=8, bk=8, bn=8)),
)
SMOKE_SPECS = ("dense", "sparse0.5-w8")


def poisson_trace(n_requests: int, mean_interarrival: float, prompt_range,
                  gen_range, vocab: int, seed: int):
    """[(arrival_step, prompt, gen_len)] with exp. inter-arrival steps."""
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    for _ in range(n_requests):
        t += rng.exponential(mean_interarrival)
        s0 = int(rng.integers(*prompt_range))
        gen = int(rng.integers(*gen_range))
        out.append((int(t), rng.integers(0, vocab, s0), gen))
    return out


class PackedRouteCounter:
    """Counts kratos.apply_packed dispatches (trace-time: hits = packed
    GEMMs baked into the compiled prefill/decode steps)."""

    def __init__(self):
        self.hits = 0
        self._orig = kr.apply_packed

    def __enter__(self):
        def counted(*a, **kw):
            self.hits += 1
            return self._orig(*a, **kw)
        kr.apply_packed = counted
        return self

    def __exit__(self, *exc):
        kr.apply_packed = self._orig
        return False


def run_one(model, trace, n_slots: int, max_len: int, scheduler):
    eng = InferenceEngine(
        model, EngineConfig(n_slots=n_slots, max_len=max_len),
        scheduler=scheduler)
    for arrival, prompt, gen in trace:
        eng.submit(prompt, gen, arrival_step=arrival)
    eng.run()
    return eng.metrics.report()


def run(arch: str = "h2o-danube-1.8b", n_requests: int = 16,
        n_slots: int = 4, mean_interarrival: float = 2.0,
        prompt_range=(4, 24), gen_range=(4, 24), seed: int = 0,
        smoke: bool = False) -> bool:
    registry = ModelRegistry()
    csv = CSV(["spec", "scheduler", "toks", "decode_steps", "tok_per_step",
               "occupancy", "tok_per_s_wall", "lat_p50_steps", "lat_p99_steps",
               "packed_MB", "compression", "apply_packed_hits"])
    specs = [(n, s) for n, s in SPECS if not smoke or n in SMOKE_SPECS]
    ok = True
    for spec_name, spec in specs:
        model = registry.load(arch, spec, seed=seed)
        cfg = model.cfg
        trace = poisson_trace(n_requests, mean_interarrival, prompt_range,
                              gen_range, cfg.vocab, seed)
        max_len = cfg.n_img_tokens + prompt_range[1] + gen_range[1] + 8
        results = {}
        for sched_name, sched in (("static", StaticScheduler()),
                                  ("continuous", None)):
            with PackedRouteCounter() as counter:
                rep = run_one(model, trace, n_slots, max_len, sched)
            results[sched_name] = rep
            csv.row(spec_name, sched_name, int(rep["tokens_generated"]),
                    int(rep["decode_steps"]), rep["tokens_per_step"],
                    rep["mean_occupancy"], rep["tok_per_s"],
                    rep["latency_steps_p50"], rep["latency_steps_p99"],
                    model.packed_bytes / 1e6, model.compression, counter.hits)
            if counter.hits == 0:
                print(f"# FAIL {spec_name}: decode did not route through "
                      "apply_packed")
                ok = False
        cont, stat = results["continuous"], results["static"]
        win = cont["tokens_per_step"] >= stat["tokens_per_step"]
        ok = ok and win
        print(f"# {spec_name}: continuous {cont['tokens_per_step']:.2f} "
              f"tok/step vs static {stat['tokens_per_step']:.2f} "
              f"({'PASS' if win else 'FAIL'}); latency p50 "
              f"{cont['latency_steps_p50']:.0f} vs "
              f"{stat['latency_steps_p50']:.0f} steps")
    print(f"# serve_bench: {'PASS' if ok else 'FAIL'} — continuous >= static "
          "on every spec, decode on packed buffers")
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: dense + sparse0.5-w8, small trace, <60s")
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    if a.smoke:
        ok = run(a.arch, n_requests=a.requests or 8, n_slots=a.slots,
                 prompt_range=(4, 16), gen_range=(4, 12),
                 mean_interarrival=1.5, seed=a.seed, smoke=True)
    else:
        ok = run(a.arch, n_requests=a.requests or 16, n_slots=a.slots,
                 seed=a.seed)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
