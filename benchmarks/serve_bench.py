"""Serving benchmark: device-resident decode loop vs the PR-1 host loop,
and static vs continuous batching, under a Poisson trace.

The serving claims of the Kratos stack:

  (1) continuous batching keeps the decode slab full under mixed-length
      traffic, where the lock-step baseline drains to the longest member;
  (2) the decode hot path runs on PACKED weights (kratos.pack once at load,
      apply_packed per step), so the sparsity/precision savings exist at
      serving time;
  (3) [PR 2] the decode loop is device-resident: sampling fused into the
      compiled step, donated KV slab, K micro-steps per dispatch — decode
      syncs drop from 3 per micro-step (full-vocab logits pull + token/index
      uploads) to exactly 1 per K-step dispatch (= 1/K per micro-step, and
      <= 1/K per decoded token whenever the trace sustains K tokens per
      dispatch);
  (4) [PR 2] the decode GEMMs (m = n_slots) dispatch through the Pallas
      kernels' skinny-m path, asserted by trace-time instrumentation
      (pallas_compat.SKINNY_M_EVENTS) the same way apply_packed routing is.

Method: one Poisson arrival trace (exponential inter-arrival steps, mixed
prompt/generation lengths) is replayed against the SAME engine configuration
in three modes per KratosSpec — 'host' (PR-1 loop, continuous), 'device'
(fused loop, K=--decode-chunk, continuous) and 'static' (fused loop, static
scheduler). The primary comparison metric is tokens/decode-dispatch — the
deterministic, compile-noise-free clock — with wall tok/s and host syncs per
decoded token alongside. `--out` writes the records as JSON
({arch, spec, mode, tokens_per_step, wall_tok_s, host_syncs_per_token, ...})
so every future PR has a perf baseline to diff against.

Speculative mode (PR 4): `--speculate K` switches the benchmark to the
self-draft comparison — ONE trace replayed through the PLAIN device loop
(decode_chunk=1: `speculate` replaces the chunk knob, so the un-chunked
loop is the apples-to-apples baseline) and through the speculative engine
(speculate=K) with the draft described by
`--draft-bits/--draft-sparsity/--draft-keep-layers`. The GATE is
deterministic: speculative >= 1.2x TOKENS PER DISPATCH vs the plain loop
(integers, immune to CI timing noise), plus greedy token-identity. Both
engines are additionally WARMED on a full replay and timed on a second
one, and the wall tokens/sec ratio is REPORTED ungated — on the CPU
reference backend the draft re-pack executes at full-precision cost (the
packed Pallas kernels that realize its FLOP discount engage off-ref).
Records carry acceptance rate, rollback counts and the draft/verify FLOP
ratio.

Prefix-trace mode (PR 5): `--prefix-trace` replays a Poisson trace whose
prompts share Zipf-distributed SYSTEM PREFIXES (the chat-fleet shape:
a few long system prompts, many short user suffixes) through the slab
engine and through the paged + prefix-cached engine
(`EngineConfig.page_size`, serve.paging). Gates: greedy token-identity,
paged+prefix >= 1.3x ADMITTED tokens/sec (prompt + generated tokens per
wall second, warm-measured — the number admission latency caps), and >=
50% of prompt tokens skipped at prefill (served from shared prefix pages).
Every JSON record carries the prefill FLOPs saved (2 * N_active * skipped
tokens) and the page-pool occupancy; `--out results/BENCH_prefix.json` is
the CI artifact.

Conversation-trace mode (PR 8): `--conversation-trace` drives multi-turn
CHATS — each turn's prompt is the whole prior conversation (prompt +
the engine's own reply) plus a short follow-up, so the trace cannot be
precomputed: later turns are built live from the tokens the engine
emitted. The paged engine publishes every finished request's FULL
conversation into the radix prefix tree (generated tokens included), so
turn t matches every full page of turns 1..t-1 and prefills only the
follow-up. Gates: >= 70% of ALL prompt tokens skipped at prefill, ZERO
gather/scatter events on the page-table-native decode hot path (with
`gather_bytes_avoided` exactly accounting the traffic the legacy wrap
would have moved), and greedy token-identity against a slab engine fed
the same per-turn prompts. `--out results/BENCH_conv.json` is the CI
artifact, diffed against its golden by benchmarks/qor.py.

Overload-trace mode (PR 7): `--overload-trace` replays a 2x SATURATING
Poisson trace (token arrivals at twice the chunk-1 slab's service rate)
through a baseline engine that admits everything and through the resilient
engine (per-request deadlines + QoS tier ladder + bounded pool-wait
retries). Gates: resilient >= 1.2x GOODPUT per engine step — deadline-met
generated tokens on the deterministic step clock — and ZERO completions
served past their deadline on the resilient side (admission-time doom
shedding + per-step expiry make that exact at decode_chunk=1). Wall tok/s
is reported ungated; `--out results/BENCH_overload.json` is the CI
artifact.

Ledger-trace mode (PR 9): `--ledger-trace` replays one Poisson trace TWICE
through a device-loop engine carrying the ineffectual-work ledger
(serve.ledger) and gates, all step-clock deterministic: measured activation
zeros > 0 (nemotron's squared-ReLU MLP), every counter and per-layer
zero-group histogram bit-identical across the two runs (hist_checksum),
host syncs == decode dispatches (the ledger drains inside the existing
token sync), and an exact tier-0 quality shadow (top1 1.0, MAD 0.0). The
JSON artifact adds an ungated roofline join (analysis.roofline over the
tracer's dispatch walls); `--out results/BENCH_ledger.json` is the CI
artifact, diffed against its golden by benchmarks/qor.py.

Provenance (PR 4): every JSON record is stamped with the git commit, jax
version and rng seed, so BENCH trajectories are comparable across runs.

Mesh / router modes (PR 3): `--mesh data,model` adds a 'sharded' mode —
the same trace through `serve.ShardedBackend` on a local mesh of that
shape, gated on emitting exactly the tokens the local device loop emits
(placement must not change outputs). `--replicas N` adds a router
comparison: ONE dense synthetic trace replayed against a single engine and
against `serve.ReplicaRouter` over N replicas (each on its own
data-submesh when `--mesh` is given), gated on aggregate
tokens/router-step >= 1.5x the single replica's tokens/step. Every JSON
record carries `mesh_shape` and `n_replicas` so the CI artifact
distinguishes placements.

  PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] [--arch ...]
      [--requests N] [--slots K] [--seed S] [--decode-chunk K]
      [--mesh D,M] [--replicas N] [--out results/BENCH_serve.json]
      [--trace-dir results/traces]

QoR gates (PR 6): `--out` records are diffed against committed goldens by
`benchmarks/qor.py` (direction-aware per-metric tolerances; deterministic
step-clock integers gate EXACTLY) — regressions fail CI. `--trace-dir`
additionally records every (spec, mode) run with the serve tracer (JSONL +
Chrome trace + telemetry snapshot per mode) for the CI artifact.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from benchmarks.common import CSV
from repro.core import kratos as kr
from repro.distributed import steps as ST
from repro.kernels import pallas_compat as PC
from repro.serve import (DraftSpec, EngineConfig, InferenceEngine,
                         ModelRegistry, StaticScheduler, TelemetryConfig,
                         TelemetryExporter, TraceConfig, engine_sample)


def provenance(seed: int) -> dict:
    """Stamped into EVERY json record: what produced this number."""
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            timeout=10).stdout.strip()
    except Exception:
        commit = ""
    return {"git_commit": commit or os.environ.get("GITHUB_SHA", "unknown"),
            "jax_version": jax.__version__, "rng_seed": seed}

SPECS = (
    ("dense", kr.KratosSpec()),
    ("sparse-tree", kr.KratosSpec(sparsity=0.5, bk=8, bn=8)),
    ("w8a8", kr.KratosSpec(bits=8, act_bits=8)),
    ("sparse0.5-w8", kr.KratosSpec(sparsity=0.5, bits=8, bk=8, bn=8)),
)
SMOKE_SPECS = ("dense", "sparse0.5-w8")


def poisson_trace(n_requests: int, mean_interarrival: float, prompt_range,
                  gen_range, vocab: int, seed: int):
    """[(arrival_step, prompt, gen_len)] with exp. inter-arrival steps."""
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    for _ in range(n_requests):
        t += rng.exponential(mean_interarrival)
        s0 = int(rng.integers(*prompt_range))
        gen = int(rng.integers(*gen_range))
        out.append((int(t), rng.integers(0, vocab, s0), gen))
    return out


class PackedRouteCounter:
    """Counts kratos.apply_packed dispatches (trace-time: hits = packed
    GEMMs baked into the compiled prefill/decode steps)."""

    def __init__(self):
        self.hits = 0
        self._orig = kr.apply_packed

    def __enter__(self):
        def counted(*a, **kw):
            self.hits += 1
            return self._orig(*a, **kw)
        kr.apply_packed = counted
        return self

    def __exit__(self, *exc):
        kr.apply_packed = self._orig
        return False


def _warn_trace_dropped(tracer) -> None:
    """Warn ONCE per process when a trace export lost events to the ring
    buffer — every later export is silently incomplete in the same way, so
    repeating the warning per mode would just bury the bench output."""
    if getattr(tracer, "dropped", 0) and not _warn_trace_dropped.warned:
        _warn_trace_dropped.warned = True
        print(f"# WARNING: trace ring buffer dropped {tracer.dropped} "
              "events — exports are incomplete; raise TraceConfig.capacity",
              file=sys.stderr)


_warn_trace_dropped.warned = False


def run_one(model, trace, n_slots: int, max_len: int, scheduler, *,
            device_loop: bool = True, decode_chunk: int = 1, backend=None,
            trace_cfg=None, telemetry_jsonl: str = ""):
    eng = InferenceEngine(
        model, EngineConfig(n_slots=n_slots, max_len=max_len,
                            device_loop=device_loop,
                            decode_chunk=decode_chunk,
                            trace=trace_cfg),
        scheduler=scheduler, backend=backend)
    for arrival, prompt, gen in trace:
        eng.submit(prompt, gen, arrival_step=arrival)
    eng.run()
    if trace_cfg is not None:
        eng.trace.export()          # the TraceConfig's out/chrome paths
        _warn_trace_dropped(eng.trace)
    if telemetry_jsonl:
        # one end-of-run snapshot per mode: the CI artifact shows the full
        # metric vector per (spec, mode) alongside the event traces
        TelemetryExporter(lambda: engine_sample(eng),
                          TelemetryConfig(jsonl=telemetry_jsonl)).sample()
    return eng.metrics.report()


def run_router(model, trace, n_slots: int, max_len: int, n_replicas: int,
               decode_chunk: int, mesh_shape=None):
    """The SAME trace through a single engine and through the router over
    n_replicas engines; returns (single_report, router_report). With a mesh
    shape, each replica owns a disjoint data-submesh (replica_meshes);
    max_waiting = n_slots bounds each replica's deque so overload exercises
    the spill-over path instead of queueing unboundedly."""
    from repro.serve import ReplicaRouter, ShardedBackend

    def mk_backend(i):
        if mesh_shape is None:
            return None
        return ShardedBackend(mesh=mk_backend.meshes[i])

    if mesh_shape is not None:
        from repro.launch import mesh as M
        mk_backend.meshes = M.replica_meshes(*mesh_shape, n_replicas)

    single = run_one(model, trace, n_slots, max_len, None,
                     decode_chunk=decode_chunk,
                     backend=mk_backend(0) if mesh_shape else None)
    cfg = EngineConfig(n_slots=n_slots, max_len=max_len,
                       decode_chunk=decode_chunk, max_waiting=n_slots)
    router = ReplicaRouter.build(model, cfg, n_replicas,
                                 backend_factory=mk_backend
                                 if mesh_shape else None)
    for arrival, prompt, gen in trace:
        router.submit(prompt, gen, arrival_step=arrival)
    router.run()
    return single, router.report()


def skinny_decode_trace(model, n_slots: int, max_len: int,
                        decode_chunk: int) -> dict:
    """Trace (don't run) one fused decode step with backend='interpret' and
    count packed + skinny-m dispatches baked into the compiled hot loop.

    The Pallas kernels only engage off the 'ref' backend; tracing is enough —
    both counters fire at trace time — so this stays cheap on CPU while
    asserting exactly what a TPU deployment would compile."""
    from repro.models import transformer as T
    decode = ST.make_decode_step(model.cfg, "interpret",
                                 n_steps=decode_chunk)
    caches = T.make_caches(model.cfg, n_slots, max_len)
    state = ST.make_decode_state(n_slots)
    PC.SKINNY_M_EVENTS.clear()
    with PackedRouteCounter() as counter:
        jax.jit(decode).lower(model.params, caches, state)
    events = list(PC.SKINNY_M_EVENTS)
    PC.SKINNY_M_EVENTS.clear()
    return {"apply_packed_hits": counter.hits,
            "skinny_m_dispatches": len(events),
            "skinny_kernels": sorted({e[0] for e in events})}


def timed_throughput(model, trace, n_slots: int, max_len: int, *,
                     tokens: int = 0, fresh_metrics: bool = False,
                     **cfg_kw):
    """Steady-state tokens/sec: the trace is replayed once to warm (jit
    compiles for prefill buckets + the decode/spec step — and, on a paged
    engine, the radix prefix tree — land here), then replayed again on the
    SAME engine and timed. Returns (tok/s, engine).

    tokens: fixed numerator per replay (e.g. ADMITTED prompt+generated
    tokens); 0 = decoded-token delta. fresh_metrics: swap in a clean
    ServeMetrics after the warm replay so the engine's report describes
    ONLY the timed steady state (the prefix-trace artifact wants hit/skip
    rates undiluted by cold-start misses; don't combine with speculate,
    whose engine seeds draft_flop_fraction into the metrics at init).
    Default keeps the speculative mode's documented behavior: metrics span
    both passes, wall timing only the second."""
    eng = InferenceEngine(model, EngineConfig(n_slots=n_slots,
                                              max_len=max_len, **cfg_kw))

    def replay(offset):
        for arrival, prompt, gen in trace:
            eng.submit(prompt, gen, arrival_step=arrival + offset)
        eng.run()

    replay(0)
    if fresh_metrics:
        from repro.serve import ServeMetrics
        eng.metrics = ServeMetrics()
    tok0 = eng.metrics.tokens_generated
    t0 = time.time()
    replay(eng.step_count + 1)
    dt = max(time.time() - t0, 1e-9)
    return (tokens or eng.metrics.tokens_generated - tok0) / dt, eng


def zipf_prefix_trace(n_requests: int, n_sys: int, sys_len: int,
                      sfx_range, gen_range, vocab: int,
                      mean_interarrival: float, seed: int):
    """Poisson arrivals whose prompts share Zipf-weighted system prefixes:
    rank-r system prompt drawn with p ~ 1/(r+1)^1.1 (a few prompts carry
    most of the traffic — the fleet shape prefix caching exists for), each
    followed by a short unique user suffix."""
    rng = np.random.default_rng(seed)
    sys_prompts = [rng.integers(0, vocab, sys_len) for _ in range(n_sys)]
    w = 1.0 / (1.0 + np.arange(n_sys)) ** 1.1
    w /= w.sum()
    t, out = 0.0, []
    for _ in range(n_requests):
        t += rng.exponential(mean_interarrival)
        k = int(rng.choice(n_sys, p=w))
        sfx = rng.integers(0, vocab, int(rng.integers(*sfx_range)))
        prompt = np.concatenate([sys_prompts[k], sfx])
        out.append((int(t), prompt, int(rng.integers(*gen_range))))
    return out


def run_prefix_trace(arch: str, n_requests: int, n_slots: int, seed: int,
                     page_size: int, out: str = "", gate: float = 1.3,
                     skip_gate: float = 0.5) -> bool:
    """Slab vs paged+prefix on one shared-prefix trace, warm-measured.

    The gated metric is ADMITTED tokens/sec — (prompt + generated) tokens
    per wall second — because that is the quantity redundant prefill caps:
    decode work is identical on both sides, so the ratio isolates the
    admission path. Both engines replay the trace once to warm (compiles
    AND the paged engine's radix tree land there — steady state is the
    claim), then swap in fresh metrics and are timed on a second identical
    replay, so the gates AND the JSON records describe only the steady
    state, undiluted by cold-start misses. Greedy outputs must match token
    for token; >= `skip_gate` of all prompt tokens must have been served
    from shared prefix pages rather than prefilled."""
    registry = ModelRegistry()
    model = registry.load(arch)
    # chat-fleet geometry: long shared system prompts, short unique user
    # suffixes, short replies — the regime where admission (prefill) is the
    # binding cost and prefix reuse pays. Both engines decode chunked
    # (K=4), so the decode side is identical and the ratio isolates the
    # prefill economy.
    sys_len, sfx_range, gen_range = 192, (4, 9), (4, 7)
    trace = zipf_prefix_trace(n_requests, 4, sys_len, sfx_range, gen_range,
                              model.cfg.vocab, 1.0, seed)
    max_len = sys_len + sfx_range[1] + gen_range[1] + 4
    pp = -(-max_len // page_size)
    # pool sized for live slots + the retained system-prefix working set —
    # the paged pool budgets pages against ACTUAL tokens, not slots*max_len
    n_pages = (n_slots + 4) * pp + 1
    prov = provenance(seed)
    admitted_tokens = sum(len(p) + g for _, p, g in trace)

    def timed(**kw):
        return timed_throughput(model, trace, n_slots, max_len,
                                tokens=admitted_tokens, fresh_metrics=True,
                                decode_chunk=4, **kw)

    slab_tps, slab_eng = timed()
    paged_tps, paged_eng = timed(page_size=page_size, n_pages=n_pages)
    same = all(
        slab_eng.requests[i].generated == paged_eng.requests[i].generated
        for i in slab_eng.requests)
    rep, rep_s = paged_eng.metrics.report(), slab_eng.metrics.report()
    ratio = paged_tps / max(1e-9, slab_tps)
    skip = rep["prefill_skip_fraction"]
    ok = same and ratio >= gate and skip >= skip_gate
    flops_per_tok = 2.0 * model.cfg.active_param_count()
    print(f"# prefix-trace[{arch}] P={page_size}: paged+prefix "
          f"{paged_tps:.1f} admitted tok/s vs slab {slab_tps:.1f} "
          f"({ratio:.2f}x, gate >= {gate:g}x) "
          f"[{'PASS' if ratio >= gate else 'FAIL'}] | prefill skipped "
          f"{int(rep['prefill_tokens_skipped'])} toks ({skip:.2f}, gate >= "
          f"{skip_gate:g}) [{'PASS' if skip >= skip_gate else 'FAIL'}] | "
          f"token-identical [{'PASS' if same else 'FAIL'}] | hit rate "
          f"{rep['prefix_hit_rate']:.2f}, pages "
          f"{rep['pages_in_use']:.1f}/{paged_eng.pool.n_usable_pages} "
          f"({rep['page_occupancy']:.2f} full), pool waits "
          f"{int(rep['pool_waits'])}")
    records = [{
        "arch": arch, "mode": mode, "page_size": ps,
        "n_pages": np_, "mesh_shape": [1, 1], "n_replicas": 1, **prov,
        "admitted_tok_s": tps, "wall_tok_s": r["tok_per_s"],
        "tokens_generated": r["tokens_generated"],
        "decode_steps": r["decode_steps"],
        "tokens_per_dispatch": r["tokens_per_dispatch"],
        # every record reports the prefill economy + pool pressure, the
        # slab side as the zero baseline
        "prefix_hit_rate": r["prefix_hit_rate"],
        "prefill_tokens_skipped": r["prefill_tokens_skipped"],
        "prefill_skip_fraction": r["prefill_skip_fraction"],
        "prefill_flops_saved": flops_per_tok * r["prefill_tokens_skipped"],
        "pages_in_use": r["pages_in_use"],
        "page_occupancy": r["page_occupancy"],
        "pool_waits": r["pool_waits"],
        "paged_vs_slab_admitted": ratio,
    } for mode, ps, np_, tps, r in (
        ("slab", 0, 0, slab_tps, rep_s),
        ("paged-prefix", page_size, n_pages, paged_tps, rep))]
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump({"arch": arch, "n_slots": n_slots,
                       "page_size": page_size, "n_pages": n_pages,
                       "gate": gate, "skip_gate": skip_gate,
                       "paged_vs_slab_admitted": ratio,
                       "prefill_skip_fraction": skip, **prov,
                       "records": records}, f, indent=2)
        print(f"# wrote {out} ({len(records)} records)")
    print(f"# serve_bench --prefix-trace: {'PASS' if ok else 'FAIL'} — "
          f"paged+prefix >= {gate:g}x admitted tok/s, >= {skip_gate:.0%} "
          "prefill tokens skipped, greedy token-identical")
    return ok


def conversation_turns(n_conversations: int, n_turns: int, utt_range,
                       gen_range, vocab: int, seed: int):
    """Per-conversation turn schedules [(utterance, gen_len), ...]. Only
    the NEW user text per turn is drawn here — each turn's full prompt is
    assembled live from the engine's own prior replies, because a chat's
    turn-t prompt contains the turn-(t-1) output."""
    rng = np.random.default_rng(seed)
    return [[(rng.integers(0, vocab, int(rng.integers(*utt_range))),
              int(rng.integers(*gen_range))) for _ in range(n_turns)]
            for _ in range(n_conversations)]


def run_conversation_trace(arch: str, n_conversations: int, n_turns: int,
                           n_slots: int, seed: int, page_size: int,
                           out: str = "", skip_gate: float = 0.7) -> bool:
    """Multi-turn chats resuming their own history through the paged
    native engine, vs a slab engine fed the same per-turn prompts.

    The chat shape: short user follow-ups, longer assistant replies —
    so by turn t the prompt is dominated by the prior conversation. With
    whole-conversation publishing (prompt + GENERATED tokens land in the
    prefix tree at finish) every full page of the prior exchange is
    served from cache; prompt-only publishing would re-prefill every
    past reply. Gates, all deterministic: >= `skip_gate` of all prompt
    tokens skipped at prefill; ZERO gather/scatter events on the
    page-table-native decode path with `gather_bytes_avoided` exactly
    2*slab_view_bytes per dispatch; greedy token-identity vs the slab."""
    from repro.serve.paging import GATHER_EVENTS
    registry = ModelRegistry()
    model = registry.load(arch)
    # follow-ups much shorter than replies: the regime where reusing the
    # whole conversation (not just its prompts) carries the economics
    utt_range, gen_range = (4, 9), (18, 25)
    convs = conversation_turns(n_conversations, n_turns, utt_range,
                               gen_range, model.cfg.vocab, seed)
    max_len = n_turns * (utt_range[1] + gen_range[1]) + 8
    pp = -(-max_len // page_size)
    # every retired conversation stays resident in the prefix tree until
    # its last turn, plus the live slots' working pages
    n_pages = (n_conversations + n_slots) * pp + 1
    prov = provenance(seed)

    eng = InferenceEngine(model, EngineConfig(
        n_slots=n_slots, max_len=max_len, decode_chunk=4,
        page_size=page_size, n_pages=n_pages))
    GATHER_EVENTS.clear()
    histories = [np.zeros(0, np.int32) for _ in convs]
    prompts, paged_reqs = [], []
    t0 = time.time()
    for t in range(n_turns):
        round_reqs = []
        for c, turns in enumerate(convs):
            utt, gen = turns[t]
            prompt = np.concatenate([histories[c], utt]).astype(np.int32)
            prompts.append((prompt, gen))
            round_reqs.append((c, eng.submit(prompt, gen)))
        eng.run()                     # turn t finishes fleet-wide before
        for c, r in round_reqs:       # turn t+1 resumes the conversation
            histories[c] = np.concatenate(
                [histories[c], convs[c][t][0],
                 np.asarray(r.generated, np.int32)]).astype(np.int32)
            paged_reqs.append(r)
    wall = max(time.time() - t0, 1e-9)
    rep = eng.metrics.report()

    # slab oracle: the SAME per-turn prompts (histories included), no
    # paging — greedy outputs must match token for token
    slab_eng = InferenceEngine(model, EngineConfig(n_slots=n_slots,
                                                   max_len=max_len,
                                                   decode_chunk=4))
    slab_reqs = [slab_eng.submit(p, g) for p, g in prompts]
    slab_eng.run()
    same = all(pr.generated == sr.generated
               for pr, sr in zip(paged_reqs, slab_reqs))
    rep_s = slab_eng.metrics.report()

    skip = rep["prefill_skip_fraction"]
    gather_events = len(GATHER_EVENTS)
    avoided = rep["gather_bytes_avoided"]
    avoided_exact = avoided == eng.backend.gather_bytes_per_dispatch() \
        * rep["decode_steps"]
    native_ok = gather_events == 0 and avoided > 0 and avoided_exact
    ok = same and skip >= skip_gate and native_ok
    print(f"# conversation-trace[{arch}] {n_conversations} chats x "
          f"{n_turns} turns, P={page_size}: prefill skipped "
          f"{int(rep['prefill_tokens_skipped'])} of "
          f"{eng.metrics.prefill_tokens_skipped + eng.metrics.prefill_tokens_computed} "
          f"prompt toks ({skip:.2f}, gate >= {skip_gate:g}) "
          f"[{'PASS' if skip >= skip_gate else 'FAIL'}] | conversation "
          f"hits {int(rep['conversation_prefix_hits'])}, tokens reused "
          f"{int(rep['conversation_tokens_reused'])} | gather events "
          f"{gather_events}, avoided {avoided / 1e6:.2f} MB over "
          f"{int(rep['decode_steps'])} dispatches "
          f"[{'PASS' if native_ok else 'FAIL'} == 0 events, exact ledger]"
          f" | token-identical [{'PASS' if same else 'FAIL'}] | "
          f"{rep['tokens_generated'] / wall:.1f} tok/s wall, pages "
          f"{rep['pages_in_use']:.1f}/{eng.pool.n_usable_pages}, pool "
          f"waits {int(rep['pool_waits'])}")
    common = {"arch": arch, "decode_chunk": 4, "mesh_shape": [1, 1],
              "n_replicas": 1, "n_conversations": n_conversations,
              "n_turns": n_turns, **prov}
    records = [
        {**common, "mode": "conversation-native", "page_size": page_size,
         "n_pages": n_pages,
         "tokens_generated": rep["tokens_generated"],
         "decode_steps": rep["decode_steps"],
         "tokens_per_dispatch": rep["tokens_per_dispatch"],
         "wall_tok_s": rep["tok_per_s"],
         "prefix_hit_rate": rep["prefix_hit_rate"],
         "prefill_tokens_skipped": rep["prefill_tokens_skipped"],
         "prefill_skip_fraction": skip,
         "conversation_prefix_hits": rep["conversation_prefix_hits"],
         "conversation_tokens_reused": rep["conversation_tokens_reused"],
         "gather_bytes_avoided": avoided,
         "decode_gather_events": float(gather_events),
         "pages_in_use": rep["pages_in_use"],
         "page_occupancy": rep["page_occupancy"],
         "pool_waits": rep["pool_waits"]},
        {**common, "mode": "slab", "page_size": 0, "n_pages": 0,
         "tokens_generated": rep_s["tokens_generated"],
         "decode_steps": rep_s["decode_steps"],
         "tokens_per_dispatch": rep_s["tokens_per_dispatch"],
         "wall_tok_s": rep_s["tok_per_s"],
         "prefill_tokens_skipped": rep_s["prefill_tokens_skipped"],
         "prefill_skip_fraction": rep_s["prefill_skip_fraction"],
         "gather_bytes_avoided": rep_s["gather_bytes_avoided"]}]
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump({"arch": arch, "n_slots": n_slots,
                       "n_conversations": n_conversations,
                       "n_turns": n_turns, "page_size": page_size,
                       "n_pages": n_pages, "skip_gate": skip_gate,
                       "prefill_skip_fraction": skip,
                       "gather_bytes_avoided": avoided, **prov,
                       "records": records}, f, indent=2)
        print(f"# wrote {out} ({len(records)} records)")
    print(f"# serve_bench --conversation-trace: {'PASS' if ok else 'FAIL'}"
          f" — >= {skip_gate:.0%} prompt tokens skipped across multi-turn "
          "chats, zero decode gather/scatter, greedy token-identical")
    return ok


def run_overload_trace(arch: str, n_requests: int, n_slots: int, seed: int,
                       out: str = "", gate: float = 1.2,
                       deadline_steps: int = 0) -> bool:
    """Resilient vs non-degrading engine under 2x saturating Poisson load.

    One trace whose token arrival rate is TWICE the chunk-1 service
    capacity (n_slots tokens/step) replayed through (a) a BASELINE engine
    that admits everything and serves it however late, and (b) a RESILIENT
    engine with per-request deadlines (admission-time doom shedding +
    in-flight expiry), the QoS tier ladder, and bounded pool-wait retries.

    The gated metric is GOODPUT per engine step — generated tokens of
    completions that finished BY their deadline, per step on the
    deterministic engine-step clock. Under 2x load the baseline's queue
    grows without bound, so late admissions complete far past deadline:
    their tokens count zero while they still consumed slots. The resilient
    engine sheds exactly that doomed work at admission, so surviving
    requests run sooner and finish inside their deadline — the gate is
    resilient >= `gate`x baseline goodput/step, plus ZERO deadline-missed
    completions served on the resilient side (at decode_chunk=1 the
    per-step doom check is exact: any request not shed finishes in time).
    Wall tok/s is reported ungated (tier demotion's wall benefit needs
    the packed Pallas kernels, which engage off the ref CPU backend)."""
    from repro.serve import QoSConfig
    registry = ModelRegistry()
    tiers = (DraftSpec.from_args(8, 0.5, 0), DraftSpec.from_args(8, 0.75, 0))
    model = registry.load(arch, tier_specs=tiers)
    prompt_range, gen_range = (4, 12), (8, 17)
    mean_gen = (gen_range[0] + gen_range[1] - 1) / 2.0
    # 2x saturating: mean token arrival rate = 2 * the n_slots tok/step
    # that a full chunk-1 slab can serve
    trace = poisson_trace(n_requests, mean_gen / (2.0 * n_slots),
                          prompt_range, gen_range, model.cfg.vocab, seed)
    max_len = model.cfg.n_img_tokens + prompt_range[1] + gen_range[1] + 8
    # tight enough that the baseline's growing backlog dooms the later
    # arrivals (queue wait alone exceeds it), loose enough that an
    # immediately-admitted request finishes comfortably inside it
    D = deadline_steps or int(2 * mean_gen)
    prov = provenance(seed)

    def run_side(resilient: bool):
        cfg = EngineConfig(
            n_slots=n_slots, max_len=max_len, decode_chunk=1,
            qos=QoSConfig(demote_depth=4, promote_depth=1, hysteresis=2)
            if resilient else None,
            pool_wait_retries=3 if resilient else None)
        eng = InferenceEngine(model, cfg)
        t0 = time.time()
        for arrival, prompt, gen in trace:
            eng.submit(prompt, gen, arrival_step=arrival,
                       deadline_steps=D if resilient else None)
        eng.run()
        dt = max(time.time() - t0, 1e-9)
        met_tokens, served, late = 0, 0, 0
        for r in eng.requests.values():
            if r.state != "done":
                continue
            fin = eng.metrics.records[r.id].finish_step
            if fin <= r.arrival_step + D:
                met_tokens += len(r.generated)
                served += 1
            else:
                late += 1
        rep = eng.metrics.report()
        return {"engine": eng, "report": rep, "wall_s": dt,
                "goodput_tokens": met_tokens,
                "goodput_tok_per_step": met_tokens / max(1, eng.step_count),
                "served_in_deadline": served,
                "deadline_missed_completions": late,
                "steps": eng.step_count}

    base = run_side(False)
    res = run_side(True)
    ratio = res["goodput_tok_per_step"] / max(1e-9,
                                              base["goodput_tok_per_step"])
    zero_late = res["deadline_missed_completions"] == 0
    ok = ratio >= gate and zero_late
    rep_r, rep_b = res["report"], base["report"]
    print(f"# overload-trace[{arch}] 2x load, D={D} steps: resilient "
          f"{res['goodput_tok_per_step']:.2f} goodput tok/step vs baseline "
          f"{base['goodput_tok_per_step']:.2f} ({ratio:.2f}x, gate >= "
          f"{gate:g}x) [{'PASS' if ratio >= gate else 'FAIL'}] | late "
          f"completions served {res['deadline_missed_completions']} "
          f"(baseline {base['deadline_missed_completions']}) "
          f"[{'PASS' if zero_late else 'FAIL'} == 0] | shed "
          f"{int(rep_r['shed'])} (deadline {int(rep_r['deadline_missed'])}, "
          f"pool {int(rep_r['shed_pool_pressure'])}), demotions "
          f"{int(rep_r['tier_demotions'])} | wall "
          f"{rep_r['tokens_generated'] / res['wall_s']:.1f} vs "
          f"{rep_b['tokens_generated'] / base['wall_s']:.1f} tok/s "
          "(reported not gated)")
    records = [{
        "arch": arch, "mode": mode, "decode_chunk": 1,
        "deadline_steps": D, "mesh_shape": [1, 1], "n_replicas": 1, **prov,
        "tokens_generated": r["tokens_generated"],
        "decode_steps": r["decode_steps"],
        "goodput_tokens": side["goodput_tokens"],
        "goodput_tok_per_step": side["goodput_tok_per_step"],
        "served_in_deadline": side["served_in_deadline"],
        "deadline_missed_completions": side["deadline_missed_completions"],
        "shed": r["shed"], "deadline_missed": r["deadline_missed"],
        "shed_pool_pressure": r["shed_pool_pressure"],
        "tier_demotions": r["tier_demotions"],
        "tier_promotions": r["tier_promotions"],
        "wall_tok_s": r["tokens_generated"] / side["wall_s"],
        "resilient_vs_baseline_goodput": ratio,
    } for mode, side, r in (("baseline", base, rep_b),
                            ("resilient", res, rep_r))]
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump({"arch": arch, "n_slots": n_slots,
                       "deadline_steps": D, "gate": gate,
                       "resilient_vs_baseline_goodput": ratio, **prov,
                       "records": records}, f, indent=2)
        print(f"# wrote {out} ({len(records)} records)")
    print(f"# serve_bench --overload-trace: {'PASS' if ok else 'FAIL'} — "
          f"resilient >= {gate:g}x goodput tok/step under 2x load, zero "
          "deadline-missed completions served")
    return ok


def run_fleet_trace(arch: str, n_requests: int, n_slots: int, seed: int,
                    n_processes: int = 2, out: str = "", gate: float = 1.5,
                    decode_chunk: int = 4) -> bool:
    """Fleet mode (PR 10): one dense Poisson trace through a single
    in-process engine and through an N-process subprocess fleet
    (launch.fleet workers + serve.FleetRouter over the control plane).

    Gates:
      * TOKEN IDENTITY: every request's fleet output equals the single
        engine's, greedy, across the process boundary — the wire protocol
        and the per-process DistributedBackend meshes change placement,
        never tokens;
      * THROUGHPUT: tokens per FLEET step (completed tokens over the
        SLOWEST process's engine steps — processes decode concurrently,
        so the max is the wall-clock analog on the deterministic step
        clock) >= `gate`x the single engine's tokens per step.

    Wall tok/s is reported ungated (subprocess pacing + control-plane
    sleeps dominate on CPU; the step-clock ratio is the load-bearing
    number)."""
    from repro.launch.fleet import spawn_fleet

    registry = ModelRegistry()
    model = registry.load(arch)
    prompt_range, gen_range = (4, 12), (4, 10)
    # dense means QUEUE-limited, not arrival-limited: arrivals must pile
    # onto the single engine far faster than its slots drain them, or
    # both sides just ride the arrival clock and the ratio pins at 1.0
    dense = poisson_trace(max(n_requests, 12 * n_processes), 0.25,
                          prompt_range, gen_range, model.cfg.vocab, seed)
    max_len = model.cfg.n_img_tokens + prompt_range[1] + gen_range[1] + 8
    prov = provenance(seed)

    eng = InferenceEngine(model, EngineConfig(
        n_slots=n_slots, max_len=max_len, decode_chunk=decode_chunk))
    ref = [eng.submit(p, g, arrival_step=a) for a, p, g in dense]
    eng.run()
    single = eng.metrics.report()
    ref_toks = [list(r.generated) for r in ref]

    t0 = time.time()
    with spawn_fleet(n_processes, arch=arch, n_slots=n_slots,
                     max_len=max_len, decode_chunk=decode_chunk) as fleet:
        reqs = [fleet.router.submit(p, g, arrival_step=a)
                for a, p, g in dense]
        fleet.drive()
        fleet.router.stop()
        routed = fleet.router.report()
    wall = max(time.time() - t0, 1e-9)

    identical = [list(r.tokens) for r in reqs] == ref_toks
    ratio = routed["tokens_per_fleet_step"] / \
        max(1e-9, single["tokens_per_step"])
    win_ratio = ratio >= gate
    ok = identical and win_ratio
    print(f"# fleet[{arch}] {n_processes} processes: "
          f"{routed['tokens_per_fleet_step']:.2f} tok/fleet-step vs single "
          f"{single['tokens_per_step']:.2f} tok/step ({ratio:.2f}x, gate "
          f">= {gate:g}x) [{'PASS' if win_ratio else 'FAIL'}] | "
          f"token-identical [{'PASS' if identical else 'FAIL'}] | "
          f"failovers {int(routed['fleet_failovers'])}, dead "
          f"{int(routed['processes_dead'])}, overflowed "
          f"{int(routed['fleet_overflowed'])} | wall "
          f"{routed['fleet_tokens'] / wall:.1f} tok/s (reported not gated)")
    records = [{
        "arch": arch, "spec": "dense", "mode": "fleet",
        "decode_chunk": decode_chunk, "mesh_shape": [1, 1],
        "n_replicas": 1, "n_processes": n_processes, **prov,
        "fleet_tokens": routed["fleet_tokens"],
        "fleet_steps": routed["fleet_steps"],
        "fleet_requests_completed": routed["fleet_requests_completed"],
        "tokens_per_fleet_step": routed["tokens_per_fleet_step"],
        "fleet_failovers": routed["fleet_failovers"],
        "fleet_overflowed": routed["fleet_overflowed"],
        "resurrections_ignored": routed["resurrections_ignored"],
        "single_tokens_per_step": single["tokens_per_step"],
        "fleet_vs_single": ratio,
        "token_identical": float(identical),
        "wall_tok_s": routed["fleet_tokens"] / wall,
    }]
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump({"arch": arch, "n_slots": n_slots,
                       "decode_chunk": decode_chunk,
                       "n_processes": n_processes, "gate": gate,
                       "fleet_vs_single": ratio, **prov,
                       "records": records}, f, indent=2)
        print(f"# wrote {out} ({len(records)} records)")
    print(f"# serve_bench --fleet: {'PASS' if ok else 'FAIL'} — fleet >= "
          f"{gate:g}x single tok/step at {n_processes} processes, "
          "token-identical")
    return ok


def run_ledger_trace(arch: str, n_requests: int, n_slots: int, seed: int,
                     out: str = "", k_block: int = 8,
                     quality_every: int = 2) -> bool:
    """Ineffectual-work ledger mode (PR 9): the SAME Poisson trace replayed
    twice through a ledger-instrumented device-loop engine.

    Gates, all deterministic on the step clock:
      * measured activation zeros > 0 — the arch default (nemotron's
        squared-ReLU MLP) makes real zeros, and the ledger must see them;
      * BIT-DETERMINISM: every counter — including the full per-layer
        per-group zero histogram, collapsed to `hist_checksum` — is
        identical across the two runs;
      * NO EXTRA HOST SYNCS: host_syncs_decode == decode dispatches (the
        ledger drains inside the existing token device_get);
      * quality probes: tier-0 shadow prefill of a single-tier engine must
        agree with itself exactly (top1 rate 1.0, logit MAD 0.0).

    The JSON record carries the gated counters plus an UNGATED roofline
    join (analysis.roofline over the tracer's dispatch walls) — wall time
    is machine-dependent, the counters are not.
    """
    from repro.analysis import roofline as RL
    from repro.serve import LedgerConfig

    registry = ModelRegistry()
    model = registry.load(arch)
    prompt_range, gen_range = (6, 14), (10, 18)
    trace = poisson_trace(n_requests, 1.5, prompt_range, gen_range,
                          model.cfg.vocab, seed)
    max_len = prompt_range[1] + gen_range[1] + 8
    led_cfg = LedgerConfig(threshold=0.0, group=8, k_block=k_block,
                           quality_every=quality_every)
    prov = provenance(seed)

    def one_run():
        eng = InferenceEngine(model, EngineConfig(
            n_slots=n_slots, max_len=max_len, decode_chunk=4,
            ledger=led_cfg, trace=TraceConfig()))
        for arrival, prompt, gen in trace:
            eng.submit(prompt, gen, arrival_step=arrival)
        t0 = time.time()
        eng.run()
        wall = max(time.time() - t0, 1e-9)
        _warn_trace_dropped(eng.trace)
        return eng, eng.metrics.report(), eng.ledger.summary(), wall

    eng1, rep1, sum1, wall1 = one_run()
    _, rep2, sum2, _ = one_run()

    gated = ("act_probe_elems", "act_zeros", "act_near_zeros",
             "act_kblocks", "act_dead_kblocks", "act_hist_checksum")
    deterministic = all(sum1[k] == sum2[k] for k in gated) \
        and sum1["hist"] == sum2["hist"]
    zeros_ok = sum1["act_zeros"] > 0
    syncs_ok = rep1["host_syncs_decode"] == rep1["decode_steps"] \
        and rep1["ledger_dispatches"] == rep1["decode_steps"]
    quality_ok = rep1["quality_probes"] > 0 \
        and rep1["quality_top1_rate"] == 1.0 \
        and rep1["quality_logit_mad"] == 0.0
    ok = deterministic and zeros_ok and syncs_ok and quality_ok

    # ungated roofline attribution: join the tracer's dispatch walls with
    # the ledger counter tracks drained at the same steps
    dispatch_rows = RL.dispatch_rooflines(list(eng1.trace.events))
    replica = RL.replica_roofline(sum1, wall1)

    zero_frac = sum1["act_zeros"] / max(sum1["act_probe_elems"], 1.0)
    print(f"# ledger-trace[{arch}] kb={k_block}: "
          f"{int(sum1['act_zeros'])} zeros / "
          f"{int(sum1['act_probe_elems'])} probed elems "
          f"({zero_frac:.3f}) [{'PASS' if zeros_ok else 'FAIL'} > 0] | "
          f"hist checksum {sum1['act_hist_checksum']:.0f} "
          f"[{'PASS' if deterministic else 'FAIL'} bit-identical x2] | "
          f"syncs {int(rep1['host_syncs_decode'])} == dispatches "
          f"{int(rep1['decode_steps'])} "
          f"[{'PASS' if syncs_ok else 'FAIL'}] | quality "
          f"{int(rep1['quality_probes'])} probes top1 "
          f"{rep1['quality_top1_rate']:.2f} mad "
          f"{rep1['quality_logit_mad']:.3g} "
          f"[{'PASS' if quality_ok else 'FAIL'}] | eff flops "
          f"{rep1['effective_flop_fraction']:.3f}, dead k-blocks "
          f"{int(sum1['act_dead_kblocks'])}, "
          f"skip bound {replica['skip_speedup_bound']:.2f}x "
          f"({replica['dense']['bound']}-bound)")
    records = [{
        "arch": arch, "mode": "ledger", "n_requests": n_requests,
        "n_slots": n_slots, "decode_chunk": 4, "k_block": k_block,
        "group": led_cfg.group, "quality_every": quality_every,
        "mesh_shape": [1, 1], "n_replicas": 1, **prov,
        "tokens_generated": rep1["tokens_generated"],
        "decode_steps": rep1["decode_steps"],
        "ledger_dispatches": rep1["ledger_dispatches"],
        "host_syncs_decode": rep1["host_syncs_decode"],
        "act_probe_elems": sum1["act_probe_elems"],
        "act_zeros": sum1["act_zeros"],
        "act_near_zeros": sum1["act_near_zeros"],
        "act_kblocks": sum1["act_kblocks"],
        "act_dead_kblocks": sum1["act_dead_kblocks"],
        "act_hist_checksum": sum1["act_hist_checksum"],
        "act_zero_fraction": zero_frac,
        "effective_flop_fraction": rep1["effective_flop_fraction"],
        "quality_probes": rep1["quality_probes"],
        "quality_top1_rate": rep1["quality_top1_rate"],
        "quality_logit_mad": rep1["quality_logit_mad"],
        "trace_dropped": rep1["trace_dropped"],
    }]
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump({"arch": arch, "n_slots": n_slots,
                       "n_requests": n_requests, "k_block": k_block,
                       "quality_every": quality_every, **prov,
                       "deterministic": deterministic,
                       "roofline": {
                           "replica": replica,
                           "n_dispatch_rows": len(dispatch_rows),
                           "dispatches": dispatch_rows[:16]},
                       "zero_fraction_by_layer":
                           sum1["zero_fraction_by_layer"],
                       "records": records}, f, indent=2)
        print(f"# wrote {out} ({len(records)} records)")
    print(f"# serve_bench --ledger-trace: {'PASS' if ok else 'FAIL'} — "
          "activation zeros measured, counters bit-deterministic across "
          "two runs, ledger drains inside the existing dispatch sync, "
          "tier-0 quality shadow exact")
    return ok


def run_speculative(arch: str, n_requests: int, n_slots: int, seed: int,
                    speculate: int, draft: DraftSpec, out: str = "",
                    gate: float = 1.2) -> bool:
    """Plain device loop vs speculative decode (speculate=K) with a
    self-draft, one trace, warm-measured.

    The PLAIN side runs decode_chunk=1: `speculate` REPLACES the chunk knob
    (the engine refuses both), so the apples-to-apples question is "tokens
    committed per decode dispatch / host sync" against the un-chunked
    device loop. That ratio is the GATE (>= `gate`x at K=4 in CI) because
    it is deterministic — tokens and dispatches are integers, immune to CI
    timing noise — and it is the economy speculation buys on every
    substrate. Wall tokens/sec for both engines is reported alongside,
    ungated: on the CPU *reference* backend the draft re-pack executes at
    full-precision cost (per-step dequantization; the packed Pallas
    kernels that realize the draft's FLOP discount engage off-ref), so
    wall parity there is substrate-limited, not a property of the design.
    Greedy token-identity between the two engines is also gated — the
    speedup must not change a single token."""
    registry = ModelRegistry()
    model = registry.load(arch, draft_spec=draft)
    prompt_range, gen_range = (4, 16), (12, 24)
    trace = poisson_trace(n_requests, 1.5, prompt_range, gen_range,
                          model.cfg.vocab, seed)
    max_len = model.cfg.n_img_tokens + prompt_range[1] + gen_range[1] + 8
    prov = provenance(seed)

    plain_tps, plain_eng = timed_throughput(model, trace, n_slots, max_len,
                                            decode_chunk=1)
    spec_tps, spec_eng = timed_throughput(model, trace, n_slots, max_len,
                                          speculate=speculate)
    same = all(
        plain_eng.requests[i].generated == spec_eng.requests[i].generated
        for i in plain_eng.requests)
    rep = spec_eng.metrics.report()
    rep_p = plain_eng.metrics.report()
    ratio = rep["tokens_per_dispatch"] / max(1e-9,
                                             rep_p["tokens_per_dispatch"])
    wall_ratio = spec_tps / max(1e-9, plain_tps)
    ok = same and ratio >= gate
    print(f"# speculative[{draft.tag}] K={speculate}: "
          f"{rep['tokens_per_dispatch']:.2f} tok/dispatch vs plain loop "
          f"{rep_p['tokens_per_dispatch']:.2f} ({ratio:.2f}x, gate >= "
          f"{gate:.2f}x) [{'PASS' if ratio >= gate else 'FAIL'}] | "
          f"token-identical [{'PASS' if same else 'FAIL'}] | accept "
          f"{rep['acceptance_rate']:.3f}, rolled back "
          f"{int(rep['draft_rolled_back'])}, draft/verify flops "
          f"{rep['draft_verify_flop_ratio']:.2f} | wall {spec_tps:.1f} vs "
          f"{plain_tps:.1f} tok/s ({wall_ratio:.2f}x, reported not gated: "
          f"ref backend runs the draft at full-precision cost)")
    records = [{
        "arch": arch, "mode": mode, "speculate": speculate,
        "draft_spec": draft.tag if mode == "speculative" else None,
        "mesh_shape": [1, 1], "n_replicas": 1, **prov,
        "wall_tok_s": tps,
        "tokens_generated": r["tokens_generated"],
        "decode_steps": r["decode_steps"],
        "tokens_per_dispatch": r["tokens_per_dispatch"],
        "acceptance_rate": r["acceptance_rate"],
        "draft_rolled_back": r["draft_rolled_back"],
        "draft_verify_flop_ratio": r["draft_verify_flop_ratio"],
        "spec_vs_plain_dispatch": ratio,
        "spec_vs_plain_wall": wall_ratio,
    } for mode, tps, r in (("device", plain_tps, rep_p),
                           ("speculative", spec_tps, rep))]
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump({"arch": arch, "n_slots": n_slots,
                       "speculate": speculate, "draft_spec": draft.tag,
                       "gate": gate, "spec_vs_plain_dispatch": ratio,
                       "spec_vs_plain_wall": wall_ratio, **prov,
                       "records": records}, f, indent=2)
        print(f"# wrote {out} ({len(records)} records)")
    print(f"# serve_bench --speculate: {'PASS' if ok else 'FAIL'} — "
          f"speculative >= {gate:g}x tokens/dispatch vs plain device loop, "
          "greedy token-identical")
    return ok


def run(arch: str = "h2o-danube-1.8b", n_requests: int = 16,
        n_slots: int = 4, mean_interarrival: float = 2.0,
        prompt_range=(4, 24), gen_range=(8, 24), seed: int = 0,
        smoke: bool = False, decode_chunk: int = 4,
        n_replicas: int = 1, mesh_shape=None,
        out: str = "", trace_dir: str = "") -> bool:
    registry = ModelRegistry()
    csv = CSV(["spec", "mode", "toks", "dispatches", "tok_per_step",
               "occupancy", "tok_per_s_wall", "syncs_per_tok",
               "lat_p50_steps", "lat_p99_steps", "packed_MB", "compression",
               "apply_packed_hits"])
    specs = [(n, s) for n, s in SPECS if not smoke or n in SMOKE_SPECS]
    ok = True
    records = []
    mesh_list = list(mesh_shape) if mesh_shape else [1, 1]
    prov = provenance(seed)

    def record(spec_name, mode_name, rep, k, **extra):
        records.append({
            "arch": arch, "spec": spec_name, "mode": mode_name,
            "decode_chunk": k, **prov,
            # per-record placement: only sharded/router modes ran on the
            # mesh; host/device/static are the local-placement baselines
            "mesh_shape": mesh_list if mode_name in ("sharded", "router")
            else [1, 1],
            "n_replicas": extra.pop("n_replicas", 1),
            "tokens_per_step": rep.get("tokens_per_step", 0.0),
            # deterministic step-clock integers: QoR gates these EXACTLY
            # (no EOS in the synthetic traces, so every request generates
            # its full budget on any platform)
            "tokens_generated": rep["tokens_generated"],
            "decode_steps": rep["decode_steps"],
            "wall_tok_s": rep["tok_per_s"],
            "host_syncs_per_token": rep["host_syncs_per_token"],
            "host_syncs_per_dispatch": rep["host_syncs_decode"]
            / max(1.0, rep["decode_steps"]),
            "mean_occupancy": rep["mean_occupancy"],
            "latency_steps_p50": rep["latency_steps_p50"],
            **extra})

    for spec_name, spec in specs:
        model = registry.load(arch, spec, seed=seed)
        cfg = model.cfg
        trace = poisson_trace(n_requests, mean_interarrival, prompt_range,
                              gen_range, cfg.vocab, seed)
        max_len = cfg.n_img_tokens + prompt_range[1] + gen_range[1] + 8
        modes = [
            ("host", dict(scheduler=None, device_loop=False, decode_chunk=1)),
            ("device", dict(scheduler=None, device_loop=True,
                            decode_chunk=decode_chunk)),
            ("static", dict(scheduler=StaticScheduler(), device_loop=True,
                            decode_chunk=decode_chunk)),
        ]
        if mesh_shape is not None:
            from repro.serve import ShardedBackend
            modes.append(("sharded", dict(
                scheduler=None, device_loop=True, decode_chunk=decode_chunk,
                backend=lambda: ShardedBackend(mesh_shape=mesh_shape))))
        results = {}
        for mode_name, kw in modes:
            bk = kw.get("backend")
            # --trace-dir: each (spec, mode) run records the full event
            # trace; tracing is otherwise OFF (the recorded numbers ARE
            # the untraced numbers the QoR goldens gate)
            tcfg = TraceConfig(
                out=os.path.join(trace_dir,
                                 f"{spec_name}_{mode_name}.trace.jsonl"),
                chrome=os.path.join(trace_dir,
                                    f"{spec_name}_{mode_name}.chrome.json")) \
                if trace_dir else None
            with PackedRouteCounter() as counter:
                rep = run_one(model, trace, n_slots, max_len, kw["scheduler"],
                              device_loop=kw["device_loop"],
                              decode_chunk=kw["decode_chunk"],
                              backend=bk() if bk else None,
                              trace_cfg=tcfg,
                              telemetry_jsonl=os.path.join(
                                  trace_dir, "telemetry.jsonl")
                              if trace_dir else "")
            results[mode_name] = rep
            csv.row(spec_name, mode_name, int(rep["tokens_generated"]),
                    int(rep["decode_steps"]), rep["tokens_per_step"],
                    rep["mean_occupancy"], rep["tok_per_s"],
                    rep["host_syncs_per_token"],
                    rep["latency_steps_p50"], rep["latency_steps_p99"],
                    model.packed_bytes / 1e6, model.compression, counter.hits)
            record(spec_name, mode_name, rep, kw["decode_chunk"])
            if counter.hits == 0:
                print(f"# FAIL {spec_name}/{mode_name}: decode did not "
                      "route through apply_packed")
                ok = False
        if mesh_shape is not None:
            # placement must not change the traffic the trace produces:
            # the sharded engine emits exactly as many tokens per dispatch
            # as the local device loop on the same trace (greedy outputs
            # are token-identical; tested leaf-for-leaf in test_serve_*).
            dev, shd = results["device"], results["sharded"]
            win_mesh = (shd["tokens_generated"] == dev["tokens_generated"]
                        and shd["decode_steps"] == dev["decode_steps"])
            ok = ok and win_mesh
            print(f"# {spec_name}: sharded mesh {mesh_list} "
                  f"{shd['tokens_per_step']:.2f} tok/step over "
                  f"{int(shd['decode_steps'])} dispatches "
                  f"[{'PASS' if win_mesh else 'FAIL'}]")
        host, dev, stat = (results[m] for m in ("host", "device", "static"))
        win_sched = dev["tokens_per_step"] >= stat["tokens_per_step"]
        # structural invariant (occupancy-independent): exactly ONE decode
        # sync per dispatch, i.e. 1/K per micro-step (the host loop pays 3
        # per micro-step), and fewer syncs per decoded token than the host
        # loop on the same trace. The per-token <= 1/K bound additionally
        # requires the trace to sustain >= K decoded tokens per dispatch
        # (it does at any reasonable occupancy; a lone short request is
        # tail-dominated), so it is reported, not gated.
        win_sync = (dev["host_syncs_decode"] == dev["decode_steps"]
                    and dev["host_syncs_per_token"]
                    < host["host_syncs_per_token"])
        win_tps = dev["tokens_per_step"] >= host["tokens_per_step"]
        ok = ok and win_sched and win_sync and win_tps
        bound = 1.0 / decode_chunk
        amortized = dev["host_syncs_per_token"] <= bound + 1e-9
        print(f"# {spec_name}: device {dev['tokens_per_step']:.2f} tok/step "
              f"(host {host['tokens_per_step']:.2f}, static "
              f"{stat['tokens_per_step']:.2f}) "
              f"[{'PASS' if win_sched and win_tps else 'FAIL'}]; "
              f"1 sync/dispatch = {1.0 / decode_chunk:.3f}/micro-step "
              f"[{'PASS' if win_sync else 'FAIL'}]; syncs/tok "
              f"{host['host_syncs_per_token']:.2f} -> "
              f"{dev['host_syncs_per_token']:.3f} "
              f"({'<=' if amortized else '>'} 1/K = {bound:.3f})")
        if spec_name != "dense":
            # the decode GEMMs of a packed sparse/quant spec must compile
            # through the Pallas skinny-m path at slab width m = n_slots
            skinny = skinny_decode_trace(model, n_slots, max_len,
                                         decode_chunk)
            # the skinny trace lowers locally (interpret backend), never on
            # the mesh — same placement rule as record()
            records.append({"arch": arch, "spec": spec_name,
                            "mode": "skinny_trace", "mesh_shape": [1, 1],
                            "n_replicas": 1, **prov, **skinny})
            win_skinny = (skinny["skinny_m_dispatches"] > 0
                          and skinny["apply_packed_hits"] > 0)
            ok = ok and win_skinny
            print(f"# {spec_name}: decode compiles "
                  f"{skinny['skinny_m_dispatches']} skinny-m Pallas GEMMs "
                  f"({', '.join(skinny['skinny_kernels'])}) "
                  f"[{'PASS' if win_skinny else 'FAIL'}]")
    if n_replicas > 1:
        # router comparison: ONE dense trace (arrivals fast enough that a
        # single replica saturates) against a single engine and against the
        # router fleet. tokens/router-step vs tokens/step is the apples-to-
        # apples clock: one router step = one dispatch round.
        model = registry.load(arch, specs[0][1], seed=seed)
        dense = poisson_trace(max(n_requests, 12 * n_replicas), 0.75,
                              prompt_range, gen_range, model.cfg.vocab, seed)
        max_len = model.cfg.n_img_tokens + prompt_range[1] + gen_range[1] + 8
        single, routed = run_router(model, dense, n_slots, max_len,
                                    n_replicas, decode_chunk,
                                    mesh_shape=mesh_shape)
        ratio = routed["tokens_per_router_step"] / \
            max(1e-9, single["tokens_per_step"])
        win_router = ratio >= 1.5
        ok = ok and win_router
        print(f"# router: {n_replicas} replicas "
              f"{routed['tokens_per_router_step']:.2f} tok/router-step vs "
              f"single {single['tokens_per_step']:.2f} tok/step "
              f"({ratio:.2f}x, spills {int(routed['spills'])}, "
              f"rebalanced {int(routed['rebalanced'])}) "
              f"[{'PASS' if win_router else 'FAIL'} >= 1.5x]")
        record(specs[0][0], "router", routed, decode_chunk,
               n_replicas=n_replicas,
               tokens_per_router_step=routed["tokens_per_router_step"],
               router_vs_single=ratio, spills=routed["spills"],
               rebalanced=routed["rebalanced"])
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump({"arch": arch, "n_slots": n_slots,
                       "decode_chunk": decode_chunk, "smoke": smoke,
                       "mesh_shape": mesh_list, "n_replicas": n_replicas,
                       **prov, "records": records}, f, indent=2)
        print(f"# wrote {out} ({len(records)} records)")
    print(f"# serve_bench: {'PASS' if ok else 'FAIL'} — device loop >= host "
          "loop >= static, 1 decode sync per K-step dispatch, packed + "
          "skinny-m decode"
          + (", sharded == device traffic" if mesh_shape else "")
          + (", router >= 1.5x single" if n_replicas > 1 else ""))
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: dense + sparse0.5-w8, small trace, <60s")
    ap.add_argument("--arch", default=None,
                    help="default: h2o-danube-1.8b (speculative mode: "
                         "nemotron-4-340b — full attention, no circular "
                         "window cache)")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--decode-chunk", type=int, default=4,
                    help="K micro-steps per device-loop dispatch")
    ap.add_argument("--mesh", default="",
                    help="'data,model': add a ShardedBackend mode on a local "
                         "mesh of this shape (force CPU devices via "
                         "XLA_FLAGS=--xla_force_host_platform_device_count)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="router comparison: N engine replicas vs a single "
                         "engine on one dense trace (gate: >= 1.5x)")
    ap.add_argument("--speculate", type=int, default=0,
                    help="speculative mode: plain device loop (chunk=1) vs "
                         "self-draft speculation (speculate=K), gated >= "
                         "1.2x tokens/DISPATCH + greedy token-identity; "
                         "wall tok/s reported ungated; skips regular modes")
    ap.add_argument("--prefix-trace", action="store_true",
                    help="prefix-reuse mode: slab vs paged+prefix-cached "
                         "engine on a Zipf shared-system-prompt trace, "
                         "gated >= 1.3x admitted tok/s + >= 50% prefill "
                         "tokens skipped + token-identity; skips regular "
                         "modes")
    ap.add_argument("--page-size", type=int, default=8,
                    help="KV page size for --prefix-trace / "
                         "--conversation-trace")
    ap.add_argument("--conversation-trace", action="store_true",
                    help="multi-turn chat mode: each turn's prompt is the "
                         "whole prior conversation (engine replies "
                         "included) + a follow-up, through the page-table-"
                         "native paged engine; gated >= 70% prompt tokens "
                         "skipped, zero decode gather/scatter events, "
                         "greedy token-identity vs the slab; skips "
                         "regular modes")
    ap.add_argument("--turns", type=int, default=4,
                    help="turns per conversation for --conversation-trace")
    ap.add_argument("--overload-trace", action="store_true",
                    help="resilience mode: deadline+QoS engine vs non-"
                         "degrading engine under 2x saturating Poisson "
                         "load, gated >= 1.2x goodput tok/step with zero "
                         "deadline-missed completions served; skips "
                         "regular modes")
    ap.add_argument("--deadline-steps", type=int, default=0,
                    help="--overload-trace deadline (0 = 3x mean gen len)")
    ap.add_argument("--fleet", type=int, default=0,
                    help="fleet mode: one dense trace through an N-process "
                         "subprocess fleet (launch.fleet + FleetRouter) vs "
                         "a single engine, gated >= 1.5x tokens/fleet-step "
                         "+ token-identity; skips regular modes")
    ap.add_argument("--ledger-trace", action="store_true",
                    help="ineffectual-work ledger mode: one trace replayed "
                         "twice through a ledger-instrumented device-loop "
                         "engine; gated on measured activation zeros > 0, "
                         "bit-identical counters/histograms across runs, "
                         "host syncs == dispatches, exact tier-0 quality "
                         "shadow; skips regular modes")
    ap.add_argument("--ledger-kblock", type=int, default=8,
                    help="--ledger-trace dead-k-block granularity")
    ap.add_argument("--draft-bits", type=int, default=8,
                    help="draft weight bits (0 = native)")
    ap.add_argument("--draft-sparsity", type=float, default=0.0)
    ap.add_argument("--draft-keep-layers", type=int, default=0,
                    help="truncate the draft to its first N layers (0=all)")
    ap.add_argument("--out", default="",
                    help="write result records to this JSON path")
    ap.add_argument("--trace-dir", default="",
                    help="record each (spec, mode) run with the serve "
                         "tracer: JSONL + Chrome traces and one telemetry "
                         "snapshot per mode land here (CI artifacts)")
    a = ap.parse_args()
    if a.fleet:
        ok = run_fleet_trace(a.arch or "h2o-danube-1.8b",
                             a.requests or 12, a.slots, a.seed,
                             n_processes=a.fleet, out=a.out,
                             decode_chunk=a.decode_chunk)
        sys.exit(0 if ok else 1)
    if a.ledger_trace:
        ok = run_ledger_trace(a.arch or "nemotron-4-340b",
                              a.requests or 8, a.slots, a.seed,
                              out=a.out, k_block=a.ledger_kblock)
        sys.exit(0 if ok else 1)
    if a.overload_trace:
        ok = run_overload_trace(a.arch or "h2o-danube-1.8b",
                                a.requests or 40, a.slots, a.seed,
                                out=a.out, deadline_steps=a.deadline_steps)
        sys.exit(0 if ok else 1)
    if a.conversation_trace:
        ok = run_conversation_trace(a.arch or "nemotron-4-340b",
                                    a.requests or 6, a.turns, a.slots,
                                    a.seed, a.page_size, out=a.out)
        sys.exit(0 if ok else 1)
    if a.prefix_trace:
        ok = run_prefix_trace(a.arch or "nemotron-4-340b",
                              a.requests or 24, a.slots, a.seed,
                              a.page_size, out=a.out)
        sys.exit(0 if ok else 1)
    if a.speculate:
        draft = DraftSpec.from_args(a.draft_bits, a.draft_sparsity,
                                    a.draft_keep_layers)
        ok = run_speculative(a.arch or "nemotron-4-340b",
                             a.requests or 10, a.slots, a.seed,
                             a.speculate, draft, out=a.out)
        sys.exit(0 if ok else 1)
    mesh_shape = None
    if a.mesh:
        from repro.launch.mesh import parse_mesh_arg
        mesh_shape = parse_mesh_arg(a.mesh)
    if a.smoke:
        ok = run(a.arch or "h2o-danube-1.8b", n_requests=a.requests or 8, n_slots=a.slots,
                 prompt_range=(4, 16), gen_range=(8, 16),
                 mean_interarrival=1.5, seed=a.seed, smoke=True,
                 decode_chunk=a.decode_chunk, n_replicas=a.replicas,
                 mesh_shape=mesh_shape, out=a.out, trace_dir=a.trace_dir)
    else:
        ok = run(a.arch or "h2o-danube-1.8b", n_requests=a.requests or 16, n_slots=a.slots,
                 seed=a.seed, decode_chunk=a.decode_chunk,
                 n_replicas=a.replicas, mesh_shape=mesh_shape, out=a.out,
                 trace_dir=a.trace_dir)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
