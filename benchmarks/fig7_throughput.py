"""Fig. 7 reproduction: achievable rate vs input-unrolling factor.

Paper claim (C3): fully-unrolled designs hit the device clock ceiling
(~600 MHz - 1 GHz on Arria 10); pixelwise / row-parallel designs are slower
(300-600 MHz) because of control/buffering on the input-staging path.

TPU restatement: "fmax" has no analogue on fixed silicon; what the unroll
factor buys is GRID WIDTH — how much of the output one invocation
materializes — and the sustained-throughput ceiling is the roofline. We
report ops/invocation (Table I column) and roofline-sustained MACs/s per
kernel: wide (fully-unrolled) grids amortize input staging and saturate the
compute term; narrow (pixelwise) grids are bounded by the input-bandwidth
(memory) term — the same ordering the paper measures.

  PYTHONPATH=src python -m benchmarks.fig7_throughput
"""

from __future__ import annotations

import argparse

from benchmarks.common import CSV, hlo_cost, roofline_seconds
from repro.core import bench_specs as BS
from repro.launch import mesh as M


def run(sparsity=0.0, bits=None, quick: bool = False) -> None:
    csv = CSV(["kernel", "unroll", "size", "ops_per_invocation",
               "hlo_macs", "hlo_bytes", "bound", "sustained_TMACs"])
    import dataclasses
    items = list(BS.BY_NAME.items())
    if quick:
        # one spec per unroll factor keeps the C3 ordering visible while
        # skipping most of the compile time
        seen, kept = set(), []
        for name, base in items:
            if base.unroll not in seen:
                seen.add(base.unroll)
                kept.append((name, base))
        items = kept
    for name, base in items:
        spec = dataclasses.replace(base, sparsity=sparsity, bits=bits)
        params, x, fn = BS.instantiate(spec)
        cost = hlo_cost(fn, params, x)
        t = roofline_seconds(cost["flops"], cost["bytes"])
        sustained = cost["macs"] / t["t"] / 1e12
        csv.row(name, spec.unroll, spec.size, spec.ops_per_invocation(),
                cost["macs"], cost["bytes"], t["bound"], sustained)
    print("\n# C3 check: fully-unrolled ('full') rows sustain the highest")
    print("# MACs/s; pixelwise rows are memory-bound by input staging —")
    print(f"# ceiling = {M.PEAK_BF16_FLOPS/2/1e12:.1f} TMACs/s per chip.")


def main() -> None:
    argparse.ArgumentParser().parse_args()
    run()


if __name__ == "__main__":
    main()
