"""Shared benchmark machinery.

Every figure benchmark measures its quantity from the COMPILED artifact
(jit -> lower -> compile -> loop-aware HLO analysis), mirroring the paper's
workflow where every point is a synthesized circuit — not an analytic
estimate. The analytic cost model (core.kratos.cost_report) is printed next
to the measured value as a cross-check.
"""

from __future__ import annotations

import sys
from typing import Any, Callable, Dict

import jax

from repro.analysis import hlo as HA
from repro.launch import mesh as M


def hlo_cost(fn: Callable, *args) -> Dict[str, float]:
    """Compile fn(*args) and return loop-aware {flops, bytes, macs}."""
    compiled = jax.jit(fn).lower(*args).compile()
    r = HA.analyze(compiled.as_text())
    r["macs"] = r["flops"] / 2.0
    return r


def roofline_seconds(flops: float, bytes_: float, *, int8: bool = False
                     ) -> Dict[str, float]:
    peak = M.PEAK_INT8_OPS if int8 else M.PEAK_BF16_FLOPS
    t_c = flops / peak
    t_m = bytes_ / M.HBM_BW
    return {"t_compute": t_c, "t_memory": t_m, "t": max(t_c, t_m),
            "bound": "compute" if t_c >= t_m else "memory"}


class CSV:
    """Print aligned CSV to stdout and collect rows."""

    def __init__(self, header):
        self.header = header
        self.rows = []
        print(",".join(header))

    def row(self, *vals):
        r = [f"{v:.6g}" if isinstance(v, float) else str(v) for v in vals]
        self.rows.append(r)
        print(",".join(r))
        sys.stdout.flush()
