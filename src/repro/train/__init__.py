from repro.train.loop import (TrainLoopConfig, SimulatedFailure, run_training)

__all__ = ["TrainLoopConfig", "SimulatedFailure", "run_training"]
