"""The training loop: checkpointed, restartable, failure-injectable.

Fault-tolerance contract (exercised in tests/test_fault_tolerance.py):

  * every state mutation is a pure jit step over (state, batch);
  * batches are a pure function of (seed, step) — `data.pipeline` — so a
    restart consumes exactly the stream a never-failed run would have;
  * checkpoints are atomic (tmp+rename) and written async off-thread;
  * `run_training` always begins by restoring the latest checkpoint if one
    exists: crash recovery and planned restart are the same code path;
  * `fail_at_step` injects a SimulatedFailure AFTER the step executes but
    BEFORE its checkpoint boundary — the worst-case crash window;
  * on restore, leaves are device_put with the *current* shardings, so a
    checkpoint written on mesh A restores onto mesh B (elastic re-mesh).

At 1000+ nodes the same loop runs SPMD: the jit step carries in/out
shardings; checkpoint save snapshots to host (device_get per shard) and the
coordinator writes. Straggler/pre-emption posture: deterministic data +
atomic checkpoints means any node-set change is handled by restart-from-
last-checkpoint onto the surviving mesh (see README §fault-tolerance).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, make_pipeline
from repro.distributed import steps as ST
from repro.models import transformer as T
from repro.optim import adamw as O


class SimulatedFailure(RuntimeError):
    """Injected crash (tests / chaos drills)."""


@dataclasses.dataclass(frozen=True)
class TrainLoopConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 25
    keep: int = 3
    log_every: int = 10
    async_checkpoint: bool = True
    fail_at_step: Optional[int] = None      # failure injection
    grad_accum: int = 1
    seed: int = 0


def run_training(cfg: T.ModelConfig, opt_cfg: O.OptimizerConfig,
                 data_cfg: DataConfig, loop: TrainLoopConfig,
                 *, state_shardings=None, compress_fn=None,
                 on_step: Optional[Callable[[int, Dict], None]] = None,
                 ) -> Dict[str, Any]:
    """Train (or resume) to loop.steps. Returns {'state', 'history', ...}."""
    pipe = make_pipeline(data_cfg)
    step_fn = jax.jit(ST.make_train_step(
        cfg, opt_cfg, grad_accum=loop.grad_accum, compress_fn=compress_fn))

    mgr = CheckpointManager(loop.ckpt_dir, keep=loop.keep) \
        if loop.ckpt_dir else None

    state = ST.init_train_state(jax.random.PRNGKey(loop.seed), cfg, opt_cfg)
    start = 0
    if mgr is not None and mgr.latest_step() is not None:
        restored, start = mgr.restore(state, shardings=state_shardings)
        state = restored
        print(f"[train] resumed from checkpoint step {start}")

    history: List[Dict[str, float]] = []
    t0 = time.time()
    for step in range(start, loop.steps):
        batch = {k: jax.numpy.asarray(v) for k, v in pipe.batch(step).items()}
        state, metrics = step_fn(state, batch)
        m = {k: float(v) for k, v in metrics.items()}
        m["step"] = step + 1
        history.append(m)
        if on_step is not None:
            on_step(step + 1, m)
        if loop.log_every and (step + 1) % loop.log_every == 0:
            rate = (step + 1 - start) / (time.time() - t0)
            print(f"[train] step {step+1}/{loop.steps} "
                  f"loss={m['loss']:.4f} gnorm={m['grad_norm']:.3f} "
                  f"({rate:.2f} it/s)")
        if loop.fail_at_step is not None and (step + 1) == loop.fail_at_step:
            raise SimulatedFailure(f"injected failure at step {step+1}")
        if mgr is not None and (step + 1) % loop.ckpt_every == 0:
            mgr.save(step + 1, state, metadata={"loss": m["loss"]},
                     blocking=not loop.async_checkpoint)
    if mgr is not None:
        mgr.wait()
        if loop.steps % loop.ckpt_every != 0 and loop.steps > start:
            mgr.save(loop.steps, state, blocking=True)
    return {"state": state, "history": history, "resumed_from": start}
