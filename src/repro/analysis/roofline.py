"""Roofline attribution: ledger counts x HLO costs x trace wall-time.

The three observability sources each answer one question:

  * `serve.ledger` (device-resident counters) — how much of the dense work
    was INEFFECTUAL this dispatch (activation zeros, dead k-blocks,
    effective-vs-dense FLOPs/bytes), measured in-graph on the step clock;
  * `analysis.hlo.analyze` (static, loop-aware) — what the compiled
    program MUST execute per dispatch, independent of data;
  * `serve.trace` dispatch events — how long each dispatch actually TOOK.

This module joins them. `roofline_point` classifies one (flops, bytes,
wall) triple against a machine roof; `dispatch_rooflines` joins a trace
event stream's per-step wall durations with the ledger's per-step
effective fractions to place BOTH the dense point (what the hardware ran)
and the effective point (what a sparsity-aware kernel would need to run)
on the same roof — the gap between them is the activation-skip
opportunity the ledger exists to measure. `replica_roofline` does the
same once per replica from drained totals.

No third-party deps; everything is plain dict/float so results serialize
straight into bench JSON and qor gating.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional


@dataclasses.dataclass(frozen=True)
class Peaks:
    """Machine roof. Defaults are deliberately modest CPU-class numbers so
    unit tests and laptop runs produce sane utilizations; real runs pass
    measured peaks (e.g. from a dense GEMM sweep or the chip datasheet)."""

    peak_flops: float = 2.0e11     # flop/s
    peak_bw: float = 5.0e10        # bytes/s

    @property
    def ridge(self) -> float:
        """Arithmetic intensity (flop/byte) where the roofs intersect."""
        return self.peak_flops / self.peak_bw


def roofline_point(flops: float, bytes_: float, wall_s: float,
                   peaks: Peaks = Peaks()) -> Dict[str, float]:
    """Classify one workload sample against the roof.

    Returns intensity (flop/byte), achieved flop/s and bytes/s, the roof
    at that intensity, which resource bounds it, and utilization =
    achieved / roof (in the bounding resource).
    """
    bytes_ = max(float(bytes_), 1.0)
    wall_s = max(float(wall_s), 1e-12)
    flops = max(float(flops), 0.0)
    intensity = flops / bytes_
    achieved_flops = flops / wall_s
    achieved_bw = bytes_ / wall_s
    roof = min(peaks.peak_flops, intensity * peaks.peak_bw)
    bound = "compute" if intensity >= peaks.ridge else "memory"
    if bound == "compute":
        utilization = achieved_flops / peaks.peak_flops
    else:
        utilization = achieved_bw / peaks.peak_bw
    return {
        "intensity": intensity,
        "achieved_flops": achieved_flops,
        "achieved_bw": achieved_bw,
        "roof_flops": roof,
        "bound": bound,
        "utilization": utilization,
    }


def _index_ledger(events: Iterable[Dict[str, Any]]) -> Dict[int, Dict[str, Any]]:
    return {int(ev["step"]): ev for ev in events if ev.get("ev") == "ledger"}


def dispatch_rooflines(events: Iterable[Dict[str, Any]],
                       hlo_cost: Optional[Dict[str, Any]] = None,
                       peaks: Peaks = Peaks()) -> List[Dict[str, Any]]:
    """Per-dispatch roofline rows from one tracer's event list.

    events: `Tracer.export()["events"]` (or the parsed JSONL) — the join
    key is the step clock: each "decode"/"spec" dispatch event is matched
    with the "ledger" event drained at the same step.

    hlo_cost: `analysis.hlo.analyze(...)` output for the dispatch
    executable — supplies the static per-dispatch bytes (and a FLOPs
    cross-check for the ledger's dense count). Without it, bytes fall
    back to the ledger's own dense-bytes counter scaled per dispatch.

    Each row carries a `dense` point (what ran) and an `effective` point
    (the same wall clock at ledger-measured effective FLOPs/bytes): the
    utilization gap between them is the headroom an activation-skip
    kernel could claim.
    """
    evs = list(events)
    ledger_by_step = _index_ledger(evs)
    rows: List[Dict[str, Any]] = []
    for ev in evs:
        if ev.get("ev") not in ("decode", "spec"):
            continue
        step = int(ev["step"])
        led = ledger_by_step.get(step)
        if led is None:
            continue
        wall = float(ev.get("dur", 0.0))
        flops_dense = float(led["flops_dense"])
        flops_eff = float(led["flops_eff"])
        if hlo_cost is not None:
            bytes_dense = float(hlo_cost["bytes"])
            static_flops = float(hlo_cost["flops"])
        else:
            bytes_dense = flops_dense  # intensity-1 fallback, labeled below
            static_flops = 0.0
        eff_frac = float(led.get("eff_flop_frac", 1.0))
        bytes_eff = bytes_dense * eff_frac
        rows.append({
            "step": step,
            "kind": ev["ev"],
            "wall_s": wall,
            "flops_dense": flops_dense,
            "flops_effective": flops_eff,
            "static_flops": static_flops,
            "bytes_source": "hlo" if hlo_cost is not None else "ledger",
            "zero_frac": float(led.get("zero_frac", 0.0)),
            "dead_kblock_frac": float(led.get("dead_frac", 0.0)),
            "dense": roofline_point(flops_dense, bytes_dense, wall, peaks),
            "effective": roofline_point(flops_eff, bytes_eff, wall, peaks),
        })
    return rows


def replica_roofline(summary: Dict[str, Any], wall_s: float,
                     hlo_cost: Optional[Dict[str, Any]] = None,
                     n_dispatches: int = 1,
                     peaks: Peaks = Peaks()) -> Dict[str, Any]:
    """Whole-replica roofline from `LedgerSink.summary()` totals.

    summary: drained cumulative totals (flops_dense / flops_effective /
    bytes_dense / bytes_effective). wall_s: the replica's decode wall
    time over the same window (sum of dispatch durs, or bench wall).
    hlo_cost x n_dispatches supplies static bytes when the per-probe byte
    model is not what you want on the memory axis.
    """
    fd = float(summary.get("flops_dense", 0.0))
    fe = float(summary.get("flops_effective", 0.0))
    if hlo_cost is not None:
        bd = float(hlo_cost["bytes"]) * max(1, int(n_dispatches))
        be = bd * (fe / fd if fd > 0 else 1.0)
    else:
        bd = float(summary.get("bytes_dense", 0.0))
        be = float(summary.get("bytes_effective", 0.0))
    out = {
        "wall_s": float(wall_s),
        "flops_dense": fd,
        "flops_effective": fe,
        "bytes_dense": bd,
        "bytes_effective": be,
        "effective_flop_fraction": fe / fd if fd > 0 else 1.0,
        "dense": roofline_point(fd, bd, wall_s, peaks),
        "effective": roofline_point(fe, be, wall_s, peaks),
    }
    # upper bound on an activation-skip kernel's speedup: the work ratio in
    # whichever resource bounds the dense point on this roof
    if out["dense"]["bound"] == "compute":
        out["skip_speedup_bound"] = fd / fe if fe > 0 else 1.0
    else:
        out["skip_speedup_bound"] = bd / be if be > 0 else 1.0
    return out
