# Compiled-artifact analysis: loop-aware HLO cost model + roofline terms.
