"""Loop-aware cost analysis of post-SPMD HLO text.

Why this exists: `compiled.cost_analysis()` counts each `while` body ONCE —
but our models lower layer stacks (and gradient-accumulation microbatches)
as `lax.scan`, so its FLOPs/bytes under-count by the trip count (~20-100x).
This module parses `compiled.as_text()` and multiplies every computation's
cost through the loop nest, using the `known_trip_count` backend config XLA
attaches to counted loops.

Cost model (the same conventions XLA's HloCostAnalysis uses, applied
loop-aware):

  * dot: 2 * result_elems * contraction_size FLOPs
  * reduce: operand elems; elementwise arith/cmp/select: result elems;
    transcendentals (exp/tanh/log/...): result elems (reported separately too)
  * bytes accessed: sum(operand bytes) + result bytes per instruction;
    fusion internals are free (only fusion operands/result count — the
    VMEM-locality assumption); slicing ops count only the touched window;
    aliasing ops (bitcast/tuple/GTE/parameter/constant) are free
  * while: (body + condition) * trip_count; conditional: max over branches
  * collectives: result-shape bytes per execution, multiplied through loops,
    split by kind (all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute)

Wire-byte convention for the roofline's collective term: all-reduce counts
2x result bytes (ring reduce-scatter + all-gather), everything else 1x.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_TRANSCENDENTAL = {
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "power", "sqrt", "rsqrt", "cbrt", "sine", "cosine", "tan", "atan2",
    "erf", "logistic",
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "sign", "compare", "select", "and", "or", "xor", "not",
    "clamp", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "remainder", "shift-left", "shift-right-arithmetic",
    "shift-right-logical", "is-finite",
}

_FREE = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "opt-barrier", "partition-id", "replica-id", "domain",
}

_WINDOW_READ = {"slice", "dynamic-slice", "gather"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT )?%?([\w\.\-]+) = ([^=]*?) ([\w\-]+)\(")

_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)(?: \([^)]*\))? .*\{\s*$")


def _shape_info(text: str) -> Tuple[int, int]:
    """(total elements, total bytes) of a (possibly tuple) type string."""
    elems = bytes_ = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result_elems: int
    result_bytes: int
    operands: List[str]
    attrs: str
    dims: Tuple[int, ...] = ()     # first array shape in the result type


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    by_name: Dict[str, Instr]


def _split_instruction(line: str) -> Optional[Tuple[Instr, str]]:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, rtype, op = m.group(1), m.group(2).strip(), m.group(3)
    # operands: top-level %names inside the first balanced paren group
    start = line.index(op + "(") + len(op)
    depth = 0
    end = start
    for i in range(start, len(line)):
        c = line[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    oper_text = line[start + 1:end]
    attrs = line[end + 1:]
    operands = re.findall(r"%([\w\.\-]+)", oper_text) if op != "constant" else []
    elems, nbytes = _shape_info(rtype)
    dm = _SHAPE_RE.search(rtype)
    dims = tuple(int(x) for x in dm.group(2).split(",") if x) if dm else ()
    return Instr(name=name, op=op, result_elems=elems, result_bytes=nbytes,
                 operands=operands, attrs=attrs, dims=dims), oper_text


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if "/*" in line:
            line = re.sub(r"/\*.*?\*/", "", line)   # `/*index=5*/` breaks on '='
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        if stripped == "}":
            if cur is not None:
                comps[cur.name] = cur
                cur = None
            continue
        if stripped.endswith("{") and "=" not in stripped.split("(")[0]:
            m = _COMP_RE.match(stripped)
            if m:
                cur = Computation(name=m.group(1), instrs=[], by_name={})
            continue
        if cur is None:
            continue
        parsed = _split_instruction(line)
        if parsed is None:
            continue
        instr, _ = parsed
        cur.instrs.append(instr)
        cur.by_name[instr.name] = instr
    return comps


def _called_comp(attrs: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w\.\-]+)", attrs)
    return m.group(1) if m else None


def _trip_count(attrs: str) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', attrs)
    return int(m.group(1)) if m else 1


class _CostVisitor:
    def __init__(self, comps: Dict[str, Computation],
                 dims: Dict[str, Tuple[int, ...]]):
        self.comps = comps
        self.dims = dims
        self.memo: Dict[str, Dict[str, Any]] = {}
        self.warnings: List[str] = []

    def comp_cost(self, name: str) -> Dict[str, Any]:
        if name in self.memo:
            return self.memo[name]
        comp = self.comps.get(name)
        zero = {"flops": 0.0, "transc": 0.0, "bytes": 0.0,
                "convert_bytes": 0.0,
                "coll": {k: {"count": 0.0, "bytes": 0.0} for k in _COLLECTIVES}}
        if comp is None:
            return zero
        total = json.loads(json.dumps(zero))
        self.memo[name] = total       # break cycles defensively
        for ins in comp.instrs:
            self._instr_cost(ins, comp, total)
        return total

    # ------------------------------------------------------------------
    def _acc(self, total, sub, mult=1.0):
        total["flops"] += sub["flops"] * mult
        total["transc"] += sub["transc"] * mult
        total["bytes"] += sub["bytes"] * mult
        total["convert_bytes"] += sub["convert_bytes"] * mult
        for k in _COLLECTIVES:
            total["coll"][k]["count"] += sub["coll"][k]["count"] * mult
            total["coll"][k]["bytes"] += sub["coll"][k]["bytes"] * mult

    def _operand_bytes(self, ins: Instr, comp: Computation) -> float:
        tot = 0.0
        for o in ins.operands:
            src = comp.by_name.get(o)
            if src is not None:
                tot += src.result_bytes
        return tot

    def _instr_cost(self, ins: Instr, comp: Computation, total) -> None:
        op = ins.op
        if op in _FREE:
            return
        if op == "while":
            body = _called_comp(ins.attrs, "body")
            cond = _called_comp(ins.attrs, "condition")
            trip = _trip_count(ins.attrs)
            if trip == 1 and "known_trip_count" not in ins.attrs:
                self.warnings.append(f"while {ins.name}: unknown trip count")
            for c in (body, cond):
                if c:
                    self._acc(total, self.comp_cost(c), trip)
            return
        if op == "conditional":
            branches = re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                  r"true_computation=%?([\w\.\-]+)|"
                                  r"false_computation=%?([\w\.\-]+))", ins.attrs)
            names = []
            for tup in branches:
                for part in tup:
                    if part:
                        names.extend(re.findall(r"%?([\w\.\-]+)", part))
            if names:
                costs = [self.comp_cost(n) for n in names]
                worst = max(costs, key=lambda c: c["flops"] + c["bytes"])
                self._acc(total, worst)
            return
        if op in ("fusion", "call"):
            callee = _called_comp(ins.attrs, "calls") or \
                _called_comp(ins.attrs, "to_apply")
            if callee:
                sub = self.comp_cost(callee)
                # fusion: internal bytes are free; call: keep everything
                if op == "fusion":
                    sub = dict(sub, bytes=0.0, convert_bytes=0.0)
                self._acc(total, sub)
            total["bytes"] += ins.result_bytes + self._operand_bytes(ins, comp)
            return
        if op in _COLLECTIVES:
            total["coll"][op]["count"] += 1
            total["coll"][op]["bytes"] += ins.result_bytes
            total["bytes"] += ins.result_bytes + self._operand_bytes(ins, comp)
            return
        # --- plain instructions ---
        if op == "dot":
            lhs_dims = ()
            if ins.operands:
                src = comp.by_name.get(ins.operands[0])
                lhs_dims = src.dims if src is not None \
                    else self.dims.get(ins.operands[0], ())
            m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
            contraction = 1
            if m and lhs_dims:
                for ix in m.group(1).split(","):
                    if ix:
                        i = int(ix)
                        if i < len(lhs_dims):
                            contraction *= lhs_dims[i]
            else:
                self.warnings.append(f"dot {ins.name}: missing dims")
            total["flops"] += 2.0 * ins.result_elems * contraction
        elif op == "convolution":
            self.warnings.append(f"convolution {ins.name}: approximated")
            total["flops"] += 2.0 * ins.result_elems
        elif op in ("reduce", "reduce-window"):
            total["flops"] += self._operand_elems(ins, comp)
        elif op in _TRANSCENDENTAL:
            total["flops"] += ins.result_elems
            total["transc"] += ins.result_elems
        elif op in _ELEMENTWISE:
            total["flops"] += ins.result_elems
        elif op == "scatter":
            total["flops"] += ins.result_elems * 0  # adds counted via map ops
        # bytes for plain ops
        if op == "convert":
            # XLA:CPU legalizes every bf16 dot by materializing f32 operand
            # copies; on TPU the MXU consumes bf16 directly and standalone
            # converts fuse. Tracked separately so the roofline can report a
            # TPU-adjusted memory term next to the raw CPU-HLO one.
            b = ins.result_bytes + self._operand_bytes(ins, comp)
            total["bytes"] += b
            total["convert_bytes"] += b
            return
        if op in _WINDOW_READ:
            total["bytes"] += 2.0 * ins.result_bytes
        elif op == "dynamic-update-slice":
            upd = comp.by_name.get(ins.operands[1]) if len(ins.operands) > 1 \
                else None
            ub = upd.result_bytes if upd is not None else ins.result_bytes
            total["bytes"] += 2.0 * ub
        else:
            total["bytes"] += ins.result_bytes + self._operand_bytes(ins, comp)

    def _operand_elems(self, ins: Instr, comp: Computation) -> float:
        tot = 0.0
        for o in ins.operands:
            src = comp.by_name.get(o)
            if src is not None:
                tot += src.result_elems
        return tot


def _dims_table(text: str) -> Dict[str, Tuple[int, ...]]:
    """instruction name -> result dims (first array shape in its type)."""
    dims: Dict[str, Tuple[int, ...]] = {}
    pat = re.compile(r"^\s*(?:ROOT )?%?([\w\.\-]+) = ([a-z0-9]+)\[([0-9,]*)\]")
    for line in text.splitlines():
        m = pat.match(line)
        if m and m.group(2) in _DTYPE_BYTES:
            d = tuple(int(x) for x in m.group(3).split(",") if x)
            dims[m.group(1)] = d
    return dims


def breakdown(hlo_text: str, top: int = 15) -> List[Tuple[str, float, float]]:
    """Top contributors: (op_key, bytes x loop-mult, flops x mult).

    op_key groups by (opcode, result shape); loop multipliers come from the
    computation's effective execution count. The §Perf tool for 'what is the
    dominant term made of'.
    """
    comps = parse_module(hlo_text)
    m = re.search(r"^ENTRY %?([\w\.\-]+)", hlo_text, re.M)
    entry = m.group(1) if m else next(iter(comps))
    # effective execution multiplier per computation
    mult: Dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    while order:
        name = order.pop(0)
        comp = comps.get(name)
        if comp is None:
            continue
        for ins in comp.instrs:
            m_ = mult[name]
            callees = []
            if ins.op == "while":
                t = _trip_count(ins.attrs)
                for key in ("body", "condition"):
                    c = _called_comp(ins.attrs, key)
                    if c:
                        callees.append((c, m_ * t))
            elif ins.op in ("fusion", "call"):
                c = _called_comp(ins.attrs, "calls") or \
                    _called_comp(ins.attrs, "to_apply")
                if c:
                    callees.append((c, m_))
            for c, cm in callees:
                mult[c] = mult.get(c, 0.0) + cm
                if c not in seen:
                    seen.add(c)
                    order.append(c)
    agg: Dict[str, List[float]] = {}
    for cname, comp in comps.items():
        m_ = mult.get(cname, 0.0)
        if m_ == 0.0:
            continue
        for ins in comp.instrs:
            if ins.op in _FREE or ins.op in ("fusion", "call", "while",
                                             "conditional"):
                continue
            key = f"{ins.op} {ins.result_bytes/2**20:.0f}MiB"
            b = ins.result_bytes * m_
            f = ins.result_elems * m_ if ins.op in _ELEMENTWISE else 0.0
            cur = agg.setdefault(key, [0.0, 0.0])
            cur[0] += b
            cur[1] += f
    rows = sorted(((k, v[0], v[1]) for k, v in agg.items()),
                  key=lambda r: -r[1])
    return rows[:top]


def analyze(hlo_text: str, entry: Optional[str] = None) -> Dict[str, Any]:
    """Loop-aware whole-program cost. Returns per-device totals."""
    comps = parse_module(hlo_text)
    dims = _dims_table(hlo_text)
    if entry is None:
        m = re.search(r"^ENTRY %?([\w\.\-]+)", hlo_text, re.M)
        entry = m.group(1) if m else next(iter(comps))
    visitor = _CostVisitor(comps, dims)
    # memoization keyed per computation; entry computed last
    visitor.memo.pop(entry, None)
    total = visitor.comp_cost(entry)
    coll = total["coll"]
    wire = (2 * coll["all-reduce"]["bytes"] + coll["all-gather"]["bytes"]
            + coll["reduce-scatter"]["bytes"] + coll["all-to-all"]["bytes"]
            + coll["collective-permute"]["bytes"]
            + coll["ragged-all-to-all"]["bytes"])
    return {
        "flops": total["flops"],
        "transcendentals": total["transc"],
        "bytes": total["bytes"],
        "bytes_tpu_adjusted": total["bytes"] - total["convert_bytes"],
        "convert_bytes": total["convert_bytes"],
        "collectives": {k: dict(v) for k, v in coll.items()},
        "wire_bytes": wire,
        "n_computations": len(comps),
        "warnings": visitor.warnings[:20],
    }
