from repro.optim.adamw import (  # noqa: F401
    OptimizerConfig, adamw_init, adamw_update, global_norm, clip_by_global_norm,
    warmup_cosine, make_optimizer,
)
