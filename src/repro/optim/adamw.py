"""AdamW + schedules, from scratch (no optax in this environment).

Notable scale features:
  * optimizer-state dtype is configurable (`state_dtype='bfloat16'` halves
    the m/v footprint — required to fit nemotron-340B's states in
    16 GB/chip; the update math still runs in f32);
  * states inherit the parameter sharding (FSDP'd params => ZeRO-sharded
    optimizer, no extra code);
  * global-norm clipping;
  * optional int8 error-feedback gradient compression hook
    (distributed/compression.py) applied before the update.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    state_dtype: str = "float32"
    # layer-stacked leaves (scan blocks, (L, ...) >= 16M elems) update via
    # lax.map over the layer axis, bounding the f32 update temporaries to one
    # layer slice. MEASURED REFUTED on XLA:CPU (EXPERIMENTS.md §Perf): the
    # map's stacked outputs allocate fresh buffers and temp grew 18.5->28.9
    # GiB; left off by default, kept as a knob for TPU re-evaluation.
    chunk_stacked_update: bool = False
    chunk_threshold_elems: int = 1 << 24


def warmup_cosine(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def _is_float_leaf(x) -> bool:
    return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)


def adamw_init(params, cfg: OptimizerConfig) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.state_dtype)

    def zeros(x):
        return jnp.zeros(x.shape, dt) if _is_float_leaf(x) else None

    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, opt_state, params, cfg: OptimizerConfig,
                 lr: Optional[jnp.ndarray] = None):
    """Returns (new_params, new_opt_state, grad_norm)."""
    count = opt_state["count"] + 1
    if cfg.clip_norm is not None:
        grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gn = global_norm(grads)
    lr = warmup_cosine(cfg, count) if lr is None else lr
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1 - b1 ** count.astype(jnp.float32)
    c2 = 1 - b2 ** count.astype(jnp.float32)

    def upd_math(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        step = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return (newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype))

    def upd(p, g, m, v):
        if g is None or not _is_float_leaf(p):
            return p, m, v
        if (cfg.chunk_stacked_update and p.ndim >= 3
                and p.size >= cfg.chunk_threshold_elems):
            return jax.lax.map(lambda a: upd_math(*a), (p, g, m, v))
        return upd_math(p, g, m, v)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, gn


def make_optimizer(cfg: OptimizerConfig):
    return (lambda p: adamw_init(p, cfg),
            lambda g, s, p, lr=None: adamw_update(g, s, p, cfg, lr))
