"""Fault-tolerant checkpointing: atomic, async-capable, elastic-remesh-aware.

  * Atomic: checkpoints are written to `<dir>/tmp-<step>` then renamed to
    `<dir>/step-<step>` — a crash mid-write never corrupts the latest
    checkpoint; `latest_step()` only sees fully-renamed directories.
  * Async: `save(..., blocking=False)` snapshots to host memory
    (device_get) and writes on a background thread so the training loop
    keeps stepping (`wait()` joins before the next save / at exit).
  * Elastic re-mesh: `load(..., shardings=...)` re-`device_put`s every leaf
    with the *target* sharding — a checkpoint written on mesh A restores
    onto mesh B (different #devices / topology); tested in
    tests/test_fault_tolerance.py.
  * Retention: keep the last `keep` checkpoints.

Format: one .npz per checkpoint (flattened path->array) + a JSON manifest
with the treedef and scalar metadata. No external deps.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}

    def walk(path, node):
        leaves = jax.tree_util.tree_flatten_with_path(node)[0]
        for kp, leaf in leaves:
            key = path + "/" + "/".join(_key_str(k) for k in kp)
            flat[key.lstrip("/")] = leaf

    walk("", tree)
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"#{k.idx}"
    return str(k)


def save_pytree(path: str, tree, metadata: Optional[Dict] = None) -> None:
    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump({"keys": sorted(arrays.keys()),
                   "metadata": metadata or {}}, f)


def load_pytree(path: str, like, shardings=None):
    """Restore into the structure of `like`; device_put with `shardings` if
    given (elastic re-mesh)."""
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    leaves_kp, tdef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for kp, leaf in leaves_kp:
        key = "/".join(_key_str(k) for k in kp)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(tdef, out)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree


def load_metadata(path: str) -> Dict:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f).get("metadata", {})


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step-(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def path(self, step: int) -> str:
        return os.path.join(self.dir, f"step-{step}")

    # ------------------------------------------------------------------
    def save(self, step: int, tree, metadata: Optional[Dict] = None,
             blocking: bool = True) -> None:
        self.wait()
        # snapshot to host *now* so training can mutate device state
        host = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)),
                                      tree)

        def write():
            tmp = os.path.join(self.dir, f"tmp-{step}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            save_pytree(tmp, host, dict(metadata or {}, step=step,
                                        time=time.time()))
            final = self.path(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, like, step: Optional[int] = None, shardings=None):
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        p = self.path(step)
        return load_pytree(p, like, shardings), step

    # ------------------------------------------------------------------
    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for m in
            (re.fullmatch(r"step-(\d+)", n) for n in os.listdir(self.dir))
            if m)
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.path(s), ignore_errors=True)
        # drop orphaned tmp dirs from crashed writers
        for n in os.listdir(self.dir):
            if n.startswith("tmp-"):
                shutil.rmtree(os.path.join(self.dir, n), ignore_errors=True)
