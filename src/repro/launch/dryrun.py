import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST run before any jax import: jax locks the device count on first init.
# This file (and only this file) sees 512 placeholder CPU devices so the
# production meshes can be built; smoke tests and benches see 1 device.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract the roofline terms from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-27b \
      --shape train_4k [--multi-pod] [--out results.json]

Proves, without hardware: the sharding config is coherent (no mismatched
collectives), the per-device memory fits 16 GB (memory_analysis), and yields
HLO FLOPs / bytes / per-collective bytes for EXPERIMENTS.md §Roofline.
"""

import argparse
import json
import re
import sys
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as C
from repro.distributed import sharding as SH
from repro.launch import mesh as M
from repro.launch import shapes as SP
from repro.models import transformer as T


from repro.analysis import hlo as HA

# ---------------------------------------------------------------------------
# Sharding trees for the step inputs
# ---------------------------------------------------------------------------

def input_shardings(kind: str, args, mesh, cell: SP.ShapeCell):
    """NamedSharding pytree matching cell_inputs(...) output."""
    def ns(spec):
        return NamedSharding(mesh, spec)

    def batch_tree(batch):
        bp = SH.batch_pspec(mesh, cell.batch)
        lead = list(bp)
        out = {}
        for k, v in batch.items():
            out[k] = ns(P(*(lead + [None] * (v.ndim - len(lead)))))
        return out

    if kind == "train":
        state, batch = args
        pshard = SH.param_shardings(mesh, state["params"])
        opt = {
            "m": SH.param_shardings(mesh, state["opt"]["m"]),
            "v": SH.param_shardings(mesh, state["opt"]["v"]),
            "count": ns(P()),
        }
        st = {"params": pshard, "opt": opt, "step": ns(P())}
        return (st, batch_tree(batch))
    params = args[0]
    pshard = SH.param_shardings(mesh, params)
    if kind == "prefill":
        _, batch, caches = args
        cs = jax.tree_util.tree_map(
            ns, SH.cache_pspecs(caches, mesh, cell.batch))
        return (pshard, batch_tree(batch), cs)
    _, caches, token, index = args
    cs = jax.tree_util.tree_map(ns, SH.cache_pspecs(caches, mesh, cell.batch))
    bp = SH.batch_pspec(mesh, cell.batch)
    tok = ns(P(*(list(bp) + [None])))
    return (pshard, cs, tok, ns(P()))


def output_shardings(kind: str, in_sh, mesh, cell: SP.ShapeCell):
    """Outputs mirror inputs: new state keeps the state sharding, new caches
    keep the cache sharding; logits/metrics are batch-sharded/replicated.
    Without this, jit picks output layouts freely — stacked caches came back
    replicated, inflating per-device memory ~an order of magnitude."""
    def ns(spec):
        return NamedSharding(mesh, spec)

    bp = SH.batch_pspec(mesh, cell.batch)
    if kind == "train":
        state_sh, _ = in_sh
        metrics = {"loss": ns(P()), "grad_norm": ns(P()), "lr": ns(P())}
        return (state_sh, metrics)
    if kind == "prefill":
        _, _, cache_sh = in_sh
        logits = ns(P(*(list(bp) + [None, None])))
        return (logits, cache_sh)
    _, cache_sh, _, _ = in_sh
    logits = ns(P(*(list(bp) + [None, None])))
    return (logits, cache_sh)


_DONATE = {"train": (0,), "prefill": (2,), "decode": (1,)}


# ---------------------------------------------------------------------------
# One cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             cfg_overrides: Dict = None, save_hlo: str = None,
             serve_tp2d: bool = False, bf16_reduce: bool = False,
             ) -> Dict[str, Any]:
    cell = SP.SHAPES_BY_NAME[shape]
    ok, reason = SP.cell_applicable(arch, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "skipped", "reason": reason}

    mesh = M.make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    dp = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                      if a in ("pod", "data")]))
    cfg = SP.config_for_dryrun(arch, **(cfg_overrides or {}))
    t0 = time.time()
    kind, args = SP.cell_inputs(arch, cell, cfg=cfg)
    step = SP.make_step_fn(arch, cell, cfg=cfg, mesh_dp=dp)

    if bf16_reduce:
        from repro.kernels import ref as kref
        kref.set_dot_accum(jnp.bfloat16)
    rule_overrides = None
    if serve_tp2d and kind == "decode":
        # 2D-TP serving: weights stay fully (data x model)-sharded and are
        # NEVER re-gathered per step; the d_model contraction dim of every
        # projection shards over 'data' instead, psumming activation-sized
        # partials. batch is replicated (decode activations are tiny).
        rule_overrides = {"batch": None, "dm_in": "data"}

    with SH.use_mesh(mesh, rule_overrides):
        in_sh = input_shardings(kind, args, mesh, cell)
        out_sh = output_shardings(kind, in_sh, mesh, cell)
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=_DONATE[kind])
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
        hlo = compiled.as_text()          # post-SPMD: collectives are here
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()

    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    analysis = HA.analyze(hlo)            # loop-aware FLOPs/bytes/collectives
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    xla_flops = float(cost.get("flops", -1)) if cost else -1.0

    result = {
        "arch": arch, "shape": shape, "multi_pod": multi_pod,
        "status": "ok", "kind": kind, "n_chips": n_chips,
        "seq": cell.seq, "batch": cell.batch,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "hlo_flops_per_device": analysis["flops"],
        "hlo_bytes_per_device": analysis["bytes"],
        # raw bytes include XLA:CPU's f32-legalization convert copies of
        # every bf16 dot operand — buffers a TPU lowering never materializes
        # (the MXU consumes bf16 directly). The adjusted number subtracts
        # convert traffic and drives the roofline memory term.
        "hlo_bytes_tpu_adjusted": analysis["bytes_tpu_adjusted"],
        "xla_cost_analysis_flops_unscaled": xla_flops,   # loop-body-once ref
        "collectives": {
            "counts": {k: v["count"] for k, v in analysis["collectives"].items()},
            "result_bytes": {k: v["bytes"] for k, v in
                             analysis["collectives"].items()},
            "wire_bytes_per_device": analysis["wire_bytes"],
        },
        "analysis_warnings": analysis["warnings"],
        "params": int(cfg.param_count()),
        "active_params": int(cfg.active_param_count()),
    }
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                result[f"mem_{attr}"] = int(v)
    return result


def roofline_terms(rec: Dict[str, Any]) -> Dict[str, Any]:
    """The three roofline terms (seconds) from a dry-run record."""
    if rec.get("status") != "ok":
        return {}
    flops = rec["hlo_flops_per_device"]
    bytes_ = rec.get("hlo_bytes_tpu_adjusted", rec["hlo_bytes_per_device"])
    wire = rec["collectives"]["wire_bytes_per_device"]
    t_compute = flops / M.PEAK_BF16_FLOPS
    t_memory = bytes_ / M.HBM_BW
    t_coll = wire / M.ICI_BW_PER_LINK
    terms = {"t_compute_s": t_compute, "t_memory_s": t_memory,
             "t_collective_s": t_coll}
    dom = max(terms, key=terms.get)
    # useful model FLOPs: 6 N D per trained token; decode/prefill: 2 N D
    n = rec["active_params"]
    toks = rec["batch"] * (rec["seq"] if rec["kind"] != "decode" else 1)
    mult = 6 if rec["kind"] == "train" else 2
    model_flops = mult * n * toks / rec["n_chips"]   # per device
    terms.update({
        "dominant": dom.replace("t_", "").replace("_s", ""),
        "model_flops_per_device": model_flops,
        "useful_flops_fraction": model_flops / flops if flops > 0 else None,
        "roofline_fraction":
            (model_flops / M.PEAK_BF16_FLOPS) / max(terms.values())
            if max(terms.values()) > 0 else None,
    })
    return terms


def parse_kratos(text: str):
    """'sparsity=0.9,bits=8,impl=tree,bk=128,bn=128' -> KratosSpec."""
    from repro.core import kratos as kr
    kw = {}
    for part in text.split(","):
        k, v = part.split("=")
        kw[k] = v if k in ("impl", "unroll") else (
            float(v) if k == "sparsity" else int(v))
    return kr.KratosSpec(**kw)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=[s.name for s in SP.SHAPES])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None, help="append JSON record here")
    ap.add_argument("--save-hlo", default=None)
    # §Perf iteration knobs
    ap.add_argument("--serve-tp2d", action="store_true",
                    help="decode cells: 2D-TP weights, no per-step regather")
    ap.add_argument("--bf16-reduce", action="store_true",
                    help="bf16 projection-dot accumulation -> bf16 psums")
    ap.add_argument("--kratos", default=None,
                    help="attach a KratosSpec, e.g. 'sparsity=0.9,bits=8'")
    args = ap.parse_args()

    overrides = {}
    if args.kratos:
        overrides["kratos"] = parse_kratos(args.kratos)
    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   save_hlo=args.save_hlo, cfg_overrides=overrides,
                   serve_tp2d=args.serve_tp2d, bf16_reduce=args.bf16_reduce)
    rec["variant"] = {k: v for k, v in
                      (("serve_tp2d", args.serve_tp2d),
                       ("bf16_reduce", args.bf16_reduce),
                       ("kratos", args.kratos)) if v}
    rec["roofline"] = roofline_terms(rec)
    if args.out:                       # persist before stdout (SIGPIPE-safe)
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
    print(json.dumps(rec, indent=2))


if __name__ == "__main__":
    main()
