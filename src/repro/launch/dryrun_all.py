"""Batch dry-run driver: every (arch x shape x mesh) cell as a subprocess.

Each cell runs in a fresh python process so the 512-device XLA flag and the
compile-time memory are isolated; results append to a JSONL ledger and
finished cells are skipped on re-run (resumable — the fault-tolerance story
applies to the experiment harness too).

  PYTHONPATH=src python -m repro.launch.dryrun_all \
      [--out results/dryrun.jsonl] [--arch A]... [--shape S]... [--single-pod-only]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro import configs as C
from repro.launch import shapes as SP

# cheapest-first so early failures surface fast and partial ledgers are useful
ARCH_ORDER = (
    "h2o_danube_1_8b", "minicpm3_4b", "llava_next_mistral_7b",
    "falcon_mamba_7b", "deepseek_moe_16b", "deepseek_v2_lite_16b",
    "whisper_large_v3", "gemma2_27b", "jamba_v0_1_52b", "nemotron_4_340b",
)


def done_keys(path: str):
    keys = set()
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if r.get("status") in ("ok", "skipped"):
                    keys.add((r["arch"], r["shape"], r["multi_pod"]))
    return keys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    archs = args.arch or list(ARCH_ORDER)
    shapes = args.shape or [s.name for s in SP.SHAPES]
    meshes = [False] if args.single_pod_only else [False, True]

    done = done_keys(args.out)
    todo = []
    for mp in meshes:               # mesh-major: single-pod table completes first
        for shape in shapes:
            for arch in archs:
                if (arch, shape, mp) not in done:
                    todo.append((arch, shape, mp))
    print(f"{len(todo)} cells to run ({len(done)} already done)", flush=True)

    for i, (arch, shape, mp) in enumerate(todo):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", args.out]
        if mp:
            cmd.append("--multi-pod")
        t0 = time.time()
        print(f"[{i+1}/{len(todo)}] {arch} {shape} multi_pod={mp} ...",
              flush=True)
        try:
            p = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            if p.returncode != 0:
                rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                       "status": "error",
                       "error": (p.stderr or p.stdout)[-2000:]}
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
                print(f"   ERROR ({time.time()-t0:.0f}s): "
                      f"{(p.stderr or '')[-300:]}", flush=True)
            else:
                print(f"   ok ({time.time()-t0:.0f}s)", flush=True)
        except subprocess.TimeoutExpired:
            rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                   "status": "timeout"}
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
            print(f"   TIMEOUT after {args.timeout}s", flush=True)


if __name__ == "__main__":
    main()
