"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import; smoke
tests and benches must keep seeing 1 device).

Topology targets (TPU v5e-class):
  single pod : 16 x 16 = 256 chips, axes ('data', 'model')
  multi pod  : 2 x 16 x 16 = 512 chips, axes ('pod', 'data', 'model') —
               'pod' is the DCN-grade axis (extra DP by default, pipeline
               stage axis optionally; see distributed/pipeline.py).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


# Hardware constants for the roofline (TPU v5e-class, per chip)
PEAK_BF16_FLOPS = 197e12        # FLOP/s
PEAK_INT8_OPS = 394e12          # OP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s per link (~ per-axis-neighbor)
HBM_BYTES = 16 * 2 ** 30        # 16 GiB
