"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import; smoke
tests and benches must keep seeing 1 device).

Topology targets (TPU v5e-class):
  single pod : 16 x 16 = 256 chips, axes ('data', 'model')
  multi pod  : 2 x 16 x 16 = 512 chips, axes ('pod', 'data', 'model') —
               'pod' is the DCN-grade axis (extra DP by default, pipeline
               stage axis optionally; see distributed/pipeline.py).
"""

from __future__ import annotations

from typing import List, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def parse_mesh_arg(spec: str) -> Tuple[int, int]:
    """'2,4' or 'data=2,model=4' -> (data, model). The serving launchers'
    `--mesh` grammar (CPU runs force devices via
    XLA_FLAGS=--xla_force_host_platform_device_count=N first)."""
    parts = [p.strip() for p in spec.split(",") if p.strip()]
    if len(parts) != 2:
        raise ValueError(f"--mesh expects 'data,model', got {spec!r}")
    vals = {}
    for i, p in enumerate(parts):
        if "=" in p:
            k, v = p.split("=", 1)
            vals[k.strip()] = int(v)
        else:
            vals[("data", "model")[i]] = int(p)
    if set(vals) != {"data", "model"} or min(vals.values()) < 1:
        raise ValueError(f"--mesh expects positive data,model sizes, "
                         f"got {spec!r}")
    return vals["data"], vals["model"]


def replica_meshes(data: int, model: int, n_replicas: int) -> List:
    """Split a (data, model) device grid into `n_replicas` disjoint
    submeshes along the DATA axis — one serving-engine replica per
    data-parallel submesh (serve.router). Each replica keeps the full
    'model' axis (TP stays intact); the data axis divides evenly or this
    raises (uneven replicas would skew the router's load signal)."""
    if n_replicas < 1:
        raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
    if data % n_replicas:
        raise ValueError(f"data axis {data} does not divide into "
                         f"{n_replicas} replicas")
    import numpy as np
    from jax.sharding import Mesh
    need = data * model
    devices = jax.devices()
    if len(devices) < need:
        raise ValueError(f"mesh {data}x{model} needs {need} devices, "
                         f"have {len(devices)}")
    grid = np.asarray(devices[:need]).reshape(n_replicas,
                                              data // n_replicas, model)
    return [Mesh(grid[i], ("data", "model")) for i in range(n_replicas)]


def process_meshes(data: int, model: int, n_replicas: int) -> List:
    """`replica_meshes` for ONE PROCESS of a fleet: the same disjoint
    (data, model) submesh split, but over `jax.local_devices()` — the
    devices THIS process owns after `jax.distributed.initialize` — so
    each fleet process serves its own replicas on its own chips and the
    data plane never crosses the process boundary. In a single-process
    run local_devices == devices and this degenerates to replica_meshes
    exactly (same grid, same meshes), which is what keeps
    DistributedBackend token-identical to ShardedBackend."""
    if n_replicas < 1:
        raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
    if data % n_replicas:
        raise ValueError(f"data axis {data} does not divide into "
                         f"{n_replicas} replicas")
    import numpy as np
    from jax.sharding import Mesh
    need = data * model
    devices = jax.local_devices()
    if len(devices) < need:
        raise ValueError(
            f"process mesh {data}x{model} needs {need} LOCAL devices, "
            f"process {jax.process_index()} has {len(devices)} "
            f"(of {jax.device_count()} global)")
    grid = np.asarray(devices[:need]).reshape(n_replicas,
                                              data // n_replicas, model)
    return [Mesh(grid[i], ("data", "model")) for i in range(n_replicas)]


def fleet_topology(data: int, model: int, n_replicas: int) -> dict:
    """Resolved process -> devices -> replica-mesh map for THIS process,
    JSON-safe — what `launch.serve --dry-run` prints per fleet process so
    a misconfigured coordinator (wrong num_processes, short device
    count, uneven replica split) fails loudly BEFORE weight packing."""
    meshes = process_meshes(data, model, n_replicas)
    return {
        "process_index": jax.process_index(),
        "num_processes": jax.process_count(),
        "local_devices": [str(d) for d in jax.local_devices()],
        "global_device_count": jax.device_count(),
        "mesh_shape": [data, model],
        "n_replicas": n_replicas,
        "replica_meshes": [
            {"replica": i,
             "shape": dict(zip(m.axis_names,
                               (int(s) for s in m.devices.shape))),
             "devices": [str(d) for d in m.devices.flat]}
            for i, m in enumerate(meshes)],
    }


def plan_fleet_topology(n_processes: int, devices_per_process: int,
                        data: int, model: int, n_replicas: int) -> dict:
    """Arithmetic-only fleet plan: the same constraints `process_meshes`
    enforces live, checked WITHOUT touching jax device state — so
    `launch.serve --dry-run --processes N` can validate a local-fleet
    launch (which spawns workers with their own forced device counts)
    from the coordinator process, before any worker or weight pack
    exists. Raises ValueError exactly where process_meshes would."""
    if n_processes < 1:
        raise ValueError(f"n_processes must be >= 1, got {n_processes}")
    if n_replicas < 1:
        raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
    if data % n_replicas:
        raise ValueError(f"data axis {data} does not divide into "
                         f"{n_replicas} replicas")
    need = data * model
    if devices_per_process < need:
        raise ValueError(
            f"process mesh {data}x{model} needs {need} devices per "
            f"process, plan gives each of {n_processes} processes "
            f"{devices_per_process}")
    per = data // n_replicas
    procs = []
    for p in range(n_processes):
        devs = [f"cpu:{p}:{i}" for i in range(devices_per_process)]
        procs.append({
            "process_index": p,
            "local_devices": devs,
            "replica_meshes": [
                {"replica": r,
                 "shape": {"data": per, "model": model},
                 "devices": devs[r * per * model:(r + 1) * per * model]}
                for r in range(n_replicas)],
        })
    return {
        "num_processes": n_processes,
        "devices_per_process": devices_per_process,
        "global_device_count": n_processes * devices_per_process,
        "mesh_shape": [data, model],
        "n_replicas": n_replicas,
        "processes": procs,
    }


# Hardware constants for the roofline (TPU v5e-class, per chip)
PEAK_BF16_FLOPS = 197e12        # FLOP/s
PEAK_INT8_OPS = 394e12          # OP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s per link (~ per-axis-neighbor)
HBM_BYTES = 16 * 2 ** 30        # 16 GiB
