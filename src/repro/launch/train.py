"""Training launcher.

On this CPU container it runs reduced (smoke) configs end-to-end with real
learning curves; on a TPU fleet the same entry point runs the full configs
(the jit step, shardings, checkpointing and data pipeline are identical —
only the mesh constructor changes).

  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
      --smoke --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt \
      [--sparsity 0.5 --bits 8] [--compress] [--resume]
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro import configs as C
from repro.core import kratos as kr
from repro.data.pipeline import DataConfig
from repro.distributed import compression as GC
from repro.distributed import sharding as SH
from repro.launch import mesh as M
from repro.optim import adamw as O
from repro.train import TrainLoopConfig, run_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (the only option on CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--sparsity", type=float, default=0.0)
    ap.add_argument("--bits", type=int, default=0)
    ap.add_argument("--compress", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="failure injection (chaos drill)")
    ap.add_argument("--data", default="markov", choices=["markov", "uniform"])
    ap.add_argument("--mesh", default="local", choices=["local", "none"])
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch)
    if args.sparsity or args.bits:
        spec = kr.KratosSpec(sparsity=args.sparsity,
                             bits=args.bits or None, bk=8, bn=8)
        cfg = dataclasses.replace(cfg, kratos=spec)
        print(f"[train] kratos spec: {spec}")
        rep = kr.cost_report(cfg.d_model, cfg.d_ff or cfg.d_model, spec)
        print(f"[train] per-projection cost: {rep['mac_fraction']:.2f} MACs, "
              f"{rep['weight_bytes_fraction']:.2f} weight bytes vs dense")

    opt_cfg = O.OptimizerConfig(lr=args.lr, warmup_steps=min(20, args.steps),
                                total_steps=args.steps)
    data_cfg = DataConfig(
        vocab=cfg.vocab, batch=args.batch, seq=args.seq, source=args.data,
        frames=cfg.enc_positions if cfg.enc_dec else 0,
        d_model=cfg.d_model, img_tokens=cfg.n_img_tokens)
    loop = TrainLoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                           ckpt_every=args.ckpt_every,
                           fail_at_step=args.fail_at,
                           grad_accum=args.grad_accum)
    compress = GC.ef_int8_compress if args.compress else None

    if args.mesh == "local":
        mesh = M.make_local_mesh(1, jax.device_count())
        with SH.use_mesh(mesh):
            out = run_training(cfg, opt_cfg, data_cfg, loop,
                               compress_fn=compress)
    else:
        out = run_training(cfg, opt_cfg, data_cfg, loop, compress_fn=compress)

    losses = [h["loss"] for h in out["history"]]
    if losses:
        print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"over {len(losses)} steps (resumed_from={out['resumed_from']})")


if __name__ == "__main__":
    main()
