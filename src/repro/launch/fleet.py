"""Fleet launcher: N serving processes + the cross-process control plane.

The missing piece between "replicas on submeshes of one host" and
"fleets of hosts": this module SPAWNS the processes that
serve.control/serve.router coordinate. Two entry modes share one file so
the wire protocol and its two ends can never drift apart:

  coordinator (default)  binds a ControlListener, spawns N worker
                         subprocesses (each with its own
                         XLA_FLAGS=--xla_force_host_platform_device_count
                         so a laptop/CI box becomes an N-process CPU
                         fleet), waits for their hellos, and builds a
                         FleetRouter over the RemoteProcess handles.
  --worker               one serving process: optional
                         `jax.distributed.initialize` (real multi-host
                         runs pass --jax-coordinator/--process-index/
                         --processes; local CPU fleets skip it — no
                         cross-process collectives, nothing to
                         coordinate), model load, DistributedBackend
                         replicas on `process_meshes` submeshes, then
                         WorkerServer against the coordinator's socket.

`python -m repro.launch.fleet --processes 2` runs the built-in smoke:
the dense Poisson trace through the 2-process fleet, greedy outputs
checked token-identical against a single in-coordinator engine, fleet
topology printed per process — the CI `serve-fleet` job's first step and
the dev loop for anything touching the control plane. The gated
benchmark lives in benchmarks/serve_bench.py (--fleet), which imports
`spawn_fleet` from here.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

# Wall-clock control-plane horizons for REAL subprocess fleets (the
# coordinator paces its step loop with PACE-second sleeps, so these are
# roughly seconds/PACE steps). Deterministic tests use tighter step-clock
# FleetConfigs on LocalProcess handles instead.
PACE = 0.002
WALL_STALENESS = 400.0          # ~0.8 s of snapshot age tolerated
WALL_HEARTBEAT_TIMEOUT = 2500.0  # ~5 s of silence before death verdict
# jax.distributed fleets pay multi-second first-dispatch compiles per
# replica; the single-threaded worker cannot heartbeat through them, so
# the death verdict needs a compile-sized horizon (slower true-death
# detection is the honest price — tune down once steps are warm)
WALL_HEARTBEAT_TIMEOUT_DISTRIBUTED = 30000.0  # ~60 s


def worker_flags(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--worker", action="store_true",
                    help="run as one fleet serving process (spawned by the "
                         "coordinator; rarely typed by hand)")
    ap.add_argument("--connect", default="",
                    help="coordinator control address host:port")
    ap.add_argument("--process-index", type=int, default=0)
    ap.add_argument("--processes", type=int, default=2,
                    help="fleet size (coordinator: how many workers to "
                         "spawn; worker: jax.distributed num_processes)")
    ap.add_argument("--jax-coordinator", default="",
                    help="jax.distributed coordinator address for real "
                         "multi-host meshes ('auto' on the coordinator "
                         "picks a local port; empty = no jax.distributed — "
                         "local CPU fleets need none)")
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--decode-chunk", type=int, default=4)
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas PER PROCESS (disjoint submeshes "
                         "of the process's local devices)")
    ap.add_argument("--devices-per-process", type=int, default=1,
                    help="forced CPU device count per worker (XLA_FLAGS)")
    ap.add_argument("--heartbeat-every", type=int, default=2)


def _warm_replicas(router, model) -> None:
    """Compile before hello: run one throwaway request through EVERY
    replica engine (each owns its own backend and mesh, so each pays its
    own prefill-bucket + decode jit). The worker's serve loop is single-
    threaded — a multi-second first-dispatch compile after admission
    would be heartbeat SILENCE, and the coordinator would declare a
    perfectly healthy process dead. Warming before the handshake keeps
    the death horizon tight instead of compile-sized. Metrics and step
    counters reset after, so reports describe only real traffic."""
    from repro.serve import ServeMetrics
    for eng in router.replicas:
        eng.submit([1, 2, 3], 2)
        eng.run()
        eng.metrics = ServeMetrics()
    router.step_count = 0


def worker_entry(args) -> None:
    """One fleet serving process, start to finish. Order is load-bearing:
    jax.distributed BEFORE any jax backend touch (device queries pin the
    backend), model load + engine build after, the socket loop last."""
    if args.jax_coordinator:
        from repro.serve.backend import ensure_distributed
        ensure_distributed(args.jax_coordinator, args.processes,
                           args.process_index)
    from repro.serve import (DistributedBackend, EngineConfig, FleetConfig,
                             ModelRegistry, ReplicaRouter, WorkerServer)
    from repro.serve.control import connect

    reg = ModelRegistry()
    model = reg.load(args.arch)
    cfg = EngineConfig(n_slots=args.slots, max_len=args.max_len,
                       decode_chunk=args.decode_chunk,
                       max_waiting=args.slots)
    mesh_shape = (args.replicas, 1)     # 1 device per replica, TP=1: the
    #                                     CPU-fleet shape; real runs widen

    def backend_factory(i: int) -> DistributedBackend:
        return DistributedBackend(
            mesh_shape=mesh_shape, n_replicas=args.replicas, replica=i,
            coordinator_address=args.jax_coordinator or None,
            num_processes=args.processes, process_id=args.process_index)

    router = ReplicaRouter.build(model, cfg, args.replicas,
                                 backend_factory=backend_factory)
    for i, eng in enumerate(router.replicas):
        eng.trace.process = args.process_index   # tag before any event
    _warm_replicas(router, model)
    endpoint = connect(args.connect)
    WorkerServer(router, endpoint, args.process_index,
                 cfg=FleetConfig(heartbeat_every=args.heartbeat_every,
                                 staleness=WALL_STALENESS,
                                 heartbeat_timeout=WALL_HEARTBEAT_TIMEOUT)
                 ).serve_forever()
    endpoint.close()


# --------------------------------------------------------------- coordinator

def _free_port() -> int:
    import socket
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _await_hello(endpoint, timeout: float = 120.0) -> Dict[str, Any]:
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        for msg in endpoint.poll():
            if msg.get("kind") == "hello":
                return msg
        if not endpoint.alive:
            raise RuntimeError("worker hung up before hello")
        time.sleep(0.01)
    raise TimeoutError("no hello from worker within timeout")


class Fleet:
    """A spawned local fleet: worker Popens + the FleetRouter over them.
    Context-manages cleanup so a failed bench never leaks processes."""

    def __init__(self, router, workers: List[subprocess.Popen],
                 listener) -> None:
        self.router = router
        self.workers = workers
        self.listener = listener

    def drive(self, max_seconds: float = 600.0) -> None:
        """Pump the router until every request finishes. Wall-paced: the
        coordinator's `now` advances one step per PACE sleep, which is
        what calibrates WALL_* horizons to seconds."""
        t0 = time.monotonic()
        while any(not r.finished for r in self.router.requests.values()):
            if time.monotonic() - t0 > max_seconds:
                raise TimeoutError("fleet did not drain in time")
            live = [pi for pi in self.router.processes
                    if pi not in self.router.state.dead]
            if not live:
                raise RuntimeError("every fleet process died")
            self.router.step()
            time.sleep(PACE)

    def shutdown(self) -> None:
        try:
            self.router.stop()
        finally:
            for w in self.workers:
                try:
                    w.wait(timeout=20)
                except subprocess.TimeoutExpired:
                    w.kill()
            self.listener.close()

    def __enter__(self) -> "Fleet":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
        return None


def spawn_fleet(n_processes: int, *, arch: str = "h2o-danube-1.8b",
                n_slots: int = 4, max_len: int = 96, decode_chunk: int = 4,
                replicas_per_process: int = 1,
                devices_per_process: int = 0,
                jax_coordinator: str = "", heartbeat_every: int = 2,
                cfg=None, hello_timeout: float = 300.0) -> Fleet:
    """Spawn `n_processes` worker subprocesses and return a Fleet whose
    router admits across them. Workers force their own CPU device counts
    (XLA_FLAGS in the child env — set BEFORE the child imports jax, the
    only reliable point to do it), so the parent's jax state is never
    touched: spawn_fleet is safe to call from pytest or a bench that
    already initialized jax."""
    from repro.serve import FleetConfig, FleetRouter
    from repro.serve.control import ControlListener, RemoteProcess

    listener = ControlListener()
    if jax_coordinator == "auto":
        jax_coordinator = f"127.0.0.1:{_free_port()}"
    devices = devices_per_process or replicas_per_process
    repo_src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    workers = []
    for i in range(n_processes):
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices}")
        env["JAX_PLATFORMS"] = env.get("JAX_PLATFORMS", "cpu")
        env["PYTHONPATH"] = repo_src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        cmd = [sys.executable, "-m", "repro.launch.fleet", "--worker",
               "--connect", listener.address,
               "--process-index", str(i), "--processes", str(n_processes),
               "--arch", arch, "--slots", str(n_slots),
               "--max-len", str(max_len),
               "--decode-chunk", str(decode_chunk),
               "--replicas", str(replicas_per_process),
               "--heartbeat-every", str(heartbeat_every)]
        if jax_coordinator:
            cmd += ["--jax-coordinator", jax_coordinator]
        workers.append(subprocess.Popen(cmd, env=env))
    try:
        handles = []
        for _ in range(n_processes):
            ep = listener.accept(timeout=hello_timeout)
            hello = _await_hello(ep, timeout=hello_timeout)
            handles.append(RemoteProcess(ep, int(hello["process_index"])))
        handles.sort(key=lambda h: h.process_index)
        cfg = cfg or FleetConfig(
            heartbeat_every=heartbeat_every,
            staleness=WALL_STALENESS,
            heartbeat_timeout=WALL_HEARTBEAT_TIMEOUT_DISTRIBUTED
            if jax_coordinator else WALL_HEARTBEAT_TIMEOUT)
        return Fleet(FleetRouter(handles, cfg=cfg), workers, listener)
    except Exception:
        for w in workers:
            w.kill()
        listener.close()
        raise


# --------------------------------------------------------------------- smoke

def run_smoke(args) -> int:
    """2-process fleet vs one in-coordinator engine on the same Poisson
    trace: token identity is the pass/fail; topology + throughput print.
    Optionally (--inject-death) kills one worker mid-trace and requires
    the heartbeat-timeout failover to keep the outputs token-identical."""
    import numpy as np
    from repro.serve import EngineConfig, InferenceEngine, ModelRegistry

    reg = ModelRegistry()
    model = reg.load(args.arch)
    rng = np.random.default_rng(args.seed)
    trace = []
    t = 0.0
    # death injection needs every request mid-generation long enough for
    # the kill to land: stretch the decode phase, same trace both sides
    gen_extra = 16 if args.inject_death else 0
    for _ in range(args.requests):
        t += rng.exponential(0.75)
        trace.append((int(t), rng.integers(0, model.cfg.vocab,
                                           int(rng.integers(4, 12))),
                      int(rng.integers(4, 10)) + gen_extra))

    eng = InferenceEngine(model, EngineConfig(
        n_slots=args.slots, max_len=args.max_len,
        decode_chunk=args.decode_chunk))
    ref = [eng.submit(p, g, arrival_step=a) for a, p, g in trace]
    eng.run()
    ref_toks = [list(r.generated) for r in ref]

    with spawn_fleet(args.processes, arch=args.arch, n_slots=args.slots,
                     max_len=args.max_len, decode_chunk=args.decode_chunk,
                     replicas_per_process=args.replicas,
                     jax_coordinator=args.jax_coordinator) as fleet:
        reqs = [fleet.router.submit(p, g, arrival_step=a)
                for a, p, g in trace]
        if args.inject_death:
            # crash a process while it is MID-GENERATION: the victim is
            # picked live (a process observed with an unfinished request
            # that has accumulated tokens) — a fixed victim races, e.g.
            # a slow-starting worker that never got any requests homed
            victim = None
            deadline = time.monotonic() + 60.0
            while victim is None:
                alive = [r.process for r in reqs
                         if r.process >= 0 and not r.finished
                         and len(r.tokens)]
                if alive:
                    victim = max(alive)
                    break
                if all(r.finished for r in reqs):
                    raise RuntimeError(
                        "trace drained before death injection — grow "
                        "--requests or --gen to widen the window")
                fleet.router.step()
                time.sleep(PACE)
                if time.monotonic() > deadline:
                    raise TimeoutError("no progress before death injection")
            fleet.router.processes[victim].kill()
            print(f"# injected death: process {victim}")
        fleet.drive()
        fleet.router.stop()
        rep = fleet.router.report()

    fleet_toks = [list(r.tokens) for r in reqs]
    identical = fleet_toks == ref_toks
    print(f"fleet {args.processes}x{args.replicas}: "
          f"{int(rep.get('fleet_requests_completed', 0))} reqs, "
          f"{int(rep.get('fleet_tokens', 0))} toks | "
          f"tokens/fleet-step {rep.get('tokens_per_fleet_step', 0):.2f} | "
          f"failovers {int(rep.get('fleet_failovers', 0))}, "
          f"dead {int(rep.get('processes_dead', 0))}, "
          f"resurrections ignored "
          f"{int(rep.get('resurrections_ignored', 0))} | "
          f"token-identical vs single: {identical}")
    if args.inject_death and not rep.get("fleet_failovers", 0):
        print("# FAIL: death injected but no failover happened")
        return 1
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"identical": identical, "report": rep}, f, indent=2)
            f.write("\n")
    return 0 if identical else 1


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Spawn a local N-process serving fleet (or run as one "
                    "of its workers).")
    worker_flags(ap)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--inject-death", action="store_true",
                    help="smoke: kill one worker mid-trace and require "
                         "token-identical failover")
    ap.add_argument("--out", default="", help="write smoke result JSON")
    args = ap.parse_args(argv)
    if args.worker:
        if not args.connect:
            ap.error("--worker requires --connect host:port")
        worker_entry(args)
        return 0
    return run_smoke(args)


if __name__ == "__main__":
    sys.exit(main())
