"""The assigned (architecture x input-shape) grid: 10 archs x 4 shapes.

Defines, per cell:
  * the step being lowered (train_step / prefill / decode),
  * `input_specs()` — weak-type-correct ShapeDtypeStruct stand-ins for every
    step input (params, optimizer state, batches, KV caches) — nothing is
    ever allocated,
  * applicability (long_500k only runs where the KV state is bounded or
    sub-quadratic; see DESIGN.md §Shape-cell skips),
  * the per-arch dry-run policy: grad-accumulation factor and dtypes chosen
    so every cell fits 16 GB/chip on the production mesh (verified by
    compiled.memory_analysis(), EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.distributed import steps as ST
from repro.models import transformer as T
from repro.optim import adamw as O


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str        # 'train' | 'prefill' | 'decode'
    seq: int
    batch: int


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4096, 256),
    ShapeCell("prefill_32k", "prefill", 32768, 32),
    ShapeCell("decode_32k", "decode", 32768, 128),
    ShapeCell("long_500k", "decode", 524288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}

# long_500k runs only where the per-layer KV state is bounded (SWA circular
# cache) or O(1) (SSM); pure full-attention archs are skipped per the brief.
LONG_OK = {
    "falcon_mamba_7b": "O(1) SSM state",
    "jamba_v0_1_52b": "SSM + 1:8 attn layers (the few full caches shard and fit)",
    "h2o_danube_1_8b": "SWA: cache capped at window=4096",
    "llava_next_mistral_7b": "Mistral SWA: cache capped at window=4096",
    "gemma2_27b": "alternating local/global: half the caches are window-capped,"
                  " the 23 global 500k caches shard over the mesh and fit",
}
LONG_SKIP_REASON = ("pure full-attention: every layer needs an unbounded "
                    "O(S) cache and O(S^2) prefill; skipped per brief")


def cell_applicable(arch: str, shape: str) -> Tuple[bool, str]:
    arch = C.ALIASES.get(arch, arch)
    if shape == "long_500k":
        if arch not in LONG_OK:
            return False, LONG_SKIP_REASON
        return True, LONG_OK[arch]
    return True, ""


# ---------------------------------------------------------------------------
# Per-arch dry-run policy (memory fitting knobs)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DryrunPolicy:
    grad_accum: int = 4              # train_4k microbatching
    opt_state_dtype: str = "float32"
    param_dtype: str = "bfloat16"
    act_dtype: str = "bfloat16"
    cache_dtype: str = "bfloat16"
    accum_dtype: str = "float32"     # grad-accumulation buffer
    remat_policy: str = "nothing"


POLICIES: Dict[str, DryrunPolicy] = {
    "minicpm3_4b": DryrunPolicy(grad_accum=4),
    # ga=4 (not 16): SP residual sharding fits the activations, and the
    # per-layer gradient psum over 'data' runs per microbatch — fewer
    # microbatches cut that wire term ~4x (§Perf H2.2).
    "nemotron_4_340b": DryrunPolicy(grad_accum=4, opt_state_dtype="bfloat16",
                                    accum_dtype="bfloat16"),
    "gemma2_27b": DryrunPolicy(grad_accum=8),
    "h2o_danube_1_8b": DryrunPolicy(grad_accum=2),
    "jamba_v0_1_52b": DryrunPolicy(grad_accum=8),
    "whisper_large_v3": DryrunPolicy(grad_accum=2),
    "deepseek_v2_lite_16b": DryrunPolicy(grad_accum=4),
    "deepseek_moe_16b": DryrunPolicy(grad_accum=4),
    "llava_next_mistral_7b": DryrunPolicy(grad_accum=8),
    "falcon_mamba_7b": DryrunPolicy(grad_accum=8),
}


def policy_for(arch: str) -> DryrunPolicy:
    return POLICIES[C.ALIASES.get(arch, arch)]


def config_for_dryrun(arch: str, **overrides) -> T.ModelConfig:
    """Full published config with dry-run dtypes applied."""
    pol = policy_for(arch)
    cfg = C.get_config(arch)
    return dataclasses.replace(
        cfg, param_dtype=pol.param_dtype, dtype=pol.act_dtype,
        remat=True, remat_policy=pol.remat_policy, **overrides)


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input builders (never allocate)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: T.ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    b, s = cell.batch, cell.seq
    out = {"tokens": _sds((b, s), jnp.int32), "labels": _sds((b, s), jnp.int32)}
    if cfg.enc_dec:
        out["frames"] = _sds((b, cfg.enc_positions, cfg.d_model), jnp.float32)
    if cfg.n_img_tokens:
        out["img_embeds"] = _sds((b, cfg.n_img_tokens, cfg.d_model),
                                 jnp.float32)
    return out


def state_specs(cfg: T.ModelConfig, opt_cfg: O.OptimizerConfig):
    """eval_shape of the training state (params + AdamW m/v + step)."""
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)

    def build(k):
        return ST.init_train_state(k, cfg, opt_cfg)

    return jax.eval_shape(build, key)


def param_specs(cfg: T.ModelConfig):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: T.init(k, cfg), key)


def cache_specs(cfg: T.ModelConfig, batch: int, max_len: int, dtype):
    return jax.eval_shape(
        functools.partial(T.make_caches, cfg, batch, max_len, dtype=dtype))


def cell_inputs(arch: str, cell: ShapeCell, cfg: Optional[T.ModelConfig] = None,
                opt_cfg: Optional[O.OptimizerConfig] = None):
    """Returns (step_kind, args_pytree_of_SDS) for the cell."""
    pol = policy_for(arch)
    cfg = cfg or config_for_dryrun(arch)
    if cell.kind == "train":
        opt_cfg = opt_cfg or O.OptimizerConfig(state_dtype=pol.opt_state_dtype)
        state = state_specs(cfg, opt_cfg)
        batch = batch_specs(cfg, cell)
        return "train", (state, batch)
    params = param_specs(cfg)
    cdtype = jnp.dtype(pol.cache_dtype)
    if cell.kind == "prefill":
        max_len = cell.seq + cfg.n_img_tokens
        caches = cache_specs(cfg, cell.batch, max_len, cdtype)
        batch = batch_specs(cfg, cell)
        del batch["labels"]
        return "prefill", (params, batch, caches)
    # decode: one new token against a cache holding `seq` positions
    max_len = cell.seq + cfg.n_img_tokens
    caches = cache_specs(cfg, cell.batch, max_len, cdtype)
    token = _sds((cell.batch, 1), jnp.int32)
    index = _sds((), jnp.int32)
    return "decode", (params, caches, token, index)


def make_step_fn(arch: str, cell: ShapeCell, cfg: Optional[T.ModelConfig] = None,
                 opt_cfg: Optional[O.OptimizerConfig] = None, *,
                 mesh_dp: int = 16, backend: str = "ref"):
    """The python callable lowered for this cell."""
    pol = policy_for(arch)
    cfg = cfg or config_for_dryrun(arch)
    if cell.kind == "train":
        opt_cfg = opt_cfg or O.OptimizerConfig(state_dtype=pol.opt_state_dtype)
        # keep >= 1 batch row per data shard in each microbatch
        ga = max(1, min(pol.grad_accum, cell.batch // max(mesh_dp, 1)))
        return ST.make_train_step(cfg, opt_cfg, grad_accum=ga, backend=backend,
                                  accum_dtype=pol.accum_dtype)
    if cell.kind == "prefill":
        return ST.make_prefill_step(cfg, backend=backend)
    return ST.make_decode_step(cfg, backend=backend)
