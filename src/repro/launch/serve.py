"""Batched serving launcher: prefill + decode with KV caches and sampling.

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
      --batch 4 --prompt-len 32 --gen 32 [--temperature 0.8]

Runs the reduced config on CPU; the serve steps are the SAME functions the
decode_32k / long_500k dry-run cells lower for the production mesh.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.distributed import steps as ST
from repro.models import transformer as T


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch)
    b, s0, gen = args.batch, args.prompt_len, args.gen
    params = T.init(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (b, s0)), jnp.int32)

    batch = {"tokens": prompts}
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.enc_positions, cfg.d_model)) * 0.1,
            jnp.float32)
    if cfg.n_img_tokens:
        batch["img_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_img_tokens, cfg.d_model)) * 0.1,
            jnp.float32)

    max_len = cfg.n_img_tokens + s0 + gen
    caches = T.make_caches(cfg, b, max_len)
    prefill = jax.jit(ST.make_prefill_step(cfg))
    decode = jax.jit(ST.make_decode_step(cfg))

    t0 = time.time()
    logits, caches = prefill(params, batch, caches)
    print(f"[serve] prefill {b}x{s0} in {time.time()-t0:.2f}s")

    key = jax.random.PRNGKey(args.seed + 1)

    def sample(key, logits):
        if args.temperature <= 0:
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits[:, -1] / args.temperature).astype(jnp.int32)

    tok = sample(key, logits)[:, None]
    out = [tok]
    t0 = time.time()
    for t in range(gen - 1):
        index = jnp.int32(cfg.n_img_tokens + s0 + t)
        logits, caches = decode(params, caches, tok, index)
        key, sub = jax.random.split(key)
        tok = sample(sub, logits)[:, None]
        out.append(tok)
    dt = time.time() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"[serve] decoded {gen} tokens x {b} requests in {dt:.2f}s "
          f"({b * gen / max(dt, 1e-9):.1f} tok/s)")
    for i in range(min(b, 2)):
        print(f"  req{i}: {np.asarray(toks[i])[:16]} ...")


if __name__ == "__main__":
    main()
