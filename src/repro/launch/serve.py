"""Serving launcher: continuous-batching engine over packed Kratos weights.

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
      --requests 8 --prompt-len 32 --gen 32 \
      [--sparsity 0.5 --bits 8 --impl tree] [--slots 4] [--static] \
      [--temperature 0.8] \
      [--speculate 4 --draft-bits 8 [--draft-sparsity S] \
       [--draft-keep-layers N]] \
      [--page-size P [--n-pages N] [--no-prefix-cache]] \
      [--mesh data,model] [--replicas N] [--max-waiting M] [--dry-run] \
      [--trace-out T.jsonl] [--trace-chrome T.json] [--profile-dir D] \
      [--telemetry-port P] [--telemetry-jsonl S.jsonl] \
      [--ledger [--ledger-threshold T] [--quality-every N]] \
      [--tiers 8:0.5,8:0.75 [--qos-*]] [--deadline-steps D] \
      [--pool-wait-retries R] [--auto-restart]

Resilience (PR 7): `--tiers bits:sparsity[,...]` loads a QoS degradation
ladder — the same weights re-packed at cheaper (sparsity, bits) points,
all resident — and the engine demotes the live decode down the ladder
under sustained queue depth / page pressure (hysteresis via --qos-*),
re-promoting when load clears; in-flight streams continue across swaps.
`--deadline-steps` sheds doomed work at admission and cancels expired
work in flight; `--pool-wait-retries` bounds PoolExhausted requeues with
exponential backoff; `--auto-restart` rebuilds a replica the router
marked dead (serve.qos, serve.chaos, router failover).

Observability: `--trace-out` / `--trace-chrome` switch the engines to the
ring-buffer tracer (serve.trace) and export every lifecycle/dispatch edge
as JSONL / chrome://tracing JSON after the run; `--profile-dir` brackets
the first N traced dispatches with jax.profiler (device timeline next to
the host spans); `--telemetry-port` serves live Prometheus text at
/metrics during the run and `--telemetry-jsonl` appends one metrics
snapshot per `--telemetry-interval` (serve.telemetry). `--ledger` carries
the ineffectual-work counter matrix (serve.ledger) through every decode /
speculative / suffix-prefill dispatch as donated device state — activation
zero fractions, per-group zero histograms, dead k-blocks, effective vs
dense FLOPs/bytes — drained once per dispatch inside the existing token
sync and surfaced through ServeMetrics, the tracer's Chrome counter
tracks, and Prometheus; `--quality-every N` shadow-runs every Nth
full-prefill admission through tier 0 for per-tier logit agreement.

Paged KV + prefix reuse: `--page-size P` switches the KV pool to the
block-paged form (serve.paging) — per-slot page tables over refcounted
fixed-size pages — with the radix prefix index on by default where the
arch supports it: admissions sharing a cached prompt prefix skip its
prefill and share its pages. `--n-pages N` sizes the pool (default:
slab-equivalent capacity); the engine report prints the prefix hit rate,
prefill tokens skipped, and page occupancy to steer P by (smaller pages =
finer sharing granularity + more table entries; start at 8-16).

Speculative decode: `--speculate K` derives a SELF-DRAFT artifact (the same
weights re-packed at the --draft-* Kratos point, serve.speculative) and
serves with the fused propose-then-verify dispatch — 1..K+1 tokens commit
per dispatch, greedy output token-identical to plain decode. Replaces
--decode-chunk. Acceptance-rate tuning: start with --draft-bits 8 (highest
fidelity, ~1.0 acceptance), add sparsity / layer truncation to cut draft
FLOPs while acceptance stays above ~0.8; the engine report prints the
acceptance rate and draft/verify FLOP ratio to steer by.

Loads the reduced config on CPU through the serve registry (weights packed
once via kratos.pack), submits `--requests` generation requests with a small
prompt-length jitter, and drives the engine until the trace drains. The
engine's prefill/decode steps are the SAME `distributed.steps` factories the
decode_32k / long_500k dry-run cells lower for the production mesh — the
per-slot-index decode is a strict generalization of the lock-step step.

Mesh serving: `--mesh 2,4` places every replica's params/slab/state over a
(data=2, model=4) mesh via `serve.ShardedBackend` (force CPU devices first:
XLA_FLAGS=--xla_force_host_platform_device_count=8). `--replicas N` fronts
N engines with `serve.ReplicaRouter` — with a mesh, the data axis splits
into one disjoint submesh per replica (launch.mesh.replica_meshes); without
one, N LocalBackend replicas share the default device. `--dry-run` prints
the RESOLVED placement — one line per cache/state leaf with its
PartitionSpec — plus the loop-aware cost of the lowered sharded decode step
(analysis.hlo: flops, memory bytes, collective wire bytes) and exits
without running traffic.

Fleet serving (PR 10): `--processes N` serves through a local fleet — N
worker processes spawned by launch.fleet, each running its own engines on
its own forced CPU devices, fronted by the cross-process FleetRouter
(serve.control). `--coordinator HOST:PORT --num-processes M
--process-id I` instead identifies THIS process in a real multi-host
launch (jax.distributed.initialize via serve.ensure_distributed).
`--dry-run` with either prints the resolved fleet topology — process ->
local devices -> replica meshes -> per-leaf cache/state shardings —
BEFORE any weight packing, so a short device count, an uneven replica
split, or a wrong num_processes fails in milliseconds, not after a
multi-minute pack.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core.kratos import KratosSpec
from repro.serve import (DraftSpec, EngineConfig, InferenceEngine,
                         LedgerConfig, LocalBackend, ModelRegistry,
                         QoSConfig, ReplicaRouter, ShardedBackend,
                         StaticScheduler, TelemetryConfig, TelemetryExporter,
                         TraceConfig, engine_sample, export_chrome,
                         export_jsonl, parse_tiers, router_sample)


def _dry_run(model, cfg: EngineConfig, mesh_shape) -> None:
    """Print resolved shardings per cache/state/param group + decode cost."""
    import jax
    from repro.analysis import hlo as HA
    from repro.distributed import sharding as SH, steps as ST
    from repro.launch import mesh as M
    from repro.models import transformer as T

    mesh = M.make_local_mesh(*mesh_shape)
    print(f"[dry-run] mesh {dict(mesh.shape)} over {mesh.size} devices")
    cache_len = cfg.max_len + cfg.speculate   # +K speculative write headroom
    caches = jax.eval_shape(
        lambda: T.make_caches(model.cfg, cfg.n_slots, cache_len))
    cache_specs = SH.cache_pspecs(caches, mesh, cfg.n_slots, slab=True)
    if not cfg.page_size:
        print(f"[dry-run] KV slab leaves ({cfg.n_slots} slots x "
              f"{cache_len} positions"
              + (f" = max_len + K={cfg.speculate} headroom" if cfg.speculate
                 else "") + "):")
        for path, spec in jax.tree_util.tree_leaves_with_path(
                cache_specs, is_leaf=lambda x: isinstance(
                    x, jax.sharding.PartitionSpec)):
            print(f"    {jax.tree_util.keystr(path):48s} {spec}")
    print("[dry-run] decode state vectors:")
    for k, spec in ST.decode_state_pspecs(mesh, cfg.n_slots).items():
        print(f"    {k:48s} {spec}")
    backend = ShardedBackend(mesh=mesh)
    backend.build(model, cfg)
    if cfg.page_size:
        # resolved page-pool geometry: what the slab stride turned into
        pool = backend.pool
        d = pool.describe()
        print(f"[dry-run] page pool: {d['n_pages']} pages x "
              f"{d['page_size']} positions ({d['pages_per_slot']}/slot x "
              f"{cfg.n_slots} slots"
              + (f", + K={cfg.speculate} headroom in the last page(s)"
                 if cfg.speculate else "")
              + f"), {d['bytes'] / 1e6:.2f} MB, prefix cache "
              + ("ON" if d["prefix_cache"] else
                 "OFF (arch cache state not purely positional)"))
        print("[dry-run] page-store leaves (paged = page-major; resident = "
              "slot-major slab layout):")
        for leaf, spec in zip(pool.layout.specs, pool.shardings):
            kind = "paged   " if leaf.paged else "resident"
            print(f"    {leaf.name:40s} {kind} {spec.spec}")
        print(f"    {'page_table':40s} table    "
              f"{pool.table_sharding.spec}")
    if cfg.speculate:
        # the step that will actually dispatch: fused propose-then-verify
        if cfg.page_size:
            compiled = backend._spec_decode.lower(
                backend.params, backend.draft_params, backend.pool.store,
                backend.pool.page_table, backend.draft_pool.caches,
                backend.state).compile()
        else:
            compiled = backend._spec_decode.lower(
                backend.params, backend.draft_params, backend.pool.caches,
                backend.draft_pool.caches, backend.state).compile()
        label = f"speculative step (K={cfg.speculate}, draft replicated)"
    elif cfg.page_size:
        compiled = backend._decode.lower(
            backend.params, backend.pool.store, backend.pool.page_table,
            backend.state).compile()
        label = f"paged decode step (K={cfg.decode_chunk}, " \
                f"page={cfg.page_size})"
    else:
        compiled = backend._decode.lower(
            backend.params, backend.pool.caches, backend.state).compile()
        label = f"decode step (K={cfg.decode_chunk})"
    r = HA.analyze(compiled.as_text())
    coll = {k: int(v["count"]) for k, v in r["collectives"].items()
            if v["count"]}
    print(f"[dry-run] {label}: "
          f"{r['flops']:.3g} flops, {r['bytes']:.3g} B touched, "
          f"{r['wire_bytes']:.3g} B wire, collectives {coll or 'none'}")


def _dry_run_fleet(args, M) -> None:
    """Resolved fleet topology, NO weight packing: process -> local
    devices -> replica meshes -> per-leaf cache/state shardings. The
    sharding resolution runs over an AbstractMesh of the per-process
    replica shape, so nothing here allocates or packs — a bad topology
    fails in milliseconds."""
    import jax
    from jax.sharding import AbstractMesh

    from repro import configs as C
    from repro.distributed import sharding as SH, steps as ST
    from repro.models import transformer as T

    mesh_shape = (M.parse_mesh_arg(args.mesh) if args.mesh
                  else (args.replicas, 1))
    data, model_ax = mesh_shape
    if args.coordinator:
        from repro.serve import ensure_distributed
        ensure_distributed(args.coordinator, args.num_processes,
                           args.process_id)
        live = M.fleet_topology(data, model_ax, args.replicas)
        print(f"[dry-run] fleet (live): process {live['process_index']} "
              f"of {live['num_processes']}, "
              f"{live['global_device_count']} global devices, "
              f"mesh {data}x{model_ax}, {args.replicas} replicas/process")
        procs = [live]
    else:
        plan = M.plan_fleet_topology(args.processes, data * model_ax,
                                     data, model_ax, args.replicas)
        print(f"[dry-run] fleet (planned): {plan['num_processes']} "
              f"processes x {plan['devices_per_process']} forced CPU "
              f"devices = {plan['global_device_count']} global, "
              f"mesh {data}x{model_ax}, {args.replicas} replicas/process")
        procs = plan["processes"]
    for p in procs:
        print(f"[dry-run]   process {p['process_index']}: "
              + " ".join(p["local_devices"]))
        for rm in p["replica_meshes"]:
            shape = "x".join(str(v) for v in rm["shape"].values())
            print(f"[dry-run]     replica {rm['replica']} ({shape}): "
                  + " ".join(rm["devices"]))

    # per-leaf shardings on the per-replica submesh, shape-only
    sub = AbstractMesh((("data", data // args.replicas),
                        ("model", model_ax)))
    cfg = C.get_smoke(args.arch)
    max_len = args.max_len or (cfg.n_img_tokens + args.prompt_len
                               + args.gen + 8)
    caches = jax.eval_shape(lambda: T.make_caches(cfg, args.slots, max_len))
    print(f"[dry-run] per-replica KV slab leaves ({args.slots} slots x "
          f"{max_len} positions):")
    cache_specs = SH.cache_pspecs(caches, sub, args.slots, slab=True)
    for path, spec in jax.tree_util.tree_leaves_with_path(
            cache_specs, is_leaf=lambda x: isinstance(
                x, jax.sharding.PartitionSpec)):
        print(f"    {jax.tree_util.keystr(path):48s} {spec}")
    print("[dry-run] per-replica decode state vectors:")
    for k, spec in ST.decode_state_pspecs(sub, args.slots).items():
        print(f"    {k:48s} {spec}")


def _serve_fleet(args) -> None:
    """Local-fleet serving: spawn N workers (launch.fleet), front them
    with the FleetRouter, drive the same Poisson-ish trace."""
    import numpy as np

    from repro.launch import fleet as F

    rng = np.random.default_rng(args.seed)
    with F.spawn_fleet(args.processes, arch=args.arch, n_slots=args.slots,
                       max_len=args.max_len or 96,
                       decode_chunk=args.decode_chunk,
                       replicas_per_process=args.replicas) as fl:
        reqs = []
        for i in range(args.requests):
            s0 = max(1, args.prompt_len + int(rng.integers(-4, 5)))
            prompt = rng.integers(0, 32000, s0)
            reqs.append(fl.router.submit(
                list(map(int, prompt)), args.gen,
                temperature=args.temperature))
        fl.drive()
        fl.router.stop()
        rep = fl.router.report()
        print(f"[serve] fleet {args.processes} processes: "
              f"{rep['fleet_tokens']:.0f} tokens, "
              f"{rep['fleet_requests_completed']:.0f} done, "
              f"{rep['tokens_per_fleet_step']:.2f} tok/fleet-step, "
              f"failovers {rep['fleet_failovers']:.0f}")
        for r in reqs[:2]:
            print(f"  req{r.rid}: {np.asarray(r.tokens)[:16]} ...")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=0,
                    help="cache positions per slot (0 = prompt+gen+slack)")
    ap.add_argument("--sparsity", type=float, default=0.0)
    ap.add_argument("--bits", type=int, default=0, help="0 = native bf16/f32")
    ap.add_argument("--act-bits", type=int, default=0, help="8 => w8a8")
    ap.add_argument("--impl", default="tree", choices=("tree", "systolic"))
    ap.add_argument("--block", type=int, default=8, help="sparsity bk=bn")
    ap.add_argument("--static", action="store_true",
                    help="lock-step drain-then-refill baseline scheduler")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--decode-chunk", type=int, default=1,
                    help="K micro-steps per device-resident decode dispatch "
                         "(throughput up, admission latency up)")
    ap.add_argument("--host-loop", action="store_true",
                    help="PR-1 host decode loop (per-step logits pull + "
                         "numpy sampling) instead of the device-resident one")
    ap.add_argument("--speculate", type=int, default=0,
                    help="K-token self-draft speculation per dispatch "
                         "(derives a draft artifact from the same weights; "
                         "replaces --decode-chunk; greedy output unchanged)")
    ap.add_argument("--draft-bits", type=int, default=8,
                    help="draft weight bits for --speculate (0 = native)")
    ap.add_argument("--draft-sparsity", type=float, default=0.0,
                    help="draft sparsity for --speculate (bk=bn=8 blocks)")
    ap.add_argument("--draft-keep-layers", type=int, default=0,
                    help="truncate the draft to its first N layers (0=all)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="block-paged KV pool with P positions per page "
                         "(0 = slab); enables cross-request prefix reuse "
                         "where the arch supports it")
    ap.add_argument("--n-pages", type=int, default=0,
                    help="page-pool size for --page-size (0 = slab-"
                         "equivalent: slots x pages_per_slot + sink)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="paged pool without the radix prefix index "
                         "(paging only: no cross-request sharing)")
    ap.add_argument("--mesh", default="",
                    help="'data,model' sizes: serve through ShardedBackend "
                         "on a local mesh of that shape")
    ap.add_argument("--replicas", type=int, default=1,
                    help="front N engine replicas with the ReplicaRouter "
                         "(with --mesh, one data-submesh per replica)")
    ap.add_argument("--max-waiting", type=int, default=0,
                    help="bound each replica's waiting deque (0 = unbounded);"
                         " rejections spill across replicas")
    ap.add_argument("--dry-run", action="store_true",
                    help="print resolved cache/state shardings + decode cost "
                         "for --mesh and exit (no traffic)")
    ap.add_argument("--trace-out", default="",
                    help="record every lifecycle/dispatch edge (serve.trace) "
                         "and export the JSONL event stream here")
    ap.add_argument("--trace-chrome", default="",
                    help="export the trace as chrome://tracing JSON (one "
                         "process per replica, one track per slot)")
    ap.add_argument("--profile-dir", default="",
                    help="bracket the first --profile-dispatches traced "
                         "dispatches with jax.profiler (TensorBoard dir)")
    ap.add_argument("--profile-dispatches", type=int, default=3,
                    help="dispatches inside the --profile-dir bracket")
    ap.add_argument("--telemetry-port", type=int, default=-1,
                    help="serve Prometheus text on 127.0.0.1:PORT/metrics "
                         "during the run (0 = ephemeral port; -1 = off)")
    ap.add_argument("--telemetry-interval", type=float, default=1.0,
                    help="telemetry snapshot cadence, seconds")
    ap.add_argument("--telemetry-jsonl", default="",
                    help="append one JSON metrics snapshot per interval here")
    ap.add_argument("--ledger", action="store_true",
                    help="ineffectual-work ledger (serve.ledger): device-"
                         "resident activation-sparsity / effective-FLOP "
                         "counters drained once per dispatch inside the "
                         "existing token sync (device loop only)")
    ap.add_argument("--ledger-threshold", type=float, default=0.0,
                    help="|x| <= t counts as near-zero in the ledger probes "
                         "(0 = exact zeros only)")
    ap.add_argument("--ledger-group", type=int, default=8,
                    help="ledger per-group zero histogram group size")
    ap.add_argument("--ledger-kblock", type=int, default=32,
                    help="ledger dead-k-block granularity (contraction-dim "
                         "block size an activation-skip kernel would use)")
    ap.add_argument("--quality-every", type=int, default=0,
                    help="shadow-run every Nth full-prefill admission "
                         "through tier 0 and record per-tier logit "
                         "agreement (0 = off; implies --ledger wiring)")
    ap.add_argument("--tiers", default="",
                    help="QoS degradation ladder: 'bits:sparsity[,...]' "
                         "cheapest-last (e.g. '8:0.5,8:0.75') — the registry "
                         "keeps each tier resident and the engine demotes "
                         "the live decode to it under load (serve.qos)")
    ap.add_argument("--qos-demote-depth", type=int, default=8,
                    help="waiting-queue depth that (with hysteresis) demotes "
                         "one tier")
    ap.add_argument("--qos-promote-depth", type=int, default=1,
                    help="queue depth at/below which the engine re-promotes")
    ap.add_argument("--qos-hysteresis", type=int, default=4,
                    help="consecutive steps over/under threshold before a "
                         "tier change")
    ap.add_argument("--qos-page-pressure", type=float, default=0.95,
                    help="page-pool occupancy fraction that also counts as "
                         "overload (paged engines)")
    ap.add_argument("--deadline-steps", type=int, default=0,
                    help="per-request deadline in engine steps (0 = none): "
                         "doomed work is shed at admission, expired work "
                         "cancelled in flight")
    ap.add_argument("--pool-wait-retries", type=int, default=-1,
                    help="bound PoolExhausted requeues per request with "
                         "exponential backoff; past the cap the request is "
                         "shed (-1 = unbounded legacy wait)")
    ap.add_argument("--auto-restart", action="store_true",
                    help="router: rebuild a replica marked dead by a "
                         "ReplicaFault instead of serving degraded")
    ap.add_argument("--processes", type=int, default=1,
                    help="serve through a local fleet of N worker "
                         "processes (launch.fleet + serve.FleetRouter); "
                         "each worker runs --replicas engines on its own "
                         "forced CPU devices")
    ap.add_argument("--coordinator", default="",
                    help="jax.distributed coordinator HOST:PORT for a real "
                         "multi-host launch (this process joins the fleet)")
    ap.add_argument("--num-processes", type=int, default=1,
                    help="fleet size for --coordinator")
    ap.add_argument("--process-id", type=int, default=0,
                    help="this process's index for --coordinator")
    args = ap.parse_args()

    from repro.launch import mesh as M

    fleet_mode = args.processes > 1 or args.coordinator
    if args.dry_run and fleet_mode:
        # Topology resolution must fail BEFORE the (expensive) weight pack:
        # no registry.load on this path, config comes shape-only.
        _dry_run_fleet(args, M)
        return
    if args.processes > 1:
        # workers pack their own weights; the coordinator never loads
        _serve_fleet(args)
        return
    if args.coordinator:
        # real multi-host: join the fleet before any jax backend touch
        from repro.serve import ensure_distributed
        ensure_distributed(args.coordinator, args.num_processes,
                           args.process_id)

    spec = KratosSpec(sparsity=args.sparsity,
                      bits=args.bits or None,
                      act_bits=args.act_bits or None,
                      impl=args.impl, bk=args.block, bn=args.block)
    draft = None
    if args.speculate:
        draft = DraftSpec.from_args(args.draft_bits, args.draft_sparsity,
                                    args.draft_keep_layers)
    tier_specs = parse_tiers(args.tiers) if args.tiers else ()
    registry = ModelRegistry()
    model = registry.load(args.arch, spec, seed=args.seed, draft_spec=draft,
                          tier_specs=tier_specs)
    print(f"[serve] {model.name}: {model.n_packed} packed projections, "
          f"{model.packed_bytes / 1e6:.2f} MB packed vs "
          f"{model.dense_bytes / 1e6:.2f} MB dense "
          f"({model.compression:.2f}x)")
    if tier_specs:
        print(f"[serve] QoS ladder: tier 0 (target) + "
              + ", ".join(f"tier {i + 1} = {t.tag}"
                          for i, t in enumerate(tier_specs)))
    if draft is not None:
        print(f"[serve] self-draft {draft.tag}: {model.draft_packed} packed "
              f"projections, draft/verify flops "
              f"{model.draft_cost_fraction():.2f}, K={args.speculate}")

    max_len = args.max_len or (model.cfg.n_img_tokens + args.prompt_len
                               + args.gen + 8)
    tracing = bool(args.trace_out or args.trace_chrome or args.profile_dir)
    trace_cfg = TraceConfig(
        out=args.trace_out or None, chrome=args.trace_chrome or None,
        profile_dir=args.profile_dir or None,
        profile_dispatches=args.profile_dispatches) if tracing else None
    ledger_cfg = None
    if args.ledger or args.quality_every:
        if args.host_loop:
            raise SystemExit("--ledger requires the device-resident loop "
                             "(drop --host-loop)")
        ledger_cfg = LedgerConfig(threshold=args.ledger_threshold,
                                  group=args.ledger_group,
                                  k_block=args.ledger_kblock,
                                  quality_every=args.quality_every)
    qos = QoSConfig(demote_depth=args.qos_demote_depth,
                    promote_depth=args.qos_promote_depth,
                    hysteresis=args.qos_hysteresis,
                    page_pressure=args.qos_page_pressure) \
        if tier_specs else None
    cfg = EngineConfig(n_slots=args.slots, max_len=max_len, seed=args.seed,
                       device_loop=not args.host_loop,
                       decode_chunk=args.decode_chunk,
                       speculate=args.speculate,
                       max_waiting=args.max_waiting or None,
                       page_size=args.page_size or None,
                       n_pages=args.n_pages or None,
                       prefix_cache=not args.no_prefix_cache,
                       pool_wait_retries=args.pool_wait_retries
                       if args.pool_wait_retries >= 0 else None,
                       qos=qos, trace=trace_cfg, ledger=ledger_cfg)
    mesh_shape = M.parse_mesh_arg(args.mesh) if args.mesh else None

    if args.dry_run:
        if mesh_shape is None:
            raise SystemExit("--dry-run needs --mesh data,model")
        _dry_run(model, cfg, mesh_shape)
        return

    def backend_for(i: int):
        if mesh_shape is None:
            return LocalBackend()
        if args.replicas > 1:
            meshes = backend_for.meshes
            return ShardedBackend(mesh=meshes[i])
        return ShardedBackend(mesh_shape=mesh_shape)

    if mesh_shape is not None and args.replicas > 1:
        backend_for.meshes = M.replica_meshes(*mesh_shape, args.replicas)

    rng = np.random.default_rng(args.seed)

    def trace():
        for i in range(args.requests):
            s0 = max(1, args.prompt_len + int(rng.integers(-4, 5)))
            yield rng.integers(0, model.cfg.vocab, s0), args.gen, i

    def telemetry_for(sample_fn):
        if args.telemetry_port < 0 and not args.telemetry_jsonl:
            return None
        exp = TelemetryExporter(sample_fn, TelemetryConfig(
            interval=args.telemetry_interval,
            port=args.telemetry_port if args.telemetry_port >= 0 else None,
            jsonl=args.telemetry_jsonl or None,
            # fleet processes exporting on one host need distinct series;
            # single-process output stays byte-identical (no label)
            process_index=args.process_id if args.coordinator else None))
        exp.start()
        if exp.port is not None:
            print(f"[serve] telemetry: http://127.0.0.1:{exp.port}/metrics")
        return exp

    if args.replicas > 1:
        router = ReplicaRouter.build(
            model, cfg, args.replicas,
            backend_factory=backend_for,
            scheduler_factory=(lambda i: StaticScheduler()) if args.static
            else None,
            auto_restart=args.auto_restart)
        telemetry = telemetry_for(lambda: router_sample(router))
        reqs = [router.submit(p, g, arrival_step=at,
                              temperature=args.temperature,
                              deadline_steps=args.deadline_steps or None)
                for p, g, at in trace()]
        router.run()
        if telemetry is not None:
            telemetry.stop()
        tracers = router.tracers
        print(f"[serve] router {router.format_report()}")
    else:
        from repro.serve import EngineSaturated
        engine = InferenceEngine(
            model, cfg,
            scheduler=StaticScheduler() if args.static else None,
            backend=backend_for(0))
        telemetry = telemetry_for(lambda: engine_sample(engine))
        reqs = []
        for p, g, at in trace():
            # bounded deque + upfront trace submission: back off like a
            # client would — step the engine until the submit is accepted
            while True:
                try:
                    reqs.append(engine.submit(
                        p, g, arrival_step=at,
                        temperature=args.temperature,
                        deadline_steps=args.deadline_steps or None))
                    break
                except EngineSaturated:
                    engine.step()
        engine.run()
        if telemetry is not None:
            telemetry.stop()
        tracers = [engine.trace] if engine.trace.enabled else []
        print(f"[serve] scheduler={engine.scheduler.name} "
              f"backend={engine.backend.name} "
              f"{engine.metrics.format_report()}")
    if tracers:
        if args.trace_out:
            n = export_jsonl(tracers, args.trace_out)
            print(f"[serve] trace: {n} events -> {args.trace_out}")
        if args.trace_chrome:
            n = export_chrome(tracers, args.trace_chrome)
            print(f"[serve] chrome trace: {n} events -> {args.trace_chrome} "
                  f"(open in chrome://tracing or ui.perfetto.dev)")
        if args.profile_dir:
            print(f"[serve] profiler capture (first "
                  f"{args.profile_dispatches} dispatches) -> "
                  f"{args.profile_dir}")
    for r in reqs[:2]:
        print(f"  req{r.id}: {np.asarray(r.generated)[:16]} ...")


if __name__ == "__main__":
    main()
