"""Serving launcher: continuous-batching engine over packed Kratos weights.

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
      --requests 8 --prompt-len 32 --gen 32 \
      [--sparsity 0.5 --bits 8 --impl tree] [--slots 4] [--static] \
      [--temperature 0.8]

Loads the reduced config on CPU through the serve registry (weights packed
once via kratos.pack), submits `--requests` generation requests with a small
prompt-length jitter, and drives the engine until the trace drains. The
engine's prefill/decode steps are the SAME `distributed.steps` factories the
decode_32k / long_500k dry-run cells lower for the production mesh — the
per-slot-index decode is a strict generalization of the lock-step step.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.core.kratos import KratosSpec
from repro.serve import (EngineConfig, InferenceEngine, ModelRegistry,
                         StaticScheduler)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=0,
                    help="cache positions per slot (0 = prompt+gen+slack)")
    ap.add_argument("--sparsity", type=float, default=0.0)
    ap.add_argument("--bits", type=int, default=0, help="0 = native bf16/f32")
    ap.add_argument("--act-bits", type=int, default=0, help="8 => w8a8")
    ap.add_argument("--impl", default="tree", choices=("tree", "systolic"))
    ap.add_argument("--block", type=int, default=8, help="sparsity bk=bn")
    ap.add_argument("--static", action="store_true",
                    help="lock-step drain-then-refill baseline scheduler")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--decode-chunk", type=int, default=1,
                    help="K micro-steps per device-resident decode dispatch "
                         "(throughput up, admission latency up)")
    ap.add_argument("--host-loop", action="store_true",
                    help="PR-1 host decode loop (per-step logits pull + "
                         "numpy sampling) instead of the device-resident one")
    args = ap.parse_args()

    spec = KratosSpec(sparsity=args.sparsity,
                      bits=args.bits or None,
                      act_bits=args.act_bits or None,
                      impl=args.impl, bk=args.block, bn=args.block)
    registry = ModelRegistry()
    model = registry.load(args.arch, spec, seed=args.seed)
    print(f"[serve] {model.name}: {model.n_packed} packed projections, "
          f"{model.packed_bytes / 1e6:.2f} MB packed vs "
          f"{model.dense_bytes / 1e6:.2f} MB dense "
          f"({model.compression:.2f}x)")

    max_len = args.max_len or (model.cfg.n_img_tokens + args.prompt_len
                               + args.gen + 8)
    engine = InferenceEngine(
        model,
        EngineConfig(n_slots=args.slots, max_len=max_len, seed=args.seed,
                     device_loop=not args.host_loop,
                     decode_chunk=args.decode_chunk),
        scheduler=StaticScheduler() if args.static else None)

    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        s0 = max(1, args.prompt_len + int(rng.integers(-4, 5)))
        prompt = rng.integers(0, model.cfg.vocab, s0)
        reqs.append(engine.submit(prompt, args.gen, arrival_step=i,
                                  temperature=args.temperature))
    engine.run()
    print(f"[serve] scheduler={engine.scheduler.name} "
          f"{engine.metrics.format_report()}")
    for r in reqs[:2]:
        print(f"  req{r.id}: {np.asarray(r.generated)[:16]} ...")


if __name__ == "__main__":
    main()
