"""QoS tiers: the Kratos (sparsity, bits) grid as a live degradation ladder.

The paper's core result is that fine-grained sparsity and low bit-width
trade bounded accuracy for large area/frequency wins. At serve time that
grid is a RESILIENCE mechanism: the registry keeps 2-3 packed tiers of the
same trained weights resident (`ModelRegistry.load(..., tier_specs=...)`,
re-using the self-draft re-packing machinery), and the engine degrades to a
cheaper tier under measured load instead of saturating.

Tier semantics:

  * tier 0 is the model's own `KratosSpec` — full quality, the only tier a
    request ever runs on when the fleet has headroom;
  * tier i >= 1 is `tier_specs[i-1]` applied to the SAME dense weights — a
    cheaper (sparsity, bits) point, full depth, same cache layout.

KV-compatible swap: tier specs must keep full depth (`keep_layers=None`)
and the engine's cache dtype (`cache_dtype=None`), so every tier shares one
KV cache tree shape. A tier swap is then just re-pointing the params
operand of the compiled decode step — the slab/page store and the device
loop state are untouched, and every in-flight token stream continues from
its exact position (the continuity story; no re-prefill). The params are
jit argument 0 and are NOT donated, so the swap is safe mid-serve; each
tier's distinct packed-buffer shapes simply compile their own executable
(cached after the first swap — pre-warm tiers before latency-sensitive
traffic).

Hysteresis: `QoSController` demotes after `hysteresis` CONSECUTIVE steps
with the waiting deque at/above `demote_depth` (or the page pool at/above
`page_pressure` full), and re-promotes one tier after `hysteresis`
consecutive steps at/below `promote_depth`. The dead band between the two
watermarks resets both streaks — load oscillating inside it never flaps the
tier. One step per observation keeps the controller on the deterministic
engine-step clock, so degradation decisions are reproducible and QoR-
gateable.

Per-request accounting: `Request.tier` records the cheapest (highest) tier
the request ever decoded on; ServeMetrics counts `tier_demotions` /
`tier_promotions`; the tracer records `tier_change` events plus a
`req_tier` edge per resident request, so spans show exactly which requests
rode out a degraded window.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.serve.speculative import DraftSpec


@dataclasses.dataclass(frozen=True)
class QoSConfig:
    """Degradation policy knobs, carried by `EngineConfig.qos` (None = no
    degradation; the engine then never leaves tier 0)."""

    demote_depth: int = 8        # waiting-deque watermark to degrade at
    promote_depth: int = 1       # ... to recover at (must be < demote)
    hysteresis: int = 4          # consecutive steps past a watermark
    page_pressure: float = 0.95  # page-pool fullness that also demotes

    def __post_init__(self):
        if self.promote_depth >= self.demote_depth:
            raise ValueError(
                f"promote_depth ({self.promote_depth}) must be below "
                f"demote_depth ({self.demote_depth}) — equal watermarks "
                "would flap the tier every hysteresis window")
        if self.hysteresis < 1:
            raise ValueError(f"hysteresis must be >= 1, got "
                             f"{self.hysteresis}")


class QoSController:
    """Hysteresis ladder over `n_tiers` packed tiers (tier 0 = best)."""

    def __init__(self, cfg: QoSConfig, n_tiers: int) -> None:
        if n_tiers < 2:
            raise ValueError(f"QoS needs >= 2 resident tiers to ladder "
                             f"between, got {n_tiers} (load the model with "
                             "registry.load(..., tier_specs=...))")
        self.cfg = cfg
        self.n_tiers = n_tiers
        self.tier = 0
        self._over = 0           # consecutive observations above demote
        self._under = 0          # consecutive observations below promote

    def observe(self, queue_depth: int, page_frac: float = 0.0) -> int:
        """One engine step's load signal -> the tier the engine should run.
        Deterministic: same (depth, page_frac) sequence, same tier path."""
        over = (queue_depth >= self.cfg.demote_depth
                or page_frac >= self.cfg.page_pressure)
        under = (queue_depth <= self.cfg.promote_depth
                 and page_frac < self.cfg.page_pressure)
        if over:
            self._over += 1
            self._under = 0
            if self._over >= self.cfg.hysteresis \
                    and self.tier < self.n_tiers - 1:
                self.tier += 1
                self._over = 0
        elif under:
            self._under += 1
            self._over = 0
            if self._under >= self.cfg.hysteresis and self.tier > 0:
                self.tier -= 1
                self._under = 0
        else:
            self._over = self._under = 0        # dead band: no streaks
        return self.tier


def check_tier_spec(ts: DraftSpec) -> DraftSpec:
    """Validate one tier spec for KV-compatible swapping (registry.load).

    Layer truncation or a different cache dtype would change the cache tree
    a resident request's history lives in — a swap would corrupt every
    in-flight stream — so both are refused here rather than at swap time.
    """
    if ts.keep_layers is not None:
        raise ValueError(
            f"tier spec {ts.tag}: keep_layers is a draft-only axis — a "
            "truncated tier has a different cache tree, so an in-place "
            "tier swap would orphan every resident request's KV history")
    if ts.cache_dtype is not None:
        raise ValueError(
            f"tier spec {ts.tag}: cache_dtype must inherit the engine's "
            "(None) — tiers share one live KV cache across swaps")
    return ts


def parse_tiers(arg: str) -> Tuple[DraftSpec, ...]:
    """CLI tier ladder: 'bits:sparsity[,bits:sparsity...]', cheapest last
    (e.g. '8:0.5,8:0.75' = two degradation tiers below the full model).
    bits=0 means native precision, like the --draft-* flags."""
    tiers = []
    for part in arg.split(","):
        if not part.strip():
            continue
        bits_s, _, sp_s = part.partition(":")
        tiers.append(check_tier_spec(
            DraftSpec.from_args(int(bits_s), float(sp_s or 0.0), 0)))
    if not tiers:
        raise ValueError(f"no tiers in {arg!r}")
    return tuple(tiers)
