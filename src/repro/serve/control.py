"""Cross-process control plane for fleet serving (PR 10).

One host ran out of room: every ReplicaRouter replica lives in one
process on submeshes of one mesh, so "fleet" so far means slices of a
single host. This module is the wire between processes — the part of
multi-host serving that is NOT jax: who is alive, how loaded they are,
where a request should go, and what to do when a process stops talking.

Design constraints, in order:

  * The DATA plane never crosses the wire. Decode stays the donated
    device-resident dispatch inside each process (serve.backend); the
    control plane moves only small JSON messages — loads, heartbeats,
    prompts in, tokens out. A fleet of N processes is N independent
    engines plus this gossip, not one distributed program: no
    cross-process collectives, nothing to deadlock.
  * Every decision must work off a POSSIBLY-STALE snapshot. A load
    report is old the moment it is read; the router corrects for the
    messages it knows are in flight (`submits_sent - submits_seen`, the
    credit term in `FleetState.load`) and refuses placements on
    snapshots older than `staleness` rather than guessing.
  * Liveness is observed, never assumed: a process is dead when its
    heartbeats stop for `heartbeat_timeout`, and STAYS dead — a late
    "resurrection" message from a process already failed over would
    double-serve its requests, so `FleetState.observe` drops it.
  * Clock-agnostic: `now` is whatever float the caller advances —
    engine steps in deterministic tests, wall seconds in a live socket
    fleet. The logic never reads time itself.

Wire format: newline-delimited JSON, one message per line, each a dict
with a `"kind"` key. numpy integer arrays (prompts, token blocks) are
encoded as plain lists by `encode_message`; `decode_message` returns
them as lists — the engine's submit path re-asserts int32 anyway.

Message kinds (the full schema is documented in docs/multihost.md):

  hello   worker -> coordinator, once: {process_index, n_replicas}
  status  worker -> coordinator heartbeat: ProcessStatus.to_wire()
  submit  coordinator -> worker: {rid, prompt, max_new_tokens, ...}
  done    worker -> coordinator: {rid, process_index, tokens}
  report  worker -> coordinator, at stop: {process_index, metrics,
          fleet: {decode_steps, engine_steps}}
  stop    coordinator -> worker: drain and exit cleanly
  die     coordinator -> worker: exit WITHOUT goodbye (fault injection —
          the heartbeat-timeout path is the only way the fleet learns)
"""

from __future__ import annotations

import collections
import dataclasses
import json
import socket
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

WIRE_VERSION = 1


# ------------------------------------------------------------ serialization

def _jsonable(v: Any) -> Any:
    """Wire-safe view of a message value: numpy arrays/scalars to plain
    python, containers recursively. Rejects nothing — a field the
    schema does not know is carried verbatim (forward compatibility)."""
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return v


def encode_message(msg: Dict[str, Any]) -> bytes:
    """One message -> one JSON line (newline-terminated). Numpy values
    (prompts, token lists, scalar counters) encode as plain JSON."""
    if "kind" not in msg:
        raise ValueError("control message needs a 'kind'")
    return (json.dumps(_jsonable(msg), separators=(",", ":"))
            + "\n").encode()


def decode_message(line: bytes) -> Dict[str, Any]:
    msg = json.loads(line.decode())
    if not isinstance(msg, dict) or "kind" not in msg:
        raise ValueError(f"not a control message: {line[:80]!r}")
    return msg


# ------------------------------------------------------------- status/state

@dataclasses.dataclass
class ProcessStatus:
    """One process's heartbeat: load + occupancy + liveness in a single
    message. `seq` increments per status so reordered/duplicated
    deliveries collapse; `submits_seen` echoes how many fleet submits
    the process has accounted for — the coordinator's in-flight credit
    term reads it (see FleetState.load)."""

    process_index: int
    seq: int
    step: int                        # the process's own engine-step clock
    replica_loads: List[int]         # scheduler.replica_load per replica
    n_free_slots: int
    n_waiting: int
    page_occupancy: float            # 0.0 on slab engines
    qos_tier: int
    submits_seen: int
    progress: Dict[str, List[int]] = dataclasses.field(default_factory=dict)
    # ^ fleet rid -> tokens generated SINCE the last status (deltas keep
    #   heartbeats small; the coordinator accumulates them so failover
    #   can fold everything a dead process already produced)

    def to_wire(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["kind"] = "status"
        d["v"] = WIRE_VERSION
        return d

    @classmethod
    def from_wire(cls, msg: Dict[str, Any]) -> "ProcessStatus":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in msg.items() if k in fields})

    @property
    def load(self) -> int:
        return int(sum(self.replica_loads))


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Control-plane tuning. All horizons share ONE clock — whatever
    unit the caller's `now` advances in (coordinator steps in tests and
    in-process fleets, wall seconds if a deployment prefers). See
    docs/multihost.md for how the three relate; the invariants are
    heartbeat_every < staleness < heartbeat_timeout."""

    heartbeat_every: int = 2         # worker pumps between status sends
    staleness: float = 8.0           # max snapshot age admission tolerates
    heartbeat_timeout: float = 25.0  # silence after which a process is dead
    max_inflight: int = 0            # per-process admission cap (0 = off)

    def __post_init__(self):
        if not (0 < self.staleness <= self.heartbeat_timeout):
            raise ValueError(
                f"need 0 < staleness ({self.staleness}) <= heartbeat_timeout "
                f"({self.heartbeat_timeout}): a process must go stale "
                "(unpreferred) before it is declared dead (failover)")


class FleetState:
    """The coordinator's view of every process, built ONLY from observed
    messages. Owns the three fleet-health judgements:

      * effective load — last snapshot's load PLUS the submits this
        coordinator sent that the snapshot provably has not seen
        (`submits_sent - submits_seen`). The credit term is what stops
        stale-snapshot oscillation: without it, every arrival between
        two heartbeats lands on the same "least-loaded" process, then
        the next snapshot swings the herd to its sibling.
      * staleness — a process whose snapshot is older than
        `cfg.staleness` is not admitted to (returns None from
        `least_loaded` candidates) but is NOT dead yet.
      * death — silence past `cfg.heartbeat_timeout` (from `check`) or
        an explicit `mark_dead` (closed socket, waitpid). Death is
        terminal: later messages from that process index are counted in
        `resurrections_ignored` and dropped — its requests have been
        failed over; a zombie serving them again would double-emit.
    """

    def __init__(self, cfg: FleetConfig = FleetConfig()) -> None:
        self.cfg = cfg
        self.status: Dict[int, ProcessStatus] = {}
        self.last_seen: Dict[int, float] = {}
        self.submits_sent: Dict[int, int] = collections.defaultdict(int)
        self.dead: set = set()
        self.resurrections_ignored = 0
        self.stale_skips = 0          # placements refused on snapshot age
        self._rr = 0                  # rotating tiebreak, as in ReplicaRouter

    # -- observation --------------------------------------------------------

    def observe(self, st: ProcessStatus, now: float) -> bool:
        """Fold one heartbeat in. Returns False when ignored (process
        already dead, or a stale/duplicate seq)."""
        if st.process_index in self.dead:
            self.resurrections_ignored += 1
            return False
        prev = self.status.get(st.process_index)
        if prev is not None and st.seq <= prev.seq:
            return False              # reordered or duplicated delivery
        self.status[st.process_index] = st
        self.last_seen[st.process_index] = now
        return True

    def note_submit(self, process_index: int) -> None:
        self.submits_sent[process_index] += 1

    def mark_dead(self, process_index: int) -> None:
        self.dead.add(process_index)

    def check(self, now: float) -> List[int]:
        """Processes that JUST crossed heartbeat_timeout: marks them
        dead and returns them (the router fails their requests over)."""
        newly = [p for p, t in self.last_seen.items()
                 if p not in self.dead
                 and now - t > self.cfg.heartbeat_timeout]
        for p in newly:
            self.dead.add(p)
        return newly

    # -- judgements ---------------------------------------------------------

    def alive(self, process_index: int) -> bool:
        return (process_index in self.status
                and process_index not in self.dead)

    def staleness(self, process_index: int, now: float) -> float:
        return now - self.last_seen.get(process_index, -float("inf"))

    def load(self, process_index: int) -> int:
        """Effective load: snapshot load + in-flight submit credits. A
        process heard from (hello) but not yet snapshotted counts every
        submit sent as unseen load — admissible from step zero, so the
        first status to arrive doesn't soak up the whole backlog while
        its siblings are still booting."""
        st = self.status.get(process_index)
        if st is None:
            return self.submits_sent[process_index]
        credit = self.submits_sent[process_index] - st.submits_seen
        return st.load + max(0, credit)

    def inflight(self, process_index: int) -> int:
        st = self.status.get(process_index)
        seen = st.submits_seen if st is not None else 0
        return max(0, self.submits_sent[process_index] - seen)

    def least_loaded(self, now: float) -> Optional[int]:
        """The admission target, or None when no process qualifies
        (all dead, unheard-from, or past the staleness bound). Rotating
        tiebreak on equal effective loads, same discipline as
        ReplicaRouter._order."""
        cands = [p for p in self.last_seen
                 if p not in self.dead
                 and self.staleness(p, now) <= self.cfg.staleness
                 and (not self.cfg.max_inflight
                      or self.inflight(p) < self.cfg.max_inflight)]
        if not cands:
            if any(p not in self.dead for p in self.last_seen):
                self.stale_skips += 1
            return None
        n = max(cands) + 1
        cands.sort(key=lambda p: (self.load(p), (p - self._rr) % n))
        self._rr = (self._rr + 1) % max(1, n)
        return cands[0]

    def describe(self) -> Dict[str, Any]:
        return {
            "processes": sorted(self.status),
            "dead": sorted(self.dead),
            "loads": {p: self.load(p) for p in sorted(self.status)},
            "inflight": {p: self.inflight(p) for p in sorted(self.status)},
            "resurrections_ignored": self.resurrections_ignored,
            "stale_skips": self.stale_skips,
        }


# ---------------------------------------------------------------- transport

class Endpoint:
    """One duplex control connection: newline-framed JSON messages over a
    socket, a reader thread draining inbound lines into a queue so
    `poll()` never blocks the serving loop. Symmetric — both the
    coordinator and the worker hold one."""

    def __init__(self, sock: socket.socket) -> None:
        self.sock = sock
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._inbox: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self.alive = True
        self._reader = threading.Thread(target=self._read_loop, daemon=True,
                                        name="control-endpoint")
        self._reader.start()

    def _read_loop(self) -> None:
        buf = b""
        try:
            while True:
                chunk = self.sock.recv(65536)
                if not chunk:
                    break
                buf += chunk
                while b"\n" in buf:
                    line, buf = buf.split(b"\n", 1)
                    if not line:
                        continue
                    msg = decode_message(line)
                    with self._lock:
                        self._inbox.append(msg)
        except OSError:
            pass
        self.alive = False

    def send(self, msg: Dict[str, Any]) -> bool:
        """Best-effort send; False when the peer is gone. A dead peer is
        a liveness fact for FleetState, never an exception on the
        serving path."""
        try:
            self.sock.sendall(encode_message(msg))
            return True
        except OSError:
            self.alive = False
            return False

    def poll(self) -> List[Dict[str, Any]]:
        with self._lock:
            out = list(self._inbox)
            self._inbox.clear()
        return out

    def close(self) -> None:
        self.alive = False
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


class ControlListener:
    """Coordinator-side accept socket (127.0.0.1 by default — a real
    multi-host fleet binds its fabric address)."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0) -> None:
        self.sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self.sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self.sock.bind((host, port))
        self.sock.listen(16)
        self.address = "%s:%d" % self.sock.getsockname()[:2]

    def accept(self, timeout: float = 30.0) -> Endpoint:
        self.sock.settimeout(timeout)
        conn, _ = self.sock.accept()
        return Endpoint(conn)

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


def connect(address: str, timeout: float = 30.0) -> Endpoint:
    """Worker side: dial the coordinator's control address."""
    host, port = address.rsplit(":", 1)
    sock = socket.create_connection((host, int(port)), timeout=timeout)
    return Endpoint(sock)


# ------------------------------------------------------------ process faces

class ProcessHandle:
    """What the FleetRouter needs from one serving process. Two faces:
    `LocalProcess` (engines in THIS process — the coordinator serves
    too, and deterministic tests want no sockets) and `RemoteProcess`
    (an Endpoint to a worker). Both deliver the same message stream."""

    process_index: int = 0
    alive: bool = True

    def submit(self, msg: Dict[str, Any]) -> bool:
        raise NotImplementedError

    def pump(self, now: float) -> List[Dict[str, Any]]:
        """Advance the process (local: one router step; remote: drain
        the socket) and return newly arrived control messages."""
        raise NotImplementedError

    def stop(self) -> None:
        pass

    def kill(self) -> None:
        """Fault injection: die without a goodbye message."""
        self.alive = False


class LocalProcess(ProcessHandle):
    """In-process worker: a ReplicaRouter (or single engine wrapped in
    one) stepped by `pump`, emitting the SAME status/done/report
    messages a socket worker would. `delay` buffers outbound messages
    for that many pumps — the deterministic stand-in for network lag
    the staleness tests replay."""

    def __init__(self, router, process_index: int = 0, *,
                 cfg: FleetConfig = FleetConfig(), delay: int = 0) -> None:
        self.router = router
        self.process_index = process_index
        self.cfg = cfg
        self.delay = delay
        self.alive = True
        self._stopped = False
        self._pumps = 0                # heartbeat clock: every pump, busy
        #                                or idle, so liveness outlives work
        self._seq = 0
        self._submits_seen = 0
        self._reported: Dict[int, int] = {}    # engine rid -> tokens sent
        self._rid_of: Dict[int, Any] = {}      # fleet rid -> Request
        self._done_sent: set = set()
        self._outbox: collections.deque = collections.deque()

    def submit(self, msg: Dict[str, Any]) -> bool:
        if not self.alive:
            return False
        r = self.router.submit(
            np.asarray(msg["prompt"], np.int32), int(msg["max_new_tokens"]),
            arrival_step=int(msg.get("arrival_step", 0)),
            temperature=float(msg.get("temperature", 0.0)),
            eos_id=msg.get("eos_id"))
        fo = msg.get("failover_from")
        if fo is not None and int(fo) >= 0:
            # cross-PROCESS failover: count on the adopting engine
            # (destination-side, like ReplicaRouter._fail). If the router
            # parked it instead, stamp the request so the eventual
            # engine.adopt does the counting (adopt resets the stamp).
            counted = False
            for e in self.router.replicas:
                if r.id >= 0 and r.id in e.requests:
                    e.metrics.on_failover()
                    counted = True
                    break
            if not counted:
                r.failover_from = int(fo)
        self._rid_of[int(msg["rid"])] = r
        self._submits_seen += 1
        return True

    def _status(self) -> ProcessStatus:
        self._seq += 1
        progress: Dict[str, List[int]] = {}
        for rid, r in self._rid_of.items():
            sent = self._reported.get(rid, 0)
            if len(r.generated) > sent:
                progress[str(rid)] = [int(t) for t in r.generated[sent:]]
                self._reported[rid] = len(r.generated)
        pages = [e.metrics.page_samples[-1] / e.metrics.page_capacity
                 for e in self.router.replicas
                 if e.metrics.page_samples and e.metrics.page_capacity]
        from repro.serve.scheduler import replica_load
        return ProcessStatus(
            process_index=self.process_index, seq=self._seq,
            step=self.router.step_count,
            replica_loads=[replica_load(e.pool.n_active, e.pool.n_free,
                                        e.n_waiting)
                           for e in self.router.replicas],
            n_free_slots=sum(e.pool.n_free for e in self.router.replicas),
            n_waiting=self.router.n_waiting,
            page_occupancy=sum(pages) / len(pages) if pages else 0.0,
            qos_tier=max((e.tier for e in self.router.replicas), default=0),
            submits_seen=self._submits_seen, progress=progress)

    def _emit_dones(self) -> None:
        for rid, r in self._rid_of.items():
            if r.finished and rid not in self._done_sent:
                self._done_sent.add(rid)
                sent = self._reported.get(rid, 0)
                self._outbox.append({
                    "kind": "done", "rid": rid,
                    "process_index": self.process_index,
                    "state": r.state,
                    "tokens": [int(t) for t in r.generated[sent:]]})
                self._reported[rid] = len(r.generated)

    def pump(self, now: float) -> List[Dict[str, Any]]:
        if not self.alive:
            return []
        if self._stopped:
            # drain shutdown: everything still buffered flushes at once
            # (delay no longer applies — the link is not "lagging", the
            # process is saying goodbye)
            out = list(self._outbox)
            self._outbox.clear()
            return out
        if self.router.n_waiting or self.router.n_active:
            self.router.step()
        self._pumps += 1
        if self._pumps % max(1, self.cfg.heartbeat_every) == 0:
            self._outbox.append(self._status().to_wire())
        self._emit_dones()
        out: List[Dict[str, Any]] = []
        while self._outbox and len(self._outbox) > self.delay:
            out.append(self._outbox.popleft())
        return out

    def final_report(self) -> Dict[str, Any]:
        return {
            "kind": "report", "process_index": self.process_index,
            "metrics": [e.metrics.to_payload()
                        for e in self.router.replicas],
            "fleet": {"decode_steps": int(sum(
                e.metrics.decode_steps for e in self.router.replicas)),
                "engine_steps": int(self.router.step_count)},
        }

    def stop(self) -> None:
        """Clean shutdown: flush pending dones, then the final metrics
        report and a bye — the opposite of kill(), which drops the
        outbox on the floor exactly like a crashed socket would."""
        if not self.alive or self._stopped:
            return
        self._emit_dones()
        self._outbox.append(self.final_report())
        self._outbox.append({"kind": "bye"})
        self._stopped = True

    def kill(self) -> None:
        self.alive = False
        self._outbox.clear()           # a crash sends nothing, ever


class RemoteProcess(ProcessHandle):
    """Worker behind an Endpoint (spawned by launch.fleet). `pump` just
    drains the socket — the worker advances itself."""

    def __init__(self, endpoint: Endpoint, process_index: int) -> None:
        self.endpoint = endpoint
        self.process_index = process_index

    @property
    def alive(self) -> bool:                       # type: ignore[override]
        return self.endpoint.alive

    def submit(self, msg: Dict[str, Any]) -> bool:
        return self.endpoint.send(msg)

    def pump(self, now: float) -> List[Dict[str, Any]]:
        return self.endpoint.poll()

    def stop(self) -> None:
        self.endpoint.send({"kind": "stop"})

    def kill(self) -> None:
        self.endpoint.send({"kind": "die"})


# ------------------------------------------------------------ worker server

class WorkerServer:
    """The serving loop of one fleet worker process: a ReplicaRouter
    over this process's engines, driven against the coordinator's
    Endpoint. Steps the router, answers submits, streams progress in
    heartbeats, and exits on `stop` (clean: final report) or `die`
    (fault injection: os._exit, no goodbye — the coordinator must learn
    from the heartbeat silence)."""

    def __init__(self, router, endpoint: Endpoint, process_index: int, *,
                 cfg: FleetConfig = FleetConfig()) -> None:
        # reuse LocalProcess's engine-facing half for the status/progress
        # bookkeeping; this class owns the socket loop around it
        self.local = LocalProcess(router, process_index, cfg=cfg)
        self.endpoint = endpoint
        self.cfg = cfg

    def serve_forever(self, idle_sleep: float = 0.002) -> None:
        import os as _os
        import time as _time
        self.endpoint.send({"kind": "hello",
                            "process_index": self.local.process_index,
                            "v": WIRE_VERSION,
                            "n_replicas": len(self.local.router.replicas)})
        while True:
            for msg in self.endpoint.poll():
                kind = msg.get("kind")
                if kind == "submit":
                    self.local.submit(msg)
                elif kind == "stop":
                    # drain: finish whatever is in flight, then report
                    while self.local.router.n_waiting \
                            or self.local.router.n_active:
                        for out in self.local.pump(0.0):
                            self.endpoint.send(out)
                    for out in self.local.pump(0.0):
                        self.endpoint.send(out)
                    self.endpoint.send(self.local.final_report())
                    self.endpoint.send({"kind": "bye"})
                    return
                elif kind == "die":
                    _os._exit(17)      # no goodbye, no cleanup: a crash
            had_work = bool(self.local.router.n_waiting
                            or self.local.router.n_active)
            for out in self.local.pump(0.0):
                self.endpoint.send(out)
            if not self.endpoint.alive:
                return                 # coordinator vanished: shut down
            if not had_work:
                _time.sleep(idle_sleep)
