"""Radix-tree prefix index over token IDs, at page granularity.

Cross-request redundancy is the serving-side analogue of the weight
redundancy Kratos elides at the fabric: fleets of requests share system
prompts and few-shot preambles whose prefill we recompute — and whose KV we
store once per slot — on every admission. The index maps PAGE-ALIGNED token
prefixes to physical page ids in the paged KV pool (serve.paging): at
admission the engine matches the longest cached prefix, shares its pages by
refcount bump (no memory traffic), and prefills only the unmatched suffix.

Granularity contract: one radix node = one FULL page of `page_size` token
ids, keyed by the token tuple. Matching is therefore always page-aligned —
a partially-covered page is never shared, so sharing needs no copy-on-write
copy: a sharer's first own write lands strictly past the shared pages (its
private suffix pages), and rewinds (speculative rollback) never free a
shared page because freeing is refcount-based.

Ownership contract: the index holds ONE reference per inserted page (the
pool's refcount, bumped via the `retain` callback at insert). Pages whose
only remaining reference is the tree ("unreferenced" prefix pages) are the
eviction currency: `evict` drops LRU LEAF nodes whose page `can_free` (pool
refcount == 1) and releases them back to the free list, stopping at nodes
still shared with a live slot. Interior nodes are never dropped before
their children — prefix contiguity is an invariant of the tree shape.

The structure is host-side bookkeeping only (admission-time, off the hot
decode path); the device never sees it — it sees the page tables the
matches produce.
"""

from __future__ import annotations

import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple


class _Node:
    """One full page of tokens: key (token tuple) -> physical page id.

    `generated` marks nodes published at request FINISH (whole-conversation
    reuse: the page covers tokens the model generated, not just prompt
    text) — admission counts a match that touches one as a conversation
    hit, distinct from plain prompt-prefix sharing."""

    __slots__ = ("key", "page", "children", "parent", "last_used",
                 "generated")

    def __init__(self, key: Tuple[int, ...], page: int,
                 parent: Optional["_Node"], clock: int,
                 generated: bool = False):
        self.key = key
        self.page = page
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.parent = parent
        self.last_used = clock
        self.generated = generated


class PrefixIndex:
    """Radix tree over page-sized token chunks -> physical page ids."""

    def __init__(self, page_size: int):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        self.children: Dict[Tuple[int, ...], _Node] = {}   # root's children
        self.clock = 0                     # logical LRU clock (match/insert)
        self.n_nodes = 0                   # pages currently retained
        self.evicted = 0                   # nodes dropped under pressure

    # ------------------------------------------------------------------ walk

    def _chunks(self, tokens: Sequence[int]):
        p = self.page_size
        for i in range(0, (len(tokens) // p) * p, p):
            yield tuple(int(t) for t in tokens[i:i + p])

    def match(self, tokens: Sequence[int]) -> Tuple[List[int], bool]:
        """(physical pages of the longest page-aligned cached prefix,
        whether any matched node was published at request finish — a
        CONVERSATION hit rather than a prompt-prefix hit).

        Touches every node on the matched path (an LRU hit on a deep prefix
        refreshes its ancestors too — a prefix of a hot prompt is at least
        as hot as the prompt)."""
        self.clock += 1
        children, pages, conversation = self.children, [], False
        for key in self._chunks(tokens):
            node = children.get(key)
            if node is None:
                break
            node.last_used = self.clock
            pages.append(node.page)
            conversation |= node.generated
            children = node.children
        return pages, conversation

    def insert(self, tokens: Sequence[int], pages: Sequence[int],
               retain: Callable[[int], None], *,
               generated: bool = False) -> int:
        """Publish `pages` (the physical pages holding the leading full
        token pages of `tokens`) into the tree; returns how many were NEWLY
        retained. Chunks already present keep their existing page (the
        canonical copy — the caller's duplicate simply frees at slot
        release); `retain(page)` is called once per new node so the pool's
        refcount mirrors tree membership exactly. `generated` tags the NEW
        nodes as conversation pages (request-finish publishes); an existing
        node keeps its tag — the prompt-prefix portion of a conversation
        stays a prompt-prefix hit."""
        self.clock += 1
        children, parent, added = self.children, None, 0
        for key, page in zip(self._chunks(tokens), pages):
            node = children.get(key)
            if node is None:
                node = _Node(key, int(page), parent, self.clock,
                             generated=generated)
                children[key] = node
                retain(node.page)
                self.n_nodes += 1
                added += 1
            else:
                node.last_used = self.clock
            parent, children = node, node.children
        return added

    # ------------------------------------------------------------- eviction

    def _iter_nodes(self):
        stack = list(self.children.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            yield node

    def evict(self, n_pages: int, can_free: Callable[[int], bool],
              release: Callable[[int], None]) -> int:
        """Drop up to `n_pages` LRU LEAF nodes whose page `can_free` (no
        reference left but the tree's own), `release`-ing each page back to
        the pool. Dropping a leaf may expose its parent as the next LRU
        candidate; stops early when every remaining leaf is still shared
        with a live slot. Returns the number of pages actually freed.

        One tree traversal seeds a min-heap of leaves; parents enter the
        heap as their children drop — O(nodes log nodes) per call, not
        O(nodes) per page (admissions under pressure hit this on a tree
        with one node per cached page). Skipped leaves (still referenced)
        never re-enter: our own releases only free TREE-held pages, so no
        other page's refcount changes mid-call."""
        heap = [(n.last_used, id(n), n) for n in self._iter_nodes()
                if not n.children]
        heapq.heapify(heap)
        freed = 0
        while heap and freed < n_pages:
            _, _, node = heapq.heappop(heap)
            if node.children or not can_free(node.page):
                continue
            owner = node.parent.children if node.parent else self.children
            del owner[node.key]
            release(node.page)
            self.n_nodes -= 1
            self.evicted += 1
            freed += 1
            parent = node.parent
            if parent is not None and not parent.children:
                heapq.heappush(heap, (parent.last_used, id(parent), parent))
        return freed

    def clear(self, release: Callable[[int], None]) -> int:
        """Drop every node (shutdown / tests), releasing each page."""
        n = 0
        for node in self._iter_nodes():
            release(node.page)
            n += 1
        self.children = {}
        self.n_nodes = 0
        return n
