"""Slab-allocated KV-cache pool with per-request slot assignment.

One `transformer.make_caches(cfg, n_slots, max_len)` slab is allocated at
engine construction and never reallocated: a request entering the engine is
assigned a free SLOT (one batch row of every cache leaf in the tree), its
prefilled batch-1 cache is written into that row, and the row is returned to
the free list when the request completes. The decode step always runs over
the whole slab — per-slot validity masks (models.attention) make the stale
rows inert, so freeing is O(1) bookkeeping with no memory traffic.

Slab layout contract (transformer.make_caches): unscanned 'prelude' entries
carry the batch axis at dim 0; scanned 'blocks' entries are layer-stacked,
so their batch axis is dim 1. `write_slot` maps over the two groups with the
right axis — the only place in the serving stack that knows this.

Donation: `write_slot` donates BOTH the slab and the incoming batch-1 tree
(`donate_argnums=(0, 1)`), so on backends with buffer donation (TPU/GPU) the
slot install is an in-place row write — the slab is never copied per
admission, and the prefill's cache output buffers are recycled. On CPU, XLA
has no donation and falls back to a copy (the warning is filtered: it is the
expected, documented fallback, not a bug).

Mesh placement: constructed with `mesh=`, the pool resolves one
`NamedSharding` per cache leaf via `sharding.cache_pspecs(..., slab=True)`
(leading slot axis sharded like batch, replicated fallback), places the slab
with `device_put`, and pins the slot-install's `out_shardings` to the same
tree so donation keeps aliasing the sharded buffers (an output that changed
placement could not reuse the donated slab). `shardings` is exposed for the
execution backend to reuse as the decode step's cache in/out shardings.
"""

from __future__ import annotations

import contextlib
import warnings
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.serve.trace import NULL_TRACER


@contextlib.contextmanager
def quiet_donation():
    """Scoped suppression of the CPU no-donation warning.

    The serving hot path donates buffers (in-place on TPU/GPU); CPU has no
    donation and warns before falling back to a copy — expected, documented
    behavior, suppressed ONLY around our own donating dispatches so a user's
    broken donate_argnums elsewhere still warns."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield


class PoolExhausted(RuntimeError):
    """No free cache slot: the scheduler must hold the request in the queue."""


def _write_tree(slab: Dict, single: Dict, slot) -> Dict:
    """Write a batch-1 cache tree into row `slot` of the slab (functional)."""
    pre = jax.tree_util.tree_map(
        lambda s, u: jax.lax.dynamic_update_slice_in_dim(
            s, u.astype(s.dtype), slot, axis=0),
        slab["prelude"], single["prelude"])
    blk = jax.tree_util.tree_map(
        lambda s, u: jax.lax.dynamic_update_slice_in_dim(
            s, u.astype(s.dtype), slot, axis=1),
        slab["blocks"], single["blocks"])
    return {"prelude": pre, "blocks": blk}


class CachePool:
    """Fixed-slot KV pool; slots are reused LIFO (hot rows stay hot)."""

    # class attribute: the engine re-points this at its Tracer when tracing
    # is on; the slab pool emits no page events, but sharing the attribute
    # keeps the backend surface uniform with PagedCachePool
    tracer = NULL_TRACER

    def __init__(self, cfg: T.ModelConfig, n_slots: int, max_len: int,
                 dtype=jnp.float32, *, mesh=None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.dtype = dtype
        self.mesh = mesh
        self.caches = T.make_caches(cfg, n_slots, max_len, dtype)
        self._free: List[int] = list(range(n_slots - 1, -1, -1))
        self.shardings = None
        # donate slab AND single: the slot install updates the slab row in
        # place and recycles the prefill's output buffers (no per-admission
        # slab copy; see module docstring).
        if mesh is not None:
            from jax.sharding import NamedSharding
            from repro.distributed import sharding as SH
            pspecs = SH.cache_pspecs(self.caches, mesh, n_slots, slab=True)
            self.shardings = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), pspecs)
            self.caches = jax.device_put(self.caches, self.shardings)
            self._write = jax.jit(_write_tree, donate_argnums=(0, 1),
                                  out_shardings=self.shardings)
        else:
            self._write = jax.jit(_write_tree, donate_argnums=(0, 1))
        self._single_template = None

    @property
    def single_template(self) -> Dict:
        """Batch-1 cache tree for template-style prefills (lazy: the engine's
        donation path allocates prefill caches inside the compiled step and
        never touches this)."""
        if self._single_template is None:
            self._single_template = T.make_caches(
                self.cfg, 1, self.max_len, self.dtype)
        return self._single_template

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise PoolExhausted(
                f"all {self.n_slots} cache slots in use; admission must wait")
        return self._free.pop()

    def free(self, slot: int) -> None:
        if not (0 <= slot < self.n_slots):
            raise ValueError(f"slot {slot} out of range [0, {self.n_slots})")
        if slot in self._free:
            raise ValueError(f"double-free of slot {slot}")
        self._free.append(slot)

    def write_slot(self, slot: int, single: Dict) -> None:
        """Install a prefilled batch-1 cache tree into `slot` of the slab."""
        with quiet_donation():
            self.caches = self._write(self.caches, single,
                                      jnp.asarray(slot, jnp.int32))

    def bytes(self) -> int:
        return sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(self.caches))
