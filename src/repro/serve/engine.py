"""Continuous-batching inference engine with a device-resident decode loop.

One engine owns: a packed model (serve.registry), a fixed-slot KV slab
(serve.cache_pool), an admission policy (serve.scheduler) and three compiled
functions — per-request prefill (batch 1), and ONE slab decode step reused
every step of the engine's life.

Device-resident decode (default, `EngineConfig.device_loop=True`): between
host synchronizations nothing leaves the device. Sampling is fused into the
compiled decode step (argmax + per-slot-temperature Gumbel with a threaded
`jax.random` key — distributed.steps / transformer.sample_tokens), the
token/index/lifecycle state lives in a donated device tree
(`steps.make_decode_state`), and the KV slab is donated into every dispatch
so it updates in place. A dispatch runs `decode_chunk` (K) micro-steps under
one `lax.scan` with on-device EOS/length masking; the ONLY thing pulled back
is the (K, n_slots) int32 token block — not (n_slots, vocab) logits — so
host syncs per decoded token drop from 3/step (logits pull + token and index
uploads, the PR-1 loop kept as `device_loop=False`) to 1 per K-step
dispatch. Host-side emission catches up from the synced block: streaming
callbacks fire in micro-step order and slots that finished mid-block are
freed retroactively.

The `decode_chunk` knob is a latency/throughput trade: larger K amortizes
dispatch + sync overhead over more tokens but coarsens the admission clock
(new requests join only at block boundaries) and wastes tail micro-steps
when a request finishes mid-block. K=1 is latency-optimal and keeps PR-1
admission granularity; benchmarks run K=4.

Step loop (`step()`):

  1. admission — the scheduler picks arrived requests for free slots (the
     waiting deque is re-partitioned in ONE pass); each admitted request is
     prefilled alone (batch 1, caches allocated inside the compiled step)
     and its cache donated into its slab row. Its first token is sampled
     on device from the prefill logits and its per-slot row (token, index,
     temperature, EOS, remaining budget) is installed into the device state.
  2. slab decode — one dispatch over ALL slots with the per-slot position
     vector (models.attention gathers each row's cache clock); idle slots
     decode garbage that per-slot validity masks keep inert, so the compiled
     shape never changes and requests join/leave with zero recompiles.
  3. lifecycle — the synced token block is emitted per request in micro-step
     order (streaming via `Request.on_token`), finished requests free their
     slots, and freed slots are admissible on the very next step.

Prefill compile-shape policy: prompts are right-padded to power-of-two
buckets (full-logits prefill, read at the true prompt end; the padded cache
tail is never valid under the per-slot masks) so a mixed-length trace
compiles O(log max_len) prefill shapes instead of one per distinct length.
Architectures whose prefill state is cumulative over the padded positions
(SSM/hybrid recurrent state, MoE capacity routing, enc-dec) prefill at exact
length — correctness over compile reuse.

Determinism contract: with temperature=0 every request's output is
independent of what else shares the slab (batch-invariance), EXCEPT
capacity-routed MoE archs where expert-capacity contention is inherently
batch-dependent (true of the lock-step baseline too). Greedy outputs are
identical between the device loop (any K) and the host loop. With
temperature>0 the device loop samples with jax.random (the host loop keeps
its numpy rng): one rng split per MICRO-step makes a single request's
sampled sequence reproducible for any K grouping of the same steps.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import steps as ST
from repro.models import transformer as T
from repro.serve.cache_pool import CachePool, quiet_donation
from repro.serve.metrics import ServeMetrics
from repro.serve.registry import PackedModel
from repro.serve.scheduler import (ContinuousScheduler, Request,
                                   SchedulerBase)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 4
    max_len: int = 96                  # cache positions per slot
    backend: str = "ref"
    cache_dtype: str = "float32"
    prefill_buckets: bool = True       # pow2 right-padding (where exact)
    bucket_min: int = 16
    seed: int = 0                      # sampling rng
    device_loop: bool = True           # fused on-device sampling + state
    decode_chunk: int = 1              # K micro-steps per dispatch (device)


class InferenceEngine:
    """Request lifecycle + step loop over a packed model."""

    def __init__(self, model: PackedModel, cfg: EngineConfig = EngineConfig(),
                 scheduler: Optional[SchedulerBase] = None,
                 metrics: Optional[ServeMetrics] = None):
        if cfg.decode_chunk < 1:
            raise ValueError(f"decode_chunk must be >= 1, got "
                             f"{cfg.decode_chunk}")
        if cfg.decode_chunk > 1 and not cfg.device_loop:
            raise ValueError("decode_chunk > 1 requires device_loop=True "
                             "(the host loop samples every micro-step)")
        self.model = model
        self.cfg = cfg
        mcfg = model.cfg
        self.scheduler = scheduler or ContinuousScheduler()
        self.metrics = metrics or ServeMetrics()
        self.pool = CachePool(mcfg, cfg.n_slots, cfg.max_len,
                              jnp.dtype(cfg.cache_dtype))
        # device loop: prefill allocates its batch-1 caches inside the
        # compiled step (no host template copied in); host loop (PR-1
        # comparison baseline) keeps the template-operand form.
        pkw = dict(cache_len=cfg.max_len,
                   cache_dtype=jnp.dtype(cfg.cache_dtype)) \
            if cfg.device_loop else {}
        self._prefill_last = jax.jit(
            ST.make_prefill_step(mcfg, cfg.backend, last_only=True, **pkw))
        self._prefill_full = jax.jit(
            ST.make_prefill_step(mcfg, cfg.backend, last_only=False, **pkw))
        if cfg.device_loop:
            self._decode = jax.jit(
                ST.make_decode_step(mcfg, cfg.backend,
                                    n_steps=cfg.decode_chunk),
                donate_argnums=(1, 2))   # slab + state update in place
            self._install = jax.jit(ST.install_slot, donate_argnums=(0,))
            self._state = ST.make_decode_state(cfg.n_slots, cfg.seed)
            self._sample_first = jax.jit(T.sample_tokens)
            self._first_key = jax.random.PRNGKey(cfg.seed)
        else:
            self._decode = jax.jit(ST.make_decode_step(mcfg, cfg.backend))
            self._tokens = np.zeros((cfg.n_slots, 1), np.int32)
            self._indices = np.zeros((cfg.n_slots,), np.int32)
        self._slots: List[Optional[Request]] = [None] * cfg.n_slots
        self._waiting: collections.deque = collections.deque()
        self._rng = np.random.default_rng(cfg.seed)
        self._next_id = 0
        self.step_count = 0
        self.requests: Dict[int, Request] = {}
        # padding past the window would let the circular prefill evict real
        # positions in favor of pad garbage (attention._prefill_cache)
        windows = [w for w in (mcfg.window,) if w]
        self._bucket_cap = min([cfg.max_len] + windows)
        self._exact_prefill = bool(mcfg.is_ssm or mcfg.attn_period
                                   or mcfg.n_experts or mcfg.enc_dec)
        # whether a request's total length is bounded by max_len: pure-SSM
        # state is O(1) and a uniformly-windowed cache is circular, so both
        # serve sequences longer than the slab — the long_500k story.
        self._len_bounded = not (
            mcfg.is_ssm
            or (mcfg.window is not None and not mcfg.local_global_period
                and not mcfg.mla and not mcfg.attn_period and not mcfg.enc_dec))

    # ------------------------------------------------------------------ API

    def submit(self, prompt: Sequence[int], max_new_tokens: int, *,
               arrival_step: int = 0, temperature: float = 0.0,
               eos_id: Optional[int] = None,
               extras: Optional[Dict[str, Any]] = None,
               on_token=None) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        need = self.model.cfg.n_img_tokens + len(prompt) + max_new_tokens
        if self._len_bounded and need > self.cfg.max_len:
            raise ValueError(
                f"request needs {need} cache positions "
                f"(img + prompt {len(prompt)} + gen {max_new_tokens}) but "
                f"max_len={self.cfg.max_len}")
        r = Request(id=self._next_id, prompt=prompt,
                    max_new_tokens=max_new_tokens, arrival_step=arrival_step,
                    temperature=temperature, eos_id=eos_id, extras=extras,
                    on_token=on_token)
        self._next_id += 1
        self.requests[r.id] = r
        self._waiting.append(r)
        self.metrics.on_submit(r.id, arrival_step, len(prompt))
        return r

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self._slots)

    @property
    def n_waiting(self) -> int:
        return len(self._waiting)

    def step(self) -> None:
        """One engine step: admissions, then one slab decode dispatch."""
        arrived = [r for r in self._waiting
                   if r.arrival_step <= self.step_count]
        admitted = self.scheduler.admissible(arrived, self.pool.n_active,
                                             self.pool.n_free)
        if admitted:
            # single-pass re-partition of the deque: the per-request
            # deque.remove() of PR 1 was O(waiting) per admission, O(n^2)
            # per step under bursty arrivals.
            chosen = {r.id for r in admitted}
            self._waiting = collections.deque(
                r for r in self._waiting if r.id not in chosen)
            for r in admitted:
                self._start(r)
        if self.pool.n_active:
            advanced = self._decode_block() if self.cfg.device_loop \
                else self._decode_step_host()
        else:
            self.metrics.on_idle_step()
            advanced = 1
        self.step_count += advanced

    def run(self, max_steps: Optional[int] = None) -> Dict[int, np.ndarray]:
        """Step until every submitted request completes; returns outputs."""
        limit = max_steps if max_steps is not None else \
            10 * sum(r.max_new_tokens + 2 for r in self.requests.values()) \
            + max([r.arrival_step for r in self.requests.values()], default=0)
        while (self._waiting or self.pool.n_active) and limit > 0:
            self.step()
            limit -= 1
        if self._waiting or self.pool.n_active:
            raise RuntimeError("engine did not drain within the step limit")
        return {rid: np.asarray(r.generated, np.int32)
                for rid, r in self.requests.items()}

    # ------------------------------------------------------------- internals

    def _prefill_len(self, s0: int) -> int:
        if self._exact_prefill or not self.cfg.prefill_buckets:
            return s0
        b = self.cfg.bucket_min
        while b < s0:
            b *= 2
        return b if b <= self._bucket_cap else s0

    def _sample_host(self, row: np.ndarray, r: Request) -> int:
        if r.temperature <= 0.0:
            return int(np.argmax(row))
        logits = row.astype(np.float64) / r.temperature
        g = self._rng.gumbel(size=logits.shape)
        return int(np.argmax(logits + g))

    def _emit(self, r: Request, tok: int, step: int) -> None:
        r.generated.append(tok)
        self.metrics.on_token(r.id, step)
        if r.on_token is not None:
            r.on_token(r, tok)
        done = len(r.generated) >= r.max_new_tokens \
            or (r.eos_id is not None and tok == r.eos_id)
        if done:
            r.state = "done"
            self.pool.free(r.slot)
            self._slots[r.slot] = None
            self.metrics.on_finish(r.id, step)

    def _start(self, r: Request) -> None:
        slot = self.pool.alloc()
        s0 = len(r.prompt)
        sp = self._prefill_len(s0)
        tokens = np.zeros((1, sp), np.int32)
        tokens[0, :s0] = r.prompt
        batch = {"tokens": jnp.asarray(tokens)}
        if r.extras:
            batch.update({k: jnp.asarray(v) for k, v in r.extras.items()})
        n_img = self.model.cfg.n_img_tokens
        dev = self.cfg.device_loop
        prefill = self._prefill_last if sp == s0 else self._prefill_full
        if dev:
            logits, caches = prefill(self.model.params, batch)
        else:
            logits, caches = prefill(self.model.params, batch,
                                     self.pool.single_template)
        # (1, vocab) on device: the true prompt-end column
        row = logits[:, -1] if sp == s0 else logits[:, n_img + s0 - 1]
        self.pool.write_slot(slot, caches)
        r.state, r.slot = "running", slot
        r.index = n_img + s0
        self._slots[slot] = r
        self.metrics.on_start(r.id, self.step_count)
        if dev:
            key = jax.random.fold_in(self._first_key, r.id)
            temp = jnp.full((1,), r.temperature, jnp.float32)
            tok = int(self._sample_first(row, key, temp)[0])
            self.metrics.on_host_sync("prefill")     # the one int32 pulled
            eos = -1 if r.eos_id is None else int(r.eos_id)
            rem = 0 if (r.eos_id is not None and tok == r.eos_id) \
                else r.max_new_tokens - 1
            with quiet_donation():
                self._state = self._install(
                    self._state, slot, tok, r.index, r.temperature, eos, rem)
        else:
            tok = self._sample_host(np.asarray(row[0]), r)
            self.metrics.on_host_sync("prefill")
            self._tokens[slot, 0] = tok
            self._indices[slot] = r.index
        self._emit(r, tok, self.step_count)  # may finish (max_new_tokens == 1)

    def _decode_block(self) -> int:
        """Device-resident path: ONE dispatch = K fused micro-steps; sync a
        (K, B) int32 token block and catch host bookkeeping up to it."""
        k = self.cfg.decode_chunk
        self.metrics.on_decode_step(self.pool.n_active, self.cfg.n_slots,
                                    micro_steps=k)
        with quiet_donation():
            tok_block, self.pool.caches, self._state = self._decode(
                self.model.params, self.pool.caches, self._state)
        block = np.asarray(tok_block)                # the ONLY decode sync
        self.metrics.on_host_sync("decode")
        for j in range(k):
            step = self.step_count + j
            for slot in range(self.cfg.n_slots):
                r = self._slots[slot]
                if r is None:
                    continue
                r.index += 1
                self._emit(r, int(block[j, slot]), step)
        return k

    def _decode_step_host(self) -> int:
        """PR-1 host loop: full-vocab logits pulled, numpy sampling, token +
        index vectors re-uploaded every step. Kept as the measured baseline
        (serve_bench 'host' mode) and as the numpy-rng sampling reference."""
        self.metrics.on_decode_step(self.pool.n_active, self.cfg.n_slots)
        logits, self.pool.caches = self._decode(
            self.model.params, self.pool.caches,
            jnp.asarray(self._tokens), jnp.asarray(self._indices))
        rows = np.asarray(logits[:, -1])
        # logits pull + token and index uploads: 3 crossings per step
        self.metrics.on_host_sync("decode", 3)
        for slot, r in enumerate(self._slots):
            if r is None:
                continue
            r.index += 1
            self._indices[slot] = r.index
            tok = self._sample_host(rows[slot], r)
            self._tokens[slot, 0] = tok
            self._emit(r, tok, self.step_count)
        return 1
