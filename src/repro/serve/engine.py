"""Continuous-batching inference engine: pure request lifecycle.

One engine owns: a packed model (serve.registry), an admission policy
(serve.scheduler), metrics, and an EXECUTION BACKEND (serve.backend) that
owns everything about placement — the KV slab, the device-resident loop
state, and the compiled prefill/decode/install steps. The engine never
touches a compiled function or a device buffer directly: it decides WHICH
request runs WHEN; the backend decides WHERE the step executes
(`LocalBackend` = jax-default placement, `ShardedBackend` = SPMD over a
(data, model) mesh with the slab's slot axis sharded like batch). Greedy
outputs are identical across backends and across decode chunk sizes.

Device-resident decode (default, `EngineConfig.device_loop=True`): between
host synchronizations nothing leaves the device. Sampling is fused into the
compiled decode step (argmax + per-slot-temperature Gumbel with a threaded
`jax.random` key — distributed.steps / transformer.sample_tokens), the
token/index/lifecycle state lives in a donated device tree
(`steps.make_decode_state`), and the KV slab is donated into every dispatch
so it updates in place. A dispatch runs `decode_chunk` (K) micro-steps under
one `lax.scan` with on-device EOS/length masking; the ONLY thing pulled back
is the (K, n_slots) int32 token block — not (n_slots, vocab) logits — so
host syncs per decoded token drop from 3/step (logits pull + token and index
uploads, the PR-1 loop kept as `device_loop=False`) to 1 per K-step
dispatch. Host-side emission catches up from the synced block: streaming
callbacks fire in micro-step order and slots that finished mid-block are
freed retroactively.

Speculative decode (`EngineConfig.speculate=K`, PR 4): the dispatch becomes
a fused propose-then-verify cycle over a SELF-DRAFT artifact (the same
weights re-packed at a cheaper Kratos point — serve.speculative): the draft
proposes K tokens, the target verifies the block in one batched forward,
and per-slot accept/reject masking commits the agreeing prefix plus one
target bonus token — 1..K+1 tokens per dispatch per live slot, with
rollback a free per-slot index rewind (the backend pads both slabs by K
positions so speculative writes stay in bounds). Greedy output is
token-identical to non-speculative decode for any draft and any K; a
request can cap or disable its own drafting with `submit(speculate=...)`.

Paged KV + prefix reuse (`EngineConfig.page_size`, PR 5): the backend's
pool becomes a block-paged store (serve.paging) — per-slot page tables over
refcounted fixed-size pages, carried as donated device state through every
dispatch — and admission becomes prefix-match -> suffix-prefill -> page
install: the longest page-aligned cached prefix of the prompt (radix index
over token IDs, serve.prefix) is SHARED by refcount bump, only the
unmatched suffix is prefilled, and the prompt's full pages are published
for future admissions. Slot capacity stops being `mem / max_len` and
becomes `mem / actual_tokens`; redundant prefill FLOPs across requests
sharing a system prompt drop to zero. Page pressure surfaces as
`PoolExhausted` at admission — `step()` requeues the admission at the
front of the waiting deque (counted as `pool_waits`) instead of failing
the step; LRU eviction of prefix pages nobody references runs first.
Greedy decode stays token-identical to the slab: in the NATIVE paged form
(PR 8, `EngineConfig.paged_native`, default) attention reads and writes
the page-major store directly through the per-slot page table — no
per-dispatch gather/scatter materialisation (`gather_bytes_avoided`
counts what the legacy wrap would have moved) — and a finished request
publishes its WHOLE conversation (prompt + generated tokens) into the
prefix tree, so the next turn of the same chat skips prefill over the
entire prior exchange (`conversation_prefix_hits`). `paged_native=False`
keeps the PR-5 gather-run-scatter wrap as the measured baseline and the
token-identity oracle.

The `decode_chunk` knob is a latency/throughput trade: larger K amortizes
dispatch + sync overhead over more tokens but coarsens the admission clock
(new requests join only at block boundaries) and wastes tail micro-steps
when a request finishes mid-block. K=1 is latency-optimal and keeps PR-1
admission granularity; benchmarks run K=4.

Step loop (`step()`):

  1. admission — the scheduler picks arrived requests for free slots (the
     waiting deque is re-partitioned in ONE pass); each admitted request is
     prefilled alone (batch 1, caches allocated inside the compiled step)
     and its cache donated into its slab row. Its first token is sampled
     on device from the prefill logits and its per-slot row (token, index,
     temperature, EOS, remaining budget) is installed into the device state.
  2. slab decode — one dispatch over ALL slots with the per-slot position
     vector (models.attention gathers each row's cache clock); idle slots
     decode garbage that per-slot validity masks keep inert, so the compiled
     shape never changes and requests join/leave with zero recompiles.
  3. lifecycle — the synced token block is emitted per request in micro-step
     order (streaming via `Request.on_token`), finished requests free their
     slots, and freed slots are admissible on the very next step.

Backpressure: `EngineConfig.max_waiting` bounds the waiting deque. A submit
over the bound raises `EngineSaturated` (counted in metrics as `rejected`)
instead of queueing unboundedly — the rejection is the signal the replica
router (serve.router) uses to spill traffic to a sibling engine. The
default (None) keeps the open-ended queue for single-engine use.

Resilience (PR 7): requests can carry deadlines (`deadline_steps` on the
deterministic engine-step clock, `deadline_ms` on the wall) — admission
sheds already-doomed work immediately and a per-step sweep cancels
mid-flight requests whose remaining budget no longer fits, freeing their
slot/pages cleanly (`Request.state == "shed"` + `shed_reason`; ServeMetrics
`shed` / `deadline_missed`). `EngineConfig.pool_wait_retries` bounds the
PoolExhausted requeue loop with exponential step backoff (then sheds as
`shed_pool_pressure`). `EngineConfig.qos` (serve.qos) enables load-driven
QUALITY degradation: the engine swaps the live decode onto a cheaper
resident (sparsity, bits) tier of the same weights under queue/page
pressure — KV-compatible, so every in-flight stream continues — and
re-promotes with hysteresis. A corrupted decode sync (out-of-vocab tokens:
NaN logits, device fault) raises `ReplicaFault`, the signal the replica
router's failover path (serve.router) turns into evacuate-and-re-admit.

Prefill compile-shape policy: prompts are right-padded to power-of-two
buckets (full-logits prefill, read at the true prompt end; the padded cache
tail is never valid under the per-slot masks) so a mixed-length trace
compiles O(log max_len) prefill shapes instead of one per distinct length.
Architectures whose prefill state is cumulative over the padded positions
(SSM/hybrid recurrent state, MoE capacity routing, enc-dec) prefill at exact
length — correctness over compile reuse.

Determinism contract: with temperature=0 every request's output is
independent of what else shares the slab (batch-invariance), EXCEPT
capacity-routed MoE archs where expert-capacity contention is inherently
batch-dependent (true of the lock-step baseline too). Greedy outputs are
identical between the device loop (any K, any backend) and the host loop.
With temperature>0 the device loop samples with jax.random (the host loop
keeps its numpy rng): one rng split per MICRO-step makes a single request's
sampled sequence reproducible for any K grouping of the same steps.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.serve.backend import ExecutionBackend, LocalBackend
from repro.serve.cache_pool import PoolExhausted
from repro.serve.ledger import NULL_LEDGER, LedgerSink
from repro.serve.metrics import ServeMetrics
from repro.serve.registry import PackedModel
from repro.serve.scheduler import (ContinuousScheduler, Request,
                                   SchedulerBase)
from repro.serve.trace import NULL_TRACER, TraceConfig, Tracer


class EngineSaturated(RuntimeError):
    """The bounded waiting deque is full: admission must spill or retry."""


class ReplicaFault(RuntimeError):
    """The replica produced provably-corrupt output (out-of-vocab decode
    sync — NaN logits argmax, device fault) or its dispatch crashed. The
    router catches this around `engine.step()`, marks the replica dead,
    and re-admits its evacuated requests to survivors."""


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 4
    max_len: int = 96                  # cache positions per slot
    backend: str = "ref"
    cache_dtype: str = "float32"
    prefill_buckets: bool = True       # pow2 right-padding (where exact)
    bucket_min: int = 16
    seed: int = 0                      # sampling rng
    device_loop: bool = True           # fused on-device sampling + state
    decode_chunk: int = 1              # K micro-steps per dispatch (device)
    max_waiting: Optional[int] = None  # waiting-deque bound (None = open)
    # speculative decode (serve.speculative): K draft tokens per propose-
    # then-verify dispatch (0 = off). Requires a model loaded with
    # `draft_spec=`; replaces the decode_chunk loop (one spec cycle IS the
    # dispatch). Both slabs get K extra positions of write headroom so the
    # deepest speculative write stays in bounds before rollback.
    speculate: int = 0
    draft_cache_dtype: Optional[str] = None   # None = cache_dtype
    # paged KV + prefix reuse (serve.paging): page_size carves the cache
    # into fixed pages behind per-slot page tables (None = the slab);
    # n_pages sizes the page pool (None = slab-equivalent capacity,
    # n_slots * pages_per_slot, + the reserved sink page) — fewer pages
    # oversubscribes memory against ACTUAL tokens instead of max_len;
    # prefix_cache shares page-aligned prompt prefixes across requests via
    # the radix index (auto-disabled for archs whose cache state is not
    # purely positional — paging itself still works there).
    page_size: Optional[int] = None
    n_pages: Optional[int] = None
    prefix_cache: bool = True
    # paged_native=True (default) runs decode attention straight off the
    # page-major store through the page table (kernels.ops.paged_attention
    # / the page-table-native Pallas kernel) — no gather/scatter
    # materialisation per dispatch. False keeps the PR-5
    # gather-run-scatter wrap: the measured baseline and the
    # token-identity oracle for the native path.
    paged_native: bool = True
    # resilience (serve.qos): pool_wait_retries bounds the PoolExhausted
    # requeue loop per request — None keeps the legacy unbounded
    # requeue-at-front; N parks the retry behind an exponential step
    # backoff and sheds the request (`shed_pool_pressure`) past N retries.
    # qos (a qos.QoSConfig) enables load-driven tier degradation; requires
    # a model loaded with registry.load(..., tier_specs=...).
    pool_wait_retries: Optional[int] = None
    qos: Optional[Any] = None
    # tracing (serve.trace): None = OFF, served by the shared no-op tracer —
    # the hot path's only residue is one attribute lookup + a fixed-arity
    # no-op call per edge (allocation-free, gated by test_trace). Set a
    # TraceConfig to record every lifecycle/dispatch edge into the ring
    # buffer, with optional JSONL/Chrome export paths and a jax.profiler
    # bracket around the first N traced dispatches.
    trace: Optional[TraceConfig] = None
    # ineffectual-work ledger (serve.ledger): None = OFF, served by the
    # shared NULL_LEDGER no-op sink (allocation-free hot path, gated by
    # test_ledger). Set a ledger.LedgerConfig to carry a device-resident
    # activation-sparsity / effective-FLOPs counter matrix as donated loop
    # state through every fused dispatch, drained inside the dispatch's
    # one existing host sync — plus the per-tier quality probe when
    # LedgerConfig.quality_every > 0. Requires device_loop=True.
    ledger: Optional[Any] = None


class InferenceEngine:
    """Request lifecycle + step loop over a packed model."""

    def __init__(self, model: PackedModel, cfg: EngineConfig = EngineConfig(),
                 scheduler: Optional[SchedulerBase] = None,
                 metrics: Optional[ServeMetrics] = None,
                 backend: Optional[ExecutionBackend] = None):
        if cfg.decode_chunk < 1:
            raise ValueError(f"decode_chunk must be >= 1, got "
                             f"{cfg.decode_chunk}")
        if cfg.decode_chunk > 1 and not cfg.device_loop:
            raise ValueError("decode_chunk > 1 requires device_loop=True "
                             "(the host loop samples every micro-step)")
        if cfg.max_waiting is not None and cfg.max_waiting < 0:
            raise ValueError(f"max_waiting must be >= 0 or None, got "
                             f"{cfg.max_waiting}")
        if cfg.speculate < 0:
            raise ValueError(f"speculate must be >= 0, got {cfg.speculate}")
        if cfg.page_size is not None and cfg.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {cfg.page_size}")
        if cfg.page_size and not cfg.device_loop:
            raise ValueError("page_size requires device_loop=True (the "
                             "page-table decode lives inside the fused "
                             "dispatch; the host loop has no paged form)")
        if cfg.n_pages is not None and not cfg.page_size:
            raise ValueError("n_pages without page_size: the slab pool has "
                             "no page geometry")
        if cfg.pool_wait_retries is not None and cfg.pool_wait_retries < 0:
            raise ValueError(f"pool_wait_retries must be >= 0 or None, got "
                             f"{cfg.pool_wait_retries}")
        if cfg.ledger is not None and not cfg.device_loop:
            raise ValueError("ledger requires device_loop=True (the counter "
                             "matrix rides the fused dispatch's donated "
                             "loop state; the host loop has no fused step)")
        if cfg.speculate:
            from repro.serve import speculative as SP
            if not cfg.device_loop:
                raise ValueError("speculate requires device_loop=True (the "
                                 "propose-then-verify cycle is one fused "
                                 "dispatch)")
            if cfg.decode_chunk != 1:
                raise ValueError("speculate replaces decode_chunk: one spec "
                                 "cycle IS the multi-token dispatch — set "
                                 "decode_chunk=1")
            if not model.has_draft:
                raise ValueError(
                    f"speculate={cfg.speculate} needs a self-draft artifact: "
                    f"load the model with registry.load(..., draft_spec=Draft"
                    f"Spec(...)); '{model.name}' has none")
            SP.check_supported(model.cfg, cfg.max_len + cfg.speculate)
        self.model = model
        self.cfg = cfg
        mcfg = model.cfg
        self.scheduler = scheduler or ContinuousScheduler()
        self.metrics = metrics or ServeMetrics()
        self.backend = backend or LocalBackend()
        self.backend.build(model, cfg)
        self.pool = self.backend.pool
        # host-static per-dispatch ledger: bytes the legacy gather+scatter
        # wrap would have moved (0 on the slab pool / legacy paged mode)
        self._gather_bytes = self.backend.gather_bytes_per_dispatch()
        if cfg.qos is not None:
            from repro.serve.qos import QoSController
            self._qos = QoSController(cfg.qos, self.backend.n_tiers)
        else:
            self._qos = None
        self._vocab = model.cfg.vocab
        self._has_deadlines = False     # arms the per-step deadline sweep
        self.trace = Tracer(cfg.trace) if cfg.trace is not None \
            else NULL_TRACER
        self.pool.tracer = self.trace
        if self.backend.draft_pool is not None:
            self.backend.draft_pool.tracer = self.trace
        # ineffectual-work ledger (serve.ledger): the sink folds each
        # dispatch's drained counter delta into float64 running totals and
        # fans it out to metrics + tracer; NULL_LEDGER keeps the disabled
        # hot path allocation-free (one attribute lookup + fixed-arity
        # no-op call per dispatch).
        if cfg.ledger is not None:
            self.ledger = LedgerSink(cfg.ledger, mcfg.n_layers,
                                     metrics=self.metrics, tracer=self.trace)
        else:
            self.ledger = NULL_LEDGER
        self._quality_every = cfg.ledger.quality_every \
            if cfg.ledger is not None else 0
        self._quality_count = 0        # full-prefill admissions since probe
        self.quality_log: List[Dict[str, Any]] = []
        # per-dispatch host-sync payload, precomputed so every hot-path
        # tracer call passes only pre-existing values (the zero-allocation
        # contract of the disabled path — tests/test_trace.py)
        if cfg.speculate:
            # commit block (B, K+1) + commit counts (B,) + accepts (B,)
            self._sync_bytes = 4 * cfg.n_slots * (cfg.speculate + 3)
        elif cfg.device_loop:
            self._sync_bytes = 4 * cfg.n_slots * cfg.decode_chunk
        else:
            # full-vocab logits pull + token and index re-uploads
            self._sync_bytes = 4 * cfg.n_slots * (mcfg.vocab + 2)
        if cfg.speculate:
            self.metrics.draft_flop_fraction = model.draft_cost_fraction()
            # target verify forwards per cycle (mirrors the steps builder)
            self._verify_steps = (cfg.speculate + 1) \
                if (mcfg.is_ssm or mcfg.attn_period) else 1
        if not cfg.device_loop:
            self._tokens = np.zeros((cfg.n_slots, 1), np.int32)
            self._indices = np.zeros((cfg.n_slots,), np.int32)
        self._slots: List[Optional[Request]] = [None] * cfg.n_slots
        self._waiting: collections.deque = collections.deque()
        self._rng = np.random.default_rng(cfg.seed)
        self._next_id = 0
        self.step_count = 0
        self.requests: Dict[int, Request] = {}
        # padding past the window would let the circular prefill evict real
        # positions in favor of pad garbage (attention._prefill_cache)
        windows = [w for w in (mcfg.window,) if w]
        self._bucket_cap = min([cfg.max_len] + windows)
        self._exact_prefill = bool(mcfg.is_ssm or mcfg.attn_period
                                   or mcfg.n_experts or mcfg.enc_dec)
        # whether a request's total length is bounded by max_len: pure-SSM
        # state is O(1) and a uniformly-windowed cache is circular, so both
        # serve sequences longer than the slab — the long_500k story.
        self._len_bounded = not (
            mcfg.is_ssm
            or (mcfg.window is not None and not mcfg.local_global_period
                and not mcfg.mla and not mcfg.attn_period and not mcfg.enc_dec))

    # ------------------------------------------------------------------ API

    def submit(self, prompt: Sequence[int], max_new_tokens: int, *,
               arrival_step: int = 0, temperature: float = 0.0,
               eos_id: Optional[int] = None,
               extras: Optional[Dict[str, Any]] = None,
               on_token=None, speculate: Optional[int] = None,
               deadline_steps: Optional[int] = None,
               deadline_ms: Optional[float] = None,
               slo: str = "") -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        r = Request(id=-1, prompt=prompt,
                    max_new_tokens=max_new_tokens, arrival_step=arrival_step,
                    temperature=temperature, eos_id=eos_id, extras=extras,
                    on_token=on_token, speculate=speculate,
                    deadline_steps=deadline_steps, deadline_ms=deadline_ms,
                    slo=slo)
        return self.adopt(r)

    def adopt(self, r: Request) -> Request:
        """Validate + enqueue a Request object (fresh submit, a waiting
        request moved here by the replica router's rebalancer, or a request
        evacuated off a dead replica — `failover_from` set — resuming its
        generation here). Raises EngineSaturated when the bounded waiting
        deque is full — counted as a rejection; the router spills the
        request to a sibling replica. A request whose deadline provably
        cannot be met is shed HERE (terminal state, never queued) and
        returned — admission-time load shedding."""
        if r.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        # evacuated requests fold prior output into the prompt and keep
        # `generated`, so the budget below is what is still owed
        budget = r.max_new_tokens - len(r.generated)
        need = self.model.cfg.n_img_tokens + len(r.prompt) + budget
        if self._len_bounded and need > self.cfg.max_len:
            raise ValueError(
                f"request needs {need} cache positions "
                f"(img + prompt {len(r.prompt)} + gen {budget}) "
                f"but max_len={self.cfg.max_len}")
        # no page-capacity check needed: `need` clamps at max_len, so a
        # request can require at most pages_per_slot pages, and the paged
        # pool's constructor already guarantees usable >= pages_per_slot —
        # any admissible request eventually fits once pages free up.
        if self.cfg.max_waiting is not None \
                and len(self._waiting) >= self.cfg.max_waiting:
            self.metrics.on_reject()
            self.trace.reject(len(self._waiting))
            raise EngineSaturated(
                f"waiting deque at max_waiting={self.cfg.max_waiting}")
        r.id = self._next_id
        self._next_id += 1
        self.requests[r.id] = r
        self.metrics.on_submit(r.id, r.arrival_step, len(r.prompt))
        self.trace.submit(r.id, len(r.prompt), r.arrival_step)
        if r.failover_from >= 0:
            # counted on the DESTINATION replica (sums cleanly in
            # aggregate); the source's counters died with it
            self.metrics.on_failover()
            self.trace.failover(r.id, r.failover_from)
            r.failover_from = -1
        if r.deadline_steps is not None or r.deadline_ms is not None:
            self._has_deadlines = True
            if self._doomed(r):
                # already-doomed work: shedding it NOW costs nothing and
                # frees the queue slot for requests that can still make it
                self._shed(r, "deadline")
                return r
        self._waiting.append(r)
        return r

    def steal_waiting(self, n: int) -> List[Request]:
        """Pop up to `n` waiting (never started) requests off the TAIL of
        the deque — the most recently queued, i.e. the ones that would wait
        longest here — de-registering them from this engine. The router
        re-`adopt`s them into an underloaded replica; the Request objects
        (the caller's handles) survive the move."""
        out: List[Request] = []
        while self._waiting and len(out) < n:
            r = self._waiting.pop()
            del self.requests[r.id]
            self.metrics.records.pop(r.id, None)
            r.id = -1
            out.append(r)
        return out[::-1]                # preserve relative arrival order

    def cancel(self, r: Request, reason: str = "cancel") -> None:
        """Explicit in-flight cancellation: a terminal 'shed' state with
        the slot, pages (and the draft slab row — it shares the slot id),
        and prefix-tree refcounts all released cleanly. Idempotent on
        finished requests."""
        if r.finished or r.id not in self.requests:
            return
        if r.state == "waiting":
            try:
                self._waiting.remove(r)
            except ValueError:
                pass
        self._shed(r, reason)

    def evacuate(self) -> List[Request]:
        """Strip every non-finished request off this engine for
        re-admission elsewhere (router failover). A running request folds
        what it already generated into its prompt and resets to waiting —
        the survivor's greedy re-prefill of the full history reconstructs
        the causal cache exactly (the same property prefix reuse relies
        on), so the resumed stream is token-identical to an uninterrupted
        run. Requests/records are de-registered here (the router re-adopts
        them, so completions are never double-counted)."""
        out: List[Request] = []
        for slot, r in enumerate(self._slots):
            if r is None:
                continue
            try:
                if self.cfg.device_loop:
                    self.backend.release_slot(slot)
                self.pool.free(slot)
            except Exception:
                pass    # a crashed backend may refuse the dispatch; this
                #         replica is being torn down anyway
            self._slots[slot] = None
            if r.generated:
                r.prompt = np.concatenate(
                    [r.prompt, np.asarray(r.generated, np.int32)])
            r.state = "waiting"
            r.slot, r.index, r.prefix_matched = -1, 0, 0
            out.append(r)
        out.extend(self._waiting)
        self._waiting.clear()
        for r in out:
            self.requests.pop(r.id, None)
            self.metrics.records.pop(r.id, None)
            r.id = -1
        return out

    # -- QoS tiers (serve.qos) ----------------------------------------------

    @property
    def tier(self) -> int:
        """Active quality tier (0 = the model's own spec, full quality)."""
        return self.backend.tier

    def set_tier(self, tier: int) -> None:
        """Swap the live decode onto packed tier `tier`. KV-compatible by
        construction: resident requests continue from their exact stream
        position; each one records the cheapest tier it ever decoded on."""
        old = self.backend.tier
        if tier == old:
            return
        self.backend.set_tier(tier)
        self.metrics.on_tier_change(old, tier)
        self.trace.tier_change(old, tier, len(self._waiting))
        for r in self._slots:
            if r is not None and tier > r.tier:
                r.tier = tier
                self.trace.req_tier(r.id, tier)

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self._slots)

    @property
    def n_waiting(self) -> int:
        return len(self._waiting)

    def step(self) -> None:
        """One engine step: admissions, then one slab decode dispatch."""
        self.trace.step = self.step_count
        self._expire_deadlines()
        if self._qos is not None:
            self._qos_tick()
        arrived = [r for r in self._waiting
                   if r.arrival_step <= self.step_count
                   and r.retry_at_step <= self.step_count]
        admitted = self.scheduler.admissible(arrived, self.pool.n_active,
                                             self.pool.n_free)
        if admitted:
            # single-pass re-partition of the deque: the per-request
            # deque.remove() of PR 1 was O(waiting) per admission, O(n^2)
            # per step under bursty arrivals.
            chosen = {r.id for r in admitted}
            self._waiting = collections.deque(
                r for r in self._waiting if r.id not in chosen)
            for i, r in enumerate(admitted):
                try:
                    self._start(r)
                except PoolExhausted:
                    # page pressure (free slots but not enough free pages,
                    # even after LRU prefix eviction): requeue this and the
                    # remaining admissions at the FRONT in arrival order —
                    # finishing requests release pages, so they retry on
                    # the very next step instead of crashing it. With
                    # pool_wait_retries set, the failed admission instead
                    # parks behind an exponential step backoff (other
                    # arrivals keep getting tried — no head-of-line
                    # starvation) and is shed past the retry cap.
                    cap = self.cfg.pool_wait_retries
                    r.pool_retries += 1
                    rest = admitted[i + 1:]
                    if cap is not None and r.pool_retries > cap:
                        self._shed(r, "pool")
                    else:
                        if cap is not None:
                            r.retry_at_step = self.step_count + min(
                                1 << r.pool_retries, 64)
                        rest = [r] + rest
                    for rr in reversed(rest):
                        self._waiting.appendleft(rr)
                    self.metrics.on_pool_wait()
                    self.trace.pool_wait()
                    break
        if self.pool.n_active:
            if self.cfg.speculate:
                advanced = self._decode_spec()
            elif self.cfg.device_loop:
                advanced = self._decode_block()
            else:
                advanced = self._decode_step_host()
            stats = self.backend.page_stats()
            if stats is not None:       # per-dispatch page-pool gauge
                self.metrics.on_pages(*stats)
        else:
            self.metrics.on_idle_step()
            advanced = 1
        self.step_count += advanced

    def run(self, max_steps: Optional[int] = None) -> Dict[int, np.ndarray]:
        """Step until every submitted request completes; returns outputs."""
        limit = max_steps if max_steps is not None else \
            10 * sum(r.max_new_tokens + 2 for r in self.requests.values()) \
            + max([r.arrival_step for r in self.requests.values()], default=0)
        while (self._waiting or self.pool.n_active) and limit > 0:
            self.step()
            limit -= 1
        if self._waiting or self.pool.n_active:
            raise RuntimeError("engine did not drain within the step limit")
        return {rid: np.asarray(r.generated, np.int32)
                for rid, r in self.requests.items()}

    # ------------------------------------------------------------- internals

    def _prefill_len(self, s0: int) -> int:
        if self._exact_prefill or not self.cfg.prefill_buckets:
            return s0
        b = self.cfg.bucket_min
        while b < s0:
            b *= 2
        return b if b <= self._bucket_cap else s0

    def _suffix_len(self, s: int, start: int) -> int:
        """Bucketed suffix-prefill length: pow2 like `_prefill_len`, but
        the pad tail must also FIT — it is written (masked) at positions
        start..start+bucket, so the bucket falls back to exact when it
        would run past the slot's cache positions."""
        if self._exact_prefill or not self.cfg.prefill_buckets:
            return s
        b = self.cfg.bucket_min
        while b < s:
            b *= 2
        return b if start + b <= self._bucket_cap else s

    def _sample_host(self, row: np.ndarray, r: Request) -> int:
        if r.temperature <= 0.0:
            return int(np.argmax(row))
        logits = row.astype(np.float64) / r.temperature
        g = self._rng.gumbel(size=logits.shape)
        return int(np.argmax(logits + g))

    def _doomed(self, r: Request) -> bool:
        """True when the request provably cannot finish by its deadline.

        Step clock: a live slot emits at least one token per engine step
        (up to 1 + spec_limit when speculating — the OPTIMISTIC bound, so a
        salvageable request is never shed early), so the earliest possible
        finish is start + ceil(remaining / per_step) - 1. Wall clock: the
        elapsed time since submit (records' monotonic baseline) against
        deadline_ms."""
        if r.deadline_ms is not None:
            rec = self.metrics.records.get(r.id)
            if rec is not None and (time.perf_counter() - rec.submit_mono) \
                    * 1e3 > r.deadline_ms:
                return True
        d = r.deadline_step()
        if d is None:
            return False
        rem = r.max_new_tokens - len(r.generated)
        per = 1 + self._spec_limit(r)
        start = max(self.step_count, r.arrival_step)
        return start - (-rem // per) - 1 > d        # ceil division

    def _shed(self, r: Request, reason: str) -> None:
        """Terminal 'shed' disposition ('deadline' | 'pool' | 'failover' |
        'cancel'): release everything the request holds — slot row parked
        inert on device, pool slot/pages freed (prefix-tree refs drop with
        them), record kept for observability. Never counts as a
        completion."""
        if r.state == "running":
            if self.cfg.device_loop:
                self.backend.release_slot(r.slot)
            self.pool.free(r.slot)
            self._slots[r.slot] = None
        r.state = "shed"
        r.shed_reason = reason
        self.metrics.on_shed(reason)
        self.trace.shed(r.id, r.slot, reason, len(r.generated))

    def _expire_deadlines(self) -> None:
        """Per-step sweep (armed only once a deadline exists): shed waiting
        AND running requests the moment they become doomed — mid-flight
        cancellation frees the slot for work that can still meet its SLO,
        and no completion is ever served past its deadline."""
        if not self._has_deadlines:
            return
        expired = [r for r in self._waiting if self._doomed(r)]
        if expired:
            dead = {r.id for r in expired}
            self._waiting = collections.deque(
                r for r in self._waiting if r.id not in dead)
            for r in expired:
                self._shed(r, "deadline")
        for r in list(self._slots):
            if r is not None and self._doomed(r):
                self._shed(r, "deadline")

    def _qos_tick(self) -> None:
        """Feed the tier controller this step's load signal (queue depth +
        page-pool fullness) and apply its verdict."""
        stats = self.backend.page_stats()
        frac = 0.0
        if stats is not None:
            used, usable = stats
            frac = used / max(1, usable)
        want = self._qos.observe(len(self._waiting), frac)
        if want != self.backend.tier:
            self.set_tier(want)

    def _emit(self, r: Request, tok: int, step: int) -> None:
        r.generated.append(tok)
        self.metrics.on_token(r.id, step)
        if len(r.generated) == 1:
            # explicit step (not tracer.step): micro-steps within a K-block
            # advance the emission clock ahead of the dispatch clock, and
            # spans must reconcile exactly with ServeMetrics
            self.trace.first_token(r.id, r.slot, step)
        if r.on_token is not None:
            r.on_token(r, tok)
        done = len(r.generated) >= r.max_new_tokens \
            or (r.eos_id is not None and tok == r.eos_id)
        if done:
            r.state = "done"
            self.trace.finish(r.id, r.slot, step, len(r.generated))
            if self.backend.paged and not r.extras:
                # publish the WHOLE conversation (prompt + generated) into
                # the prefix tree BEFORE the slot's pages are freed — the
                # next turn of this chat prefix-matches its entire prior
                # exchange and skips that prefill. Only the finish path
                # publishes: shed/cancel/evacuate never promise their
                # pages' contents.
                self.backend.conversation_insert(
                    np.concatenate([r.prompt,
                                    np.asarray(r.generated, np.int32)]),
                    r.slot)
            self.pool.free(r.slot)
            self._slots[r.slot] = None
            self.metrics.on_finish(r.id, step)

    def _start(self, r: Request) -> None:
        slot = self.pool.alloc()
        s0 = len(r.prompt)
        n_img = self.model.cfg.n_img_tokens
        # what is still owed: a failover-resumed request already generated
        # part of its budget (now folded into the prompt)
        budget = r.max_new_tokens - len(r.generated)
        # paged admission: longest page-aligned cached prefix, then the
        # slot's page-table row (shared prefix pages refcount-bumped, fresh
        # private pages for suffix + generation + speculative headroom).
        # `conv` flags a hit that ran through pages a finished request
        # published from its GENERATED tokens — a chat resuming its own
        # prior turn. PoolExhausted here propagates to step(), requeued.
        matched, shared, conv = (0, (), False) if r.extras else \
            self.backend.prefix_match(r.prompt)
        # Page allocation is sized from the TRUE request footprint — prompt
        # + owed budget + speculative headroom — never from the pow2
        # prefill bucket. Bucket padding is a compile-shape policy only:
        # padded-tail writes land past the allocated footprint, where the
        # page table reads the reserved sink page (masked garbage), so a
        # bigger bucket must never cost real pages.
        try:
            self.backend.alloc_slot_pages(
                slot, n_img + s0 + budget + self.cfg.speculate,
                shared)
        except PoolExhausted:
            self.pool.free(slot)
            raise
        sp = self._prefill_len(s0)
        tokens = np.zeros((1, sp), np.int32)
        tokens[0, :s0] = r.prompt
        batch = {"tokens": jnp.asarray(tokens)}
        if r.extras:
            batch.update({k: jnp.asarray(v) for k, v in r.extras.items()})
        if matched:
            # prefix hit: only the unmatched suffix runs, right-padded into
            # the same pow2 buckets as full prefills (real traffic produces
            # arbitrary suffix lengths — one compile per length would be a
            # compile-shape explosion). The logits column at the TRUE
            # suffix end seeds sampling; the padded tail's writes land past
            # the shared region — in the slot's private pages where the
            # footprint still covers them, in the reserved sink page where
            # it doesn't — masked garbage either way until decode
            # overwrites the real positions. `batch` still carries the
            # full padded prompt for a speculating backend's draft.
            s_sfx = s0 - matched
            sp_sfx = self._suffix_len(s_sfx, n_img + matched)
            sfx = np.zeros((1, sp_sfx), np.int32)
            sfx[0, :s_sfx] = r.prompt[matched:]
            logits = self.backend.prefill_suffix(
                {"tokens": jnp.asarray(sfx)}, batch, slot, n_img + matched)
            row = logits[:, s_sfx - 1]
        else:
            logits, caches = self.backend.prefill(batch, exact=sp == s0)
            # (1, vocab) on device: the true prompt-end column
            row = logits[:, -1] if sp == s0 else logits[:, n_img + s0 - 1]
            self.backend.write_slot(slot, caches)
            if self._quality_every:
                # every quality_every-th FULL-prefill admission (prefix-hit
                # suffixes are skipped: their logits depend on page state
                # the offline recompute can't replay standalone)
                self._quality_count += 1
                if self._quality_count >= self._quality_every:
                    self._quality_count = 0
                    self._quality_probe(
                        r, batch, row, sp == s0,
                        -1 if sp == s0 else n_img + s0 - 1)
        if not r.extras:
            # publish the prompt's full pages for future admissions (a
            # no-op on the slab pool / prefix-unsupported archs)
            self.backend.prefix_insert(r.prompt, slot)
        if self.backend.paged:
            self.metrics.on_prefix(matched, s0)
            if conv and matched:
                self.metrics.on_conversation_hit(matched)
                self.trace.conversation_hit(r.id, matched)
        r.prefix_matched = matched
        r.state, r.slot = "running", slot
        r.index = n_img + s0
        r.tier = max(r.tier, self.backend.tier)
        self._slots[slot] = r
        self.metrics.on_start(r.id, self.step_count)
        self.trace.admit(r.id, slot, matched, s0)
        self.trace.req_tier(r.id, self.backend.tier)
        if matched:
            self.trace.prefill(r.id, slot, s_sfx, sp_sfx, True)
        else:
            self.trace.prefill(r.id, slot, s0, sp, False)
        if self.cfg.device_loop:
            tok = self.backend.first_token(row, r.id, r.temperature)
            self.metrics.on_host_sync("prefill")     # the one int32 pulled
            self.trace.host_sync("prefill", 4)
            eos = -1 if r.eos_id is None else int(r.eos_id)
            rem = 0 if (r.eos_id is not None and tok == r.eos_id) \
                else budget - 1
            self.backend.install(slot, tok, r.index, r.temperature, eos, rem,
                                 self._spec_limit(r))
        else:
            tok = self._sample_host(np.asarray(row[0]), r)
            self.metrics.on_host_sync("prefill")
            self.trace.host_sync("prefill", 4)
            self._tokens[slot, 0] = tok
            self._indices[slot] = r.index
        self._emit(r, tok, self.step_count)  # may finish (max_new_tokens == 1)

    def _quality_probe(self, r: Request, batch, row, exact: bool,
                       col: int) -> None:
        """Per-tier quality probe (serve.ledger): shadow-run this
        admission's prefill through TIER-0 params and compare the sampled
        logits column host-side — top-1 agreement + mean |Δlogit| recorded
        per active (sparsity, bits) tier. Two deliberate host pulls,
        metered as kind='quality' so `host_syncs_decode` stays exactly the
        decode-dispatch count. Both prefills are deterministic functions of
        (prompt, params), so an offline recompute of the same slot
        reproduces these gauges EXACTLY (tests/test_ledger.py)."""
        shadow = self.backend.quality_shadow(batch, exact)
        ref = np.asarray(shadow[:, col][0], np.float64)
        mine = np.asarray(row)[0].astype(np.float64)
        self.metrics.on_host_sync("quality", 2)
        self.trace.host_sync("quality", 8 * mine.size)
        top1 = bool(int(np.argmax(mine)) == int(np.argmax(ref)))
        mad = float(np.mean(np.abs(mine - ref)))
        tier = self.backend.tier
        self.metrics.on_quality_probe(tier, top1, mad)
        self.trace.quality_probe(r.id, tier, top1, mad)
        self.quality_log.append({"rid": r.id, "tier": tier,
                                 "top1": top1, "mad": mad})

    def _decode_block(self) -> int:
        """Device-resident path: ONE dispatch = K fused micro-steps; sync a
        (K, B) int32 token block and catch host bookkeeping up to it."""
        k = self.cfg.decode_chunk
        n_active = self.pool.n_active
        self.metrics.on_decode_step(n_active, self.cfg.n_slots,
                                    micro_steps=k)
        self.trace.dispatch_begin()
        block = self.backend.decode_block()
        self.trace.decode_dispatch(k, n_active, self.cfg.n_slots)
        if self._gather_bytes:
            self.metrics.on_gather_avoided(self._gather_bytes)
            self.trace.gather_avoided(self._gather_bytes)
        self.metrics.on_host_sync("decode")
        self.trace.host_sync("decode", self._sync_bytes)
        # ledger drain rides the dispatch sync above — no extra crossing
        self.ledger.on_drain(self.backend.last_ledger, self.step_count)
        if self.ledger.enabled and self.backend.maybe_rebase_ledger():
            self.ledger.rebase()
        # mirror the tracer's cumulative ring-buffer drop count so
        # report()/telemetry surface lost trace events (an int attribute
        # store: allocation-free on the disabled path)
        self.metrics.trace_dropped = self.trace.dropped
        # fault detection at the host/device boundary: a healthy fused step
        # emits argmax/Gumbel-argmax indices, ALWAYS in [0, vocab) — an
        # out-of-range token in a live column is proof of a corrupted
        # dispatch (NaN logits, device fault), never a sampling outcome.
        live = [s for s in range(self.cfg.n_slots)
                if self._slots[s] is not None]
        sub = block[:, live]
        if sub.size and (int(sub.min()) < 0 or int(sub.max()) >= self._vocab):
            raise ReplicaFault(
                f"decode sync outside [0, {self._vocab}): corrupted "
                "dispatch (NaN logits or device fault)")
        for j in range(k):
            step = self.step_count + j
            for slot in range(self.cfg.n_slots):
                r = self._slots[slot]
                if r is None:
                    continue
                r.index += 1
                self._emit(r, int(block[j, slot]), step)
        return k

    def _spec_limit(self, r: Request) -> int:
        """Per-slot speculation cap: the engine K, clamped by the request's
        own `speculate` (0 = opt out). Used for both the device install and
        the metrics' proposed-token denominators — a capped slot proposes
        only up to its cap, so acceptance rates stay meaningful."""
        if not self.cfg.speculate:
            return 0
        if r.speculate is None:
            return self.cfg.speculate
        return max(0, min(r.speculate, self.cfg.speculate))

    def _decode_spec(self) -> int:
        """Speculative path: ONE fused propose-then-verify dispatch commits
        1..K+1 tokens per live slot. The sync is (commit block, commit
        counts, accepted counts) — still one crossing; the host replays the
        committed prefix per slot in micro-step order and the engine clock
        advances by the deepest commit (speculation compresses wall
        dispatches, not the step-latency bookkeeping)."""
        k = self.cfg.speculate
        n_active = self.pool.n_active
        # slab forwards actually run per cycle: k+1 draft micro-steps plus
        # the target verify — one batched forward for positional-cache
        # archs, k+1 micro-steps for recurrent ones (steps builder)
        self.metrics.on_decode_step(n_active, self.cfg.n_slots,
                                    micro_steps=(k + 1) + self._verify_steps)
        self.trace.dispatch_begin()
        block, n_commit, n_accept = self.backend.spec_decode_block()
        self.trace.spec_dispatch(k, n_active, self.cfg.n_slots)
        if self._gather_bytes:
            self.metrics.on_gather_avoided(self._gather_bytes)
            self.trace.gather_avoided(self._gather_bytes)
        self.metrics.on_host_sync("decode")
        self.trace.host_sync("decode", self._sync_bytes)
        # ledger drain rides the dispatch sync above — no extra crossing
        self.ledger.on_drain(self.backend.last_ledger, self.step_count)
        if self.ledger.enabled and self.backend.maybe_rebase_ledger():
            self.ledger.rebase()
        # mirror the tracer's cumulative ring-buffer drop count so
        # report()/telemetry surface lost trace events (an int attribute
        # store: allocation-free on the disabled path)
        self.metrics.trace_dropped = self.trace.dropped
        # fault detection (see _decode_block): validate every live slot's
        # committed prefix BEFORE any emission side effects
        for slot in range(self.cfg.n_slots):
            r = self._slots[slot]
            if r is None:
                continue
            m = int(n_commit[slot])
            if not 0 <= m <= k + 1 or (m and (
                    int(block[slot, :m].min()) < 0
                    or int(block[slot, :m].max()) >= self._vocab)):
                raise ReplicaFault(
                    f"speculative sync outside [0, {self._vocab}) or commit "
                    f"count {m} out of [0, {k + 1}]: corrupted dispatch")
        advanced, proposed, accepted = 1, 0, 0
        for slot in range(self.cfg.n_slots):
            r = self._slots[slot]
            if r is None:
                continue
            m = int(n_commit[slot])
            # a draft token only ever had a chance to commit within the
            # slot's remaining budget: clamp the proposed-denominator so
            # short-budget tails don't deflate the acceptance signal
            lim = min(self._spec_limit(r),
                      r.max_new_tokens - len(r.generated))
            advanced = max(advanced, m)
            for j in range(m):
                r.index += 1
                self._emit(r, int(block[slot, j]), self.step_count + j)
            if r.done and r.eos_id is not None and m \
                    and int(block[slot, m - 1]) == r.eos_id:
                # EOS ended the request mid-block: columns past it never
                # had a commit chance either
                lim = min(lim, m)
            proposed += lim
            acc = int(n_accept[slot])
            accepted += acc
            if lim:
                self.metrics.on_slot_speculation(slot, acc, lim)
                self.trace.spec_slot(slot, acc, m, lim)
        self.metrics.on_spec_dispatch(proposed=proposed, accepted=accepted)
        return advanced

    def _decode_step_host(self) -> int:
        """PR-1 host loop: full-vocab logits pulled, numpy sampling, token +
        index vectors re-uploaded every step. Kept as the measured baseline
        (serve_bench 'host' mode) and as the numpy-rng sampling reference."""
        n_active = self.pool.n_active
        self.metrics.on_decode_step(n_active, self.cfg.n_slots)
        self.trace.dispatch_begin()
        rows = self.backend.decode_host(self._tokens, self._indices)
        self.trace.decode_dispatch(1, n_active, self.cfg.n_slots)
        # logits pull + token and index uploads: 3 crossings per step
        self.metrics.on_host_sync("decode", 3)
        self.trace.host_sync("decode", self._sync_bytes)
        for slot, r in enumerate(self._slots):
            if r is None:
                continue
            r.index += 1
            self._indices[slot] = r.index
            tok = self._sample_host(rows[slot], r)
            self._tokens[slot, 0] = tok
            self._emit(r, tok, self.step_count)
        return 1
