"""Device-resident ineffectual-work ledger for the serving hot path.

Kratos's thesis is that ineffectual operations — zero weights, zero
activations, dead bits — can be skipped entirely. The repo accounts for
weight-side savings analytically (packed nnz-block FLOPs,
`draft_cost_fraction`); this module measures the *activation* side at
runtime, on device, inside the fused decode/spec/suffix-prefill steps:

  * per-layer activation zero / near-zero(|x| <= threshold) element counts
    around the packed GEMMs (probe taps in models.transformer /
    models.attention);
  * per-group zero histograms (sparseCNN-style: consecutive `group`-channel
    groups, bin j = groups with exactly j near-zero channels);
  * dead k-block counts at the configured block geometry — activation rows
    whose `k_block` consecutive channels are all near-zero, i.e. exactly
    what an activation-skipping GEMM at that geometry would have skipped;
  * effective-vs-dense FLOPs/bytes per probed GEMM (the weight-read and
    MAC work the dead k-blocks would have saved).

The probe emits one fixed-width f32 row per GEMM tap; rows sum per layer
into an `(n_layers, width)` matrix that the fused steps carry as DONATED
loop state (a `lax.scan` carry across the K micro-steps) and return
alongside the token block, so the engine drains it in the same
`device_get` that already syncs the tokens — zero extra host syncs.
Counters accumulate on device in f32 (exact up to 2**24); the backend
rebases the buffer to zero before any cell approaches that, and the
`LedgerSink` keeps the running float64 totals host-side.

Everything is optional: models take `probe=None` (no in-graph ops traced
when absent), the engine wires `NULL_LEDGER` when `EngineConfig.ledger`
is None — a fixed-arity no-op singleton whose hot-path calls allocate
nothing (gated by tests/test_ledger.py::test_null_ledger_zero_alloc,
same idiom as trace.NULL_TRACER).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

import numpy as np

# jnp is imported lazily inside LedgerProbe so that host-side consumers
# (qor gating, roofline joins) can import the schema without jax.


# ---------------------------------------------------------------- schema

# Fixed columns of a probe row; histogram bins follow (group + 1 of them).
C_ELEMS = 0          # activation elements probed
C_ZEROS = 1          # exact zeros
C_NEAR = 2           # |x| <= threshold
C_GROUPS = 3         # channel groups probed
C_KBLOCKS = 4        # k-blocks examined (per activation row)
C_DEAD_KB = 5        # k-blocks entirely near-zero (skippable work)
C_FLOPS_DENSE = 6    # dense MACs*2 the probed GEMMs would do
C_FLOPS_EFF = 7      # ... minus the dead-k-block share
C_BYTES_DENSE = 8    # act read + weight read + out write, dense
C_BYTES_EFF = 9      # weight-read term scaled by the live-k-block share
N_FIXED = 10
C_HIST = N_FIXED     # first histogram bin (bin j = j near-zero channels)


@dataclasses.dataclass(frozen=True)
class LedgerConfig:
    """Knobs for the ineffectual-work probes (launch/serve flags map here).

    threshold: |x| <= threshold counts as near-zero (0.0 = exact zeros
    only — the right setting for ReLU-family archs where true zeros are
    the signal). group: channels per histogram group. k_block: contraction
    block the dead-block accounting assumes (what an activation-skipping
    GEMM would tile k by). quality_every: shadow-run every Nth admitted
    request's prefill logits through tier 0 (0 = never).
    """

    threshold: float = 0.0
    group: int = 8
    k_block: int = 32
    quality_every: int = 0

    @property
    def width(self) -> int:
        return N_FIXED + self.group + 1


def probe_width(cfg: LedgerConfig) -> int:
    return cfg.width


def hist_checksum(mat: np.ndarray, group: int) -> float:
    """Order-sensitive scalar over the per-layer histograms — one number
    benchmarks/qor.py can gate EXACTLY (bit-determinism of the whole
    histogram matrix collapses to equality of this sum)."""
    mat = np.asarray(mat, np.float64)
    h = mat[:, C_HIST:C_HIST + group + 1]
    weights = np.arange(1, group + 2, dtype=np.float64)
    return float((h * weights[None, :]).sum())


# ----------------------------------------------------------------- probe

class LedgerProbe:
    """Trace-time tap collector: models call `tap(x, n_out)` around their
    packed GEMMs; the forward drains the accumulated rows once per layer
    (`layer_row`). Python-list state only lives within one layer's trace
    (no scan boundary crosses a tap/drain pair), so the same probe object
    threads through prelude loop and scan body alike.
    """

    def __init__(self, cfg: LedgerConfig):
        self.cfg = cfg
        self._taps: List[Any] = []

    # -- in-graph measurement --------------------------------------------

    def measure(self, x, n_out: int):
        """One probe row for activation `x` (..., d) feeding a GEMM with
        fan-out `n_out`. All counts f32; shapes are static so the dense
        FLOP/byte terms are trace-time constants."""
        import jax.numpy as jnp

        cfg = self.cfg
        x = x.astype(jnp.float32)
        d = x.shape[-1]
        flat = x.reshape(-1, d)
        rows = int(flat.shape[0])
        ax = jnp.abs(flat)
        near_mask = ax <= cfg.threshold
        n_zero = jnp.sum(flat == 0.0).astype(jnp.float32)
        n_near = jnp.sum(near_mask).astype(jnp.float32)

        g = cfg.group
        dg = d // g
        hist = jnp.zeros((g + 1,), jnp.float32)
        n_groups = 0.0
        if dg:
            cnt = jnp.sum(near_mask[:, :dg * g].reshape(rows, dg, g), axis=-1)
            hist = jnp.sum(
                (cnt[..., None] == jnp.arange(g + 1)[None, None, :]),
                axis=(0, 1)).astype(jnp.float32)
            n_groups = float(rows * dg)

        kb = cfg.k_block
        dk = d // kb
        n_kb = float(rows * dk)
        if dk:
            dead = jnp.sum(jnp.all(
                near_mask[:, :dk * kb].reshape(rows, dk, kb), axis=-1)
            ).astype(jnp.float32)
            live_frac = 1.0 - dead / max(n_kb, 1.0)
        else:
            dead = jnp.zeros((), jnp.float32)
            live_frac = jnp.float32(1.0)

        flops_dense = float(2 * rows * d * n_out)
        itemsize = 4                      # probe accounting is f32-denominated
        act_bytes = float(itemsize * (rows * d + rows * n_out))
        w_bytes = float(itemsize * d * n_out)
        fixed = jnp.stack([
            jnp.float32(rows * d), n_zero, n_near,
            jnp.float32(n_groups), jnp.float32(n_kb), dead,
            jnp.float32(flops_dense), flops_dense * live_frac,
            jnp.float32(act_bytes + w_bytes), act_bytes + w_bytes * live_frac,
        ])
        return jnp.concatenate([fixed, hist])

    def tap(self, x, n_out: int) -> None:
        self._taps.append(self.measure(x, n_out))

    def layer_row(self):
        """Sum + clear the taps accumulated during one layer application."""
        import jax.numpy as jnp

        rows = self._taps
        self._taps = []
        if not rows:
            return jnp.zeros((self.cfg.width,), jnp.float32)
        out = rows[0]
        for r in rows[1:]:
            out = out + r
        return out


# ------------------------------------------------------------------ sink

class NullLedger:
    """No-op ledger sink: the engine's hot path calls these unconditionally
    when the ledger is disabled, so they must be fixed-arity and allocate
    NOTHING (tests/test_ledger.py::test_null_ledger_zero_alloc)."""

    enabled = False
    total = None

    def on_drain(self, cum, step):
        return None

    def rebase(self):
        return None

    def summary(self):
        return {}


NULL_LEDGER = NullLedger()


class LedgerSink(NullLedger):
    """Host-side accumulator behind the per-dispatch drain.

    `on_drain(cum, step)` receives the CUMULATIVE device matrix pulled in
    the dispatch's one existing sync, computes the per-dispatch delta
    against the previous snapshot, folds it into float64 running totals,
    and fans the delta out to `ServeMetrics.on_ledger` and the tracer's
    `ledger_dispatch` hook (Chrome counter tracks ride on those events).
    `rebase()` resets the snapshot when the backend zeroes the device
    buffer (f32 exactness headroom)."""

    enabled = True

    def __init__(self, cfg: LedgerConfig, n_layers: int, *, metrics=None,
                 tracer=None):
        self.cfg, self.n_layers = cfg, n_layers
        self.metrics, self.tracer = metrics, tracer
        shape = (n_layers, cfg.width)
        self._prev = np.zeros(shape, np.float64)
        self.total = np.zeros(shape, np.float64)

    def on_drain(self, cum, step):
        if cum is None:
            return None
        cum = np.asarray(cum, np.float64)
        delta = cum - self._prev
        self._prev = cum
        self.total = self.total + delta
        t = delta.sum(axis=0)
        if self.metrics is not None:
            self.metrics.on_ledger(
                elems=t[C_ELEMS], zeros=t[C_ZEROS], near=t[C_NEAR],
                groups=t[C_GROUPS], kblocks=t[C_KBLOCKS],
                dead_kblocks=t[C_DEAD_KB],
                flops_dense=t[C_FLOPS_DENSE], flops_eff=t[C_FLOPS_EFF],
                bytes_dense=t[C_BYTES_DENSE], bytes_eff=t[C_BYTES_EFF])
        if self.tracer is not None:
            elems = max(t[C_ELEMS], 1.0)
            kb = max(t[C_KBLOCKS], 1.0)
            fd = max(t[C_FLOPS_DENSE], 1.0)
            self.tracer.ledger_dispatch(
                step, t[C_ZEROS] / elems, t[C_NEAR] / elems,
                t[C_DEAD_KB] / kb, t[C_FLOPS_EFF] / fd,
                t[C_FLOPS_DENSE], t[C_FLOPS_EFF])
        return delta

    def rebase(self):
        self._prev = np.zeros_like(self._prev)

    def summary(self) -> Dict[str, Any]:
        """Bench/analysis view of the running totals: per-layer fractions +
        the full histogram matrix + the qor-gateable checksum."""
        tot = self.total
        elems = np.maximum(tot[:, C_ELEMS], 1.0)
        kb = np.maximum(tot[:, C_KBLOCKS], 1.0)
        return {
            "n_layers": self.n_layers,
            "act_probe_elems": float(tot[:, C_ELEMS].sum()),
            "act_zeros": float(tot[:, C_ZEROS].sum()),
            "act_near_zeros": float(tot[:, C_NEAR].sum()),
            "act_kblocks": float(tot[:, C_KBLOCKS].sum()),
            "act_dead_kblocks": float(tot[:, C_DEAD_KB].sum()),
            "flops_dense": float(tot[:, C_FLOPS_DENSE].sum()),
            "flops_effective": float(tot[:, C_FLOPS_EFF].sum()),
            "bytes_dense": float(tot[:, C_BYTES_DENSE].sum()),
            "bytes_effective": float(tot[:, C_BYTES_EFF].sum()),
            "zero_fraction_by_layer": (tot[:, C_ZEROS] / elems).tolist(),
            "dead_kblock_fraction_by_layer":
                (tot[:, C_DEAD_KB] / kb).tolist(),
            "hist": tot[:, C_HIST:].tolist(),
            "act_hist_checksum": hist_checksum(tot, self.cfg.group),
        }
