"""Replica router: one front-end over N engine replicas.

A replica is a whole `InferenceEngine` — its own KV slab, decode state and
compiled steps, on whatever placement its backend chose (`LocalBackend`
engines share the jax default device; `ShardedBackend` engines typically
sit one per data-parallel submesh, `launch.mesh.replica_meshes`). The
router owns nothing that executes: it decides which replica a request
joins, steps the replicas in lockstep rounds, and aggregates their metrics.

Admission (least-loaded / deficit): `submit` scores every replica with
`scheduler.replica_load` (active + waiting - free — the same signals the
per-engine schedulers consume) and tries them in ascending-load order, with
a rotating tiebreak so equal-load replicas share arrivals round-robin
instead of piling onto index 0. A replica whose bounded waiting deque
(`EngineConfig.max_waiting`) is full raises `EngineSaturated`; the router
counts the spill and tries the next replica. When EVERY replica rejects,
the request parks in the router's overflow deque and drains into the first
replica with queue headroom at the next `step()` — backpressure composes:
each engine's deque is bounded, the router absorbs the burst.

Rebalance: queues skew when request lengths do (a replica that admitted
three long generations serves its queue slower than its siblings). Each
`step()`, any replica whose waiting deque exceeds what it can admit soon
(waiting > free slots) donates tail-of-queue requests —
`engine.steal_waiting`, never-started requests only; running slots are
pinned to their slab — to replicas with immediate headroom
(`engine.adopt`). The Request objects the caller holds survive the move.

The router's clock: one `step()` = one decode dispatch round across all
replicas (replicas with no work skip their dispatch rather than burn an
idle step). `report()` adds `tokens_per_router_step` — aggregate tokens
over lockstep rounds, directly comparable to a single engine's
tokens_per_step on the same trace; N saturated replicas approach N x.

Failover (PR 7): a replica whose step raises `ReplicaFault` (crashed
dispatch, or the engine's decode-sync validation caught corrupt output) is
marked dead — `alive[i] = False`, excluded from admission / rebalance /
stepping — and its non-finished requests are EVACUATED
(`engine.evacuate`: running requests fold generated output into their
prompts, so a survivor's greedy re-prefill resumes the stream
token-identically) and re-admitted through the normal `_place` path with
`failover_from` stamped (the adopting engine counts `failovers`). With
`auto_restart` and an `engine_factory`, the dead replica is replaced by a
fresh engine (its metrics retire into the fleet aggregate — counters are
never lost). `run()` raises rather than spins when work remains and no
replica is alive.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.serve.engine import (EngineConfig, EngineSaturated,
                                InferenceEngine, ReplicaFault)
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import Request, replica_load


class ReplicaRouter:
    """Least-loaded request routing + drain/rebalance over engine replicas."""

    def __init__(self, replicas: Sequence[InferenceEngine], *,
                 hold_overflow: bool = True, engine_factory=None,
                 auto_restart: bool = False):
        if not replicas:
            raise ValueError("router needs at least one replica")
        if auto_restart and engine_factory is None:
            raise ValueError("auto_restart needs an engine_factory(i) to "
                             "build the replacement replica")
        self.replicas = list(replicas)
        for i, eng in enumerate(self.replicas):
            eng.trace.replica = i      # stamps events + Chrome process ids
        self.hold_overflow = hold_overflow
        self.engine_factory = engine_factory
        self.auto_restart = auto_restart
        self.alive: List[bool] = [True] * len(self.replicas)
        self._overflow: collections.deque = collections.deque()
        self._rr = 0                      # rotating tiebreak for equal loads
        self.step_count = 0
        self.spills = 0                   # submits bounced to a sibling
        self.overflowed = 0               # submits parked in the router deque
        self.rebalanced = 0               # waiting requests moved mid-run
        self.rejected_fleet = 0           # submits EVERY replica rejected
        self.replica_deaths = 0           # ReplicaFault -> marked dead
        self.restarts = 0                 # dead replicas replaced fresh
        # metrics of replaced replicas: a restart must never lose counters
        # from the fleet aggregate
        self._retired_metrics: List[ServeMetrics] = []
        self.requests: List[Request] = []

    @classmethod
    def build(cls, model, cfg: EngineConfig, n_replicas: int, *,
              backend_factory=None, scheduler_factory=None,
              **kwargs) -> "ReplicaRouter":
        """N identical replicas of (model, cfg). backend_factory(i) returns
        the i-th replica's ExecutionBackend (None = LocalBackend each);
        scheduler_factory(i) likewise for admission policy. The same
        closure becomes the router's `engine_factory`, so `auto_restart`
        works out of the box."""
        def engine_factory(i: int) -> InferenceEngine:
            return InferenceEngine(
                model, cfg,
                scheduler=scheduler_factory(i) if scheduler_factory else None,
                backend=backend_factory(i) if backend_factory else None)

        replicas = [engine_factory(i) for i in range(n_replicas)]
        kwargs.setdefault("engine_factory", engine_factory)
        return cls(replicas, **kwargs)

    # ------------------------------------------------------------------ API

    def submit(self, prompt, max_new_tokens: int, **kw) -> Request:
        r = Request(id=-1, prompt=np.asarray(prompt, np.int32).reshape(-1),
                    max_new_tokens=max_new_tokens,
                    arrival_step=kw.pop("arrival_step", 0),
                    temperature=kw.pop("temperature", 0.0),
                    eos_id=kw.pop("eos_id", None),
                    extras=kw.pop("extras", None),
                    on_token=kw.pop("on_token", None),
                    speculate=kw.pop("speculate", None),
                    deadline_steps=kw.pop("deadline_steps", None),
                    deadline_ms=kw.pop("deadline_ms", None),
                    slo=kw.pop("slo", ""))
        if kw:
            raise TypeError(f"unknown submit kwargs: {sorted(kw)}")
        self.requests.append(r)
        placed = self._place(r)
        if placed:
            return r
        if not self.hold_overflow:
            self.requests.pop()
            # counted ONCE at the router: the per-replica `rejected`
            # counters record every bounce (one submit can bounce off all
            # N), so the fleet-level refusal needs its own counter for
            # per-replica/fleet totals to reconcile
            self.rejected_fleet += 1
            raise EngineSaturated("all replicas rejected the request")
        self._overflow.append(r)
        self.overflowed += 1
        return r

    @property
    def n_waiting(self) -> int:
        return len(self._overflow) + sum(e.n_waiting for e in self.replicas)

    @property
    def n_active(self) -> int:
        return sum(e.pool.n_active for e in self.replicas)

    def step(self) -> None:
        """One lockstep round: drain overflow, rebalance skewed queues,
        then one engine step per replica. Idle replicas step too (a free
        idle tick, no dispatch): freezing an idle replica's local clock
        would make a request adopted later — whose arrival_step is on the
        trace-global clock — wait out the frozen gap all over again, and
        would skew its latency record against replicas that kept ticking."""
        self.step_count += 1
        self._drain_overflow()
        self._rebalance()
        for i, eng in enumerate(self.replicas):
            if not self.alive[i]:
                continue
            try:
                eng.step()
            except ReplicaFault as e:
                self._fail(i, e)

    def run(self, max_steps: Optional[int] = None) -> Dict[int, List[int]]:
        limit = max_steps if max_steps is not None else \
            10 * sum(r.max_new_tokens + 2 for r in self.requests) \
            + max([r.arrival_step for r in self.requests], default=0)
        while self.n_waiting or self.n_active:
            if not any(self.alive):
                raise RuntimeError(
                    "every replica is dead with work remaining — enable "
                    "auto_restart or drain the overflow elsewhere")
            if limit <= 0:
                raise RuntimeError("router did not drain within step limit")
            self.step()
            limit -= 1
        return {i: list(r.generated) for i, r in enumerate(self.requests)}

    def report(self) -> Dict[str, Any]:
        pool = self._retired_metrics + [e.metrics for e in self.replicas]
        rep = ServeMetrics.aggregate(pool)
        # retired metrics joined the pool above; the fleet SIZE is the
        # replica count, not the metrics count
        rep["n_replicas"] = float(len(self.replicas))
        rep.update({
            "router_steps": float(self.step_count),
            "tokens_per_router_step": rep["tokens_generated"]
            / max(1, self.step_count),
            "spills": float(self.spills),
            "overflowed": float(self.overflowed),
            "rebalanced": float(self.rebalanced),
            "rejected_fleet": float(self.rejected_fleet),
            "replica_deaths": float(self.replica_deaths),
            "restarts": float(self.restarts),
        })
        return rep

    @property
    def tracers(self) -> List[Any]:
        """Replica tracers with events (empty when tracing is off) — feed
        straight into trace.export_jsonl / trace.export_chrome for one
        merged fleet trace, one Chrome process per replica."""
        return [e.trace for e in self.replicas if e.trace.enabled]

    def format_report(self) -> str:
        r = self.report()
        return (f"{int(r['n_replicas'])} replicas | "
                f"{int(r['requests_completed'])} reqs, "
                f"{int(r['tokens_generated'])} toks"
                f" | {r['tokens_per_router_step']:.2f} tok/router-step, "
                f"{r['tok_per_s']:.1f} tok/s wall"
                f" | occupancy {r['mean_occupancy']:.2f}"
                f" | spills {int(r['spills'])}, "
                f"rebalanced {int(r['rebalanced'])}, "
                f"rejected {int(r['rejected'])}"
                + (f" | deaths {int(r['replica_deaths'])}, "
                   f"restarts {int(r['restarts'])}, "
                   f"failovers {int(r['failovers'])}"
                   if r["replica_deaths"] else ""))

    # ------------------------------------------------------------- internals

    def _order(self) -> List[int]:
        n = len(self.replicas)
        loads = [replica_load(e.pool.n_active, e.pool.n_free, e.n_waiting)
                 for e in self.replicas]
        order = sorted(range(n), key=lambda i: (loads[i], (i - self._rr) % n))
        self._rr = (self._rr + 1) % n
        return [i for i in order if self.alive[i]]

    def _fail(self, i: int, err: Exception) -> None:
        """Health-check verdict: mark replica `i` dead, evacuate its
        non-finished requests, optionally restart it, then re-admit every
        orphan to a survivor (failover_from stamped — the adopting engine
        counts the failover). Orphans nobody can take park in overflow, or
        — with hold_overflow off — shed terminally on the dead replica's
        metrics so no request ever silently vanishes."""
        eng = self.replicas[i]
        self.alive[i] = False
        self.replica_deaths += 1
        eng.trace.fault("replica_dead", str(err))
        orphans = eng.evacuate()
        if self.auto_restart:
            self._retired_metrics.append(eng.metrics)
            fresh = self.engine_factory(i)
            fresh.trace.replica = i
            self.replicas[i] = fresh
            self.alive[i] = True
            self.restarts += 1
        for r in orphans:
            r.failover_from = i
            if self._place(r):
                continue
            if self.hold_overflow:
                self._overflow.append(r)
                self.overflowed += 1
            else:
                r.state, r.shed_reason = "shed", "failover"
                eng.metrics.on_shed("failover")
                eng.trace.shed(r.id, -1, "failover", len(r.generated))

    def _place(self, r: Request) -> bool:
        for i in self._order():
            try:
                self.replicas[i].adopt(r)
                return True
            except EngineSaturated:
                self.spills += 1
        return False

    def _drain_overflow(self) -> None:
        """Move parked requests into replicas WITH QUEUE HEADROOM. Unlike
        the fresh-submit path this never knocks on a full deque: a retry
        round against a still-saturated fleet must not re-increment spills
        or the engines' rejected counters (those count submits that
        bounced, not rounds the fleet stayed busy)."""
        while self._overflow:
            placed = False
            for i in self._order():
                eng = self.replicas[i]
                if eng.cfg.max_waiting is not None \
                        and eng.n_waiting >= eng.cfg.max_waiting:
                    continue
                eng.adopt(self._overflow[0])   # headroom => cannot saturate
                placed = True
                break
            if not placed:
                return                   # still saturated; retry next round
            self._overflow.popleft()

    def _rebalance(self) -> None:
        """Move tail-of-queue waiting requests from replicas that cannot
        admit them soon (waiting > free slots) to replicas that can."""
        live = [e for i, e in enumerate(self.replicas) if self.alive[i]]
        for src in live:
            excess = src.n_waiting - src.pool.n_free
            if excess <= 0:
                continue
            for dst in sorted(live,
                              key=lambda e: replica_load(
                                  e.pool.n_active, e.pool.n_free,
                                  e.n_waiting)):
                if dst is src or excess <= 0:
                    continue
                room = dst.pool.n_free - dst.n_waiting
                if dst.cfg.max_waiting is not None:
                    room = min(room, dst.cfg.max_waiting - dst.n_waiting)
                if room <= 0:
                    continue
                moved = src.steal_waiting(min(room, excess))
                for r in moved:
                    try:
                        dst.adopt(r)     # room > 0 => cannot saturate ...
                    except (EngineSaturated, ValueError):
                        src.adopt(r)     # ... but heterogeneous replica
                        continue         # configs may still refuse: return
                    excess -= 1          # the request instead of losing it
                    self.rebalanced += 1


# --------------------------------------------------------------------- fleet

@dataclasses.dataclass
class FleetRequest:
    """The coordinator's handle on one fleet request. Unlike scheduler.
    Request it holds no engine state — the owning PROCESS has that — only
    what the coordinator needs to route, account, and fail over: the
    original prompt, the budget, and every token the fleet has reported
    so far (progress deltas + done messages, in order). On failover the
    accumulated tokens fold into the resubmitted prompt exactly like
    `engine.evacuate` folds generated output — same re-prefill semantics,
    one process boundary up."""

    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival_step: int = 0
    temperature: float = 0.0
    eos_id: Optional[int] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    process: int = -1                 # owning process (-1 = unplaced)
    state: str = "waiting"            # waiting | running | done | shed
    failover_from: int = -1           # last dead process this escaped

    @property
    def generated(self) -> List[int]:
        return list(self.tokens)

    @property
    def finished(self) -> bool:
        return self.state in ("done", "shed")


class FleetRouter:
    """ReplicaRouter semantics lifted one process boundary up: least-
    loaded admission, spill-over and heartbeat-timeout failover across
    PROCESSES, each of which runs its own ReplicaRouter over its own
    engines. The two routers compose — fleet picks the process, the
    process's ReplicaRouter picks the replica.

    The crucial difference from ReplicaRouter: every signal here is a
    POSSIBLY-STALE message, not a live attribute. Admission reads
    `control.FleetState` (last heartbeat + in-flight submit credits, see
    its docstring for the anti-flap argument); liveness is heartbeat
    silence, not an exception (`ReplicaFault` cannot cross a process).
    Failover re-submits a dead process's unfinished requests to a
    survivor with the accumulated tokens folded into the prompt — greedy
    re-prefill continues the stream token-identically, the same
    guarantee `engine.evacuate` gives inside one process.

    `processes` are `control.ProcessHandle`s: LocalProcess (in-process,
    deterministic — tests and the coordinator's own engines) and
    RemoteProcess (a socket to a launch.fleet worker) mix freely.
    """

    def __init__(self, processes: Sequence[Any], *, cfg=None):
        from repro.serve.control import FleetConfig, FleetState
        if not processes:
            raise ValueError("fleet router needs at least one process")
        self.cfg = cfg or FleetConfig()
        self.state = FleetState(self.cfg)
        self.processes: Dict[int, Any] = {p.process_index: p
                                          for p in processes}
        if len(self.processes) != len(processes):
            raise ValueError("duplicate process_index in fleet")
        for pi in self.processes:
            # every handle was alive at construction (remote handles come
            # from a consumed hello handshake) — seed liveness so submits
            # before the first heartbeat spread on credits instead of
            # piling onto whichever process reports first
            self.state.last_seen.setdefault(pi, 0.0)
        self.now = 0.0                 # the coordinator's clock (steps here;
        #                                a live deployment may pass seconds)
        self.step_count = 0
        self.requests: Dict[int, FleetRequest] = {}
        self._next_rid = 0
        self._overflow: collections.deque = collections.deque()
        self.overflowed = 0
        self.fleet_failovers = 0       # unfinished requests re-homed
        self._reports: Dict[int, Dict[str, Any]] = {}
        self._said_bye: set = set()

    # ------------------------------------------------------------------ API

    def submit(self, prompt, max_new_tokens: int, **kw) -> FleetRequest:
        r = FleetRequest(
            rid=self._next_rid,
            prompt=np.asarray(prompt, np.int32).reshape(-1),
            max_new_tokens=max_new_tokens,
            arrival_step=kw.pop("arrival_step", 0),
            temperature=kw.pop("temperature", 0.0),
            eos_id=kw.pop("eos_id", None))
        if kw:
            raise TypeError(f"unknown submit kwargs: {sorted(kw)}")
        self._next_rid += 1
        self.requests[r.rid] = r
        if not self._dispatch(r):
            self._overflow.append(r.rid)   # no admissible process YET:
            self.overflowed += 1           # parks until snapshots arrive
        return r

    def step(self) -> None:
        """One coordinator round: advance/drain every process, fold their
        messages into FleetState, pass the death verdict on heartbeat
        silence (failing over the victims' requests), then drain parked
        submissions into whatever the fresh snapshots admit."""
        self.step_count += 1
        self.now = float(self.step_count)
        for pi, p in self.processes.items():
            # dead processes drain too: their late messages must be SEEN
            # to be counted ignored (resurrections_ignored), not left to
            # rot in a socket buffer
            for msg in p.pump(self.now):
                self._handle(pi, msg)
        for pi in self.state.check(self.now):
            self._failover(pi)
        self._drain_overflow()

    def run(self, max_steps: Optional[int] = None) -> Dict[int, List[int]]:
        limit = max_steps if max_steps is not None else \
            40 * sum(r.max_new_tokens + 2 for r in self.requests.values()) \
            + int(4 * self.cfg.heartbeat_timeout) + 100
        while any(not r.finished for r in self.requests.values()):
            live = [pi for pi in self.processes
                    if pi not in self.state.dead]
            if not live:
                raise RuntimeError("every fleet process is dead with work "
                                   "remaining")
            if limit <= 0:
                raise RuntimeError("fleet did not drain within step limit")
            self.step()
            limit -= 1
        return {r.rid: list(r.tokens) for r in self.requests.values()}

    def stop(self, max_steps: int = 2000) -> None:
        """Drain shutdown: ask every live process to stop, then pump until
        each has delivered its final report (or its socket dies)."""
        live = [pi for pi in self.processes if pi not in self.state.dead]
        for pi in live:
            self.processes[pi].stop()
        waiting = set(live)
        while waiting and max_steps > 0:
            max_steps -= 1
            progress = False
            for pi in list(waiting):
                p = self.processes[pi]
                for msg in p.pump(self.now):
                    self._handle(pi, msg)
                    progress = True
                if pi in self._reports or pi in self._said_bye \
                        or not p.alive:
                    waiting.discard(pi)
            if waiting and not progress:
                # subprocess workers need wall time to drain + report; a
                # tight loop would spin the step budget out in ms (in-
                # process fleets never hit this: their pump IS the work)
                time.sleep(0.002)

    def report(self) -> Dict[str, Any]:
        """Fleet-pooled metrics: every process ships its per-replica
        ServeMetrics payloads in its final report; the coordinator
        rebuilds the objects and reuses `ServeMetrics.aggregate` — the
        same pooling discipline (counters sum, percentiles pool the
        record union) as ReplicaRouter.report, now across processes.
        A crashed process's counters are lost with it (its report never
        arrives); the request-level truth (`fleet_tokens`) survives,
        because the coordinator accumulated every progress delta."""
        pool = [ServeMetrics.from_payload(pl)
                for rep in self._reports.values()
                for pl in rep.get("metrics", [])]
        agg = ServeMetrics.aggregate(pool) if pool else {}
        done = [r for r in self.requests.values() if r.state == "done"]
        # the fleet's deterministic clock: processes decode CONCURRENTLY,
        # so aggregate throughput per step is tokens over the SLOWEST
        # process's engine steps (a max, not a sum — the wall-clock analog
        # on the step clock), comparable to one engine's tokens_per_step
        fleet_steps = max((rep.get("fleet", {}).get("engine_steps", 0)
                           for rep in self._reports.values()), default=0)
        agg.update({
            "n_processes": float(len(self.processes)),
            "processes_dead": float(len(self.state.dead)),
            "fleet_steps": float(fleet_steps),
            "fleet_tokens": float(sum(len(r.tokens)
                                      for r in self.requests.values())),
            "fleet_requests_completed": float(len(done)),
            "tokens_per_fleet_step": sum(len(r.tokens) for r in done)
            / max(1, fleet_steps),
            "fleet_failovers": float(self.fleet_failovers),
            "fleet_overflowed": float(self.overflowed),
            "resurrections_ignored": float(self.state.resurrections_ignored),
            "stale_skips": float(self.state.stale_skips),
        })
        return agg

    # ------------------------------------------------------------- internals

    def _dispatch(self, r: FleetRequest) -> bool:
        """Least-loaded admissible process off the current snapshots. The
        wire prompt folds accumulated tokens in (empty on first dispatch,
        the failover re-prefill after a death); the wire budget shrinks by
        what was already generated — `engine.adopt`'s arithmetic."""
        pi = self.state.least_loaded(self.now)
        if pi is None:
            return False
        p = self.processes[pi]
        wire_prompt = np.concatenate(
            [r.prompt, np.asarray(r.tokens, np.int32)]) \
            if r.tokens else r.prompt
        ok = p.submit({"kind": "submit", "rid": r.rid, "prompt": wire_prompt,
                       "max_new_tokens": r.max_new_tokens - len(r.tokens),
                       "arrival_step": r.arrival_step,
                       "temperature": r.temperature, "eos_id": r.eos_id,
                       "failover_from": r.failover_from})
        if not ok:
            # the socket is already gone — a death verdict ahead of the
            # heartbeat timeout; fail over whatever else it held
            self.state.mark_dead(pi)
            self._failover(pi)
            return False
        self.state.note_submit(pi)
        r.process, r.state = pi, "running"
        return True

    def _handle(self, pi: int, msg: Dict[str, Any]) -> None:
        from repro.serve.control import ProcessStatus
        kind = msg.get("kind")
        if kind == "status":
            st = ProcessStatus.from_wire(msg)
            if st.process_index != pi:
                return                 # a socket must speak for itself
            if not self.state.observe(st, self.now):
                return                 # dead (resurrection) or stale seq:
            #                            progress dropped WITH the status —
            #                            a zombie's tokens are not truth
            for rid_s, toks in st.progress.items():
                r = self.requests.get(int(rid_s))
                if r is not None and r.process == pi and not r.finished:
                    r.tokens.extend(int(t) for t in toks)
        elif kind == "done":
            if pi in self.state.dead:
                self.state.resurrections_ignored += 1
                return
            r = self.requests.get(int(msg.get("rid", -1)))
            if r is None or r.process != pi or r.finished:
                return                 # failed over elsewhere meanwhile
            r.tokens.extend(int(t) for t in msg.get("tokens", []))
            r.state = msg.get("state", "done")
        elif kind == "hello":
            # liveness accounting starts at contact, not first status: a
            # worker that says hello and then wedges must still time out
            self.state.last_seen.setdefault(pi, self.now)
        elif kind == "report":
            if pi not in self.state.dead:
                self._reports[pi] = msg
        elif kind == "bye":
            self._said_bye.add(pi)
            self.state.last_seen.pop(pi, None)   # clean exit: silence is
            #                                      expected, not a death

    def _failover(self, pi: int) -> None:
        """Re-home every unfinished request of dead process `pi`. A
        request that already hit its budget (or generated its EOS) is
        complete — the coordinator HAS its tokens; only truly unfinished
        streams re-prefill on a survivor."""
        for r in self.requests.values():
            if r.process != pi or r.finished:
                continue
            r.failover_from = pi
            r.process = -1
            if r.max_new_tokens - len(r.tokens) <= 0 or (
                    r.eos_id is not None and r.tokens
                    and r.tokens[-1] == r.eos_id):
                r.state = "done"
                continue
            self.fleet_failovers += 1
            if not self._dispatch(r):
                self._overflow.append(r.rid)
                self.overflowed += 1

    def _drain_overflow(self) -> None:
        while self._overflow:
            r = self.requests[self._overflow[0]]
            if not r.finished and r.process < 0:
                if not self._dispatch(r):
                    return             # still nothing admissible: retry
                #                        next round, no flapping counters
            self._overflow.popleft()
