"""Serve tracing: ring-buffer lifecycle events, span timelines, exports.

Every request that moves through the serving stack crosses a fixed set of
lifecycle edges — submit, admit (with its prefix-match outcome), prefill
dispatch, first token, finish — and every engine step crosses dispatch
edges (decode / speculative propose-then-verify, host syncs, page-pool
traffic). The tracer records each edge as ONE ring-buffer event carrying
BOTH clocks the metrics layer reports in:

  * `step` — the deterministic engine-step clock (compile-noise-free, the
    clock benchmarks gate on);
  * `t`    — monotonic wall seconds since the tracer's epoch
    (`time.perf_counter`, never `time.time`: interval math must not jump
    with NTP). The epoch's wall-clock anchor (`epoch_wall`) is kept so
    exports can be correlated with external logs.

Zero-cost when disabled: the engine holds `NULL_TRACER` (module singleton)
unless `EngineConfig.trace` is set, and every hot-path hook is a plain
attribute lookup + a fixed-arity no-op method call — no conditionals, no
*args tuple packing, no keyword dicts, nothing allocated. `tests/test_trace
.py::test_null_tracer_zero_alloc` gates this. Call sites only pass values
they already computed for metrics (or engine-lifetime constants like the
per-dispatch sync byte counts), so the disabled path does no extra work.

Span pairing: `request_spans()` folds the ring buffer into one timeline per
request — queue (submit -> admit), TTFT (submit -> first token), decode
(first token -> finish) — in both clocks. The step-clock numbers reconcile
EXACTLY with `ServeMetrics.report()` (same TTFT steps, same token counts;
gated by a test): the tracer is a strictly richer view of the same events,
not a second bookkeeping that can drift.

Exports:

  * JSONL (`export_jsonl`): one meta line, then one event per line —
    greppable, diffable, streamable. Schema in docs/trace_format.md.
  * Chrome trace-event JSON (`export_chrome`): load in `chrome://tracing`
    or https://ui.perfetto.dev. One PROCESS per replica, one THREAD track
    per slot (plus an admission track and a dispatch track), request spans
    as complete ("X") events with their step-clock numbers in `args`, and
    an occupancy counter track.

Profiler capture: `TraceConfig.profile_dir` brackets the first
`profile_dispatches` traced decode dispatches with
`jax.profiler.start_trace/stop_trace`, so the DEVICE-side timeline of the
fused step lands next to the host-side spans (one TensorBoard/Perfetto
capture per run; the bracket degrades to a no-op where the profiler is
unavailable, e.g. some CPU-only wheels).

The ring buffer (`capacity` events, default 64k) makes tracing safe to
leave on under sustained traffic: old events fall off the head (counted in
`dropped`) instead of growing the host heap; span pairing simply omits
requests whose submit edge was evicted.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Tracing knobs, carried by `EngineConfig.trace` (None = tracing off).

    out / chrome: default export paths used by `Tracer.export()` (launchers
    pass CLI flags through here); exports can also be called with explicit
    paths. profile_dir: bracket the first `profile_dispatches` decode
    dispatches with jax.profiler so device time is captured alongside the
    host spans."""

    capacity: int = 1 << 16            # ring-buffer events retained
    out: Optional[str] = None          # JSONL export path (export())
    chrome: Optional[str] = None       # chrome://tracing JSON path
    profile_dir: Optional[str] = None  # jax.profiler.start_trace target
    profile_dispatches: int = 3        # dispatches inside the bracket


class NullTracer:
    """The disabled tracer: every hook is a fixed-arity no-op.

    The engine's hot path calls these unconditionally; keeping the
    signatures positional and fixed means CPython allocates nothing per
    call (no *args tuple, no kwargs dict) — gated by
    test_null_tracer_zero_alloc. `step` and `replica` exist so call sites
    and the router can assign them without isinstance checks."""

    enabled = False

    def __init__(self) -> None:
        self.step = 0
        self.replica = 0
        self.process = None  # fleet process index (PR 10); None = not in a
        #                      fleet, and exports stay byte-identical
        self.dropped = 0     # ring-buffer losses: always 0 when disabled

    # -- lifecycle edges ----------------------------------------------------

    def submit(self, rid, n_prompt, arrival_step):
        pass

    def reject(self, n_waiting):
        pass

    def admit(self, rid, slot, matched, n_prompt):
        pass

    def prefill(self, rid, slot, n_tokens, n_padded, suffix):
        pass

    def first_token(self, rid, slot, step):
        pass

    def finish(self, rid, slot, step, n_generated):
        pass

    # -- dispatch edges -----------------------------------------------------

    def dispatch_begin(self):
        pass

    def decode_dispatch(self, k, n_active, n_slots):
        pass

    def spec_dispatch(self, k, n_active, n_slots):
        pass

    def spec_slot(self, slot, accepted, committed, proposed):
        pass

    def host_sync(self, kind, n_bytes):
        pass

    # -- ineffectual-work ledger edges (serve.ledger) -----------------------

    def ledger_dispatch(self, step, zero_frac, near_frac, dead_frac,
                        eff_flop_frac, flops_dense, flops_eff):
        pass

    def quality_probe(self, rid, tier, top1, mad):
        pass

    # -- page-pool edges ----------------------------------------------------

    def page_alloc(self, slot, n_shared, n_fresh):
        pass

    def page_free(self, slot, n_pages):
        pass

    def page_evict(self, n_pages):
        pass

    def pool_wait(self):
        pass

    def gather_avoided(self, n_bytes):
        pass

    def conversation_hit(self, rid, matched):
        pass

    # -- resilience edges (serve.qos / chaos / failover) --------------------

    def tier_change(self, old_tier, new_tier, load):
        pass

    def req_tier(self, rid, tier):
        pass

    def shed(self, rid, slot, reason, n_generated):
        pass

    def failover(self, rid, src_replica):
        pass

    def fault(self, kind, detail):
        pass

    # -- introspection (empty on the null tracer) ---------------------------

    def request_spans(self) -> Dict[int, Dict[str, Any]]:
        return {}

    def export(self) -> None:
        pass


NULL_TRACER = NullTracer()


class Tracer(NullTracer):
    """Ring-buffer event recorder with span pairing and exports."""

    enabled = True

    def __init__(self, cfg: Optional[TraceConfig] = None, *,
                 replica: int = 0) -> None:
        super().__init__()
        self.cfg = cfg or TraceConfig()
        self.replica = replica
        self.epoch = time.perf_counter()   # monotonic zero for every event
        self.epoch_wall = time.time()      # wall anchor for correlation
        self.events: collections.deque = collections.deque(
            maxlen=self.cfg.capacity)
        self.dropped = 0                   # events evicted by the ring
        self._t0d = 0.0                    # dispatch_begin timestamp
        self._profiling = False
        self._profile_left = (self.cfg.profile_dispatches
                              if self.cfg.profile_dir else 0)

    # -- recording ----------------------------------------------------------

    def _push(self, ev: Dict[str, Any]) -> None:
        if len(self.events) == self.events.maxlen:
            self.dropped += 1
        self.events.append(ev)

    def _t(self) -> float:
        return time.perf_counter() - self.epoch

    def submit(self, rid, n_prompt, arrival_step):
        self._push({"ev": "submit", "step": self.step, "t": self._t(),
                    "rid": rid, "n_prompt": n_prompt,
                    "arrival_step": arrival_step})

    def reject(self, n_waiting):
        self._push({"ev": "reject", "step": self.step, "t": self._t(),
                    "n_waiting": n_waiting})

    def admit(self, rid, slot, matched, n_prompt):
        self._push({"ev": "admit", "step": self.step, "t": self._t(),
                    "rid": rid, "slot": slot, "prefix_matched": matched,
                    "prefix_skipped": matched, "n_prompt": n_prompt})

    def prefill(self, rid, slot, n_tokens, n_padded, suffix):
        self._push({"ev": "prefill", "step": self.step, "t": self._t(),
                    "rid": rid, "slot": slot, "n_tokens": n_tokens,
                    "n_padded": n_padded, "suffix": bool(suffix)})

    def first_token(self, rid, slot, step):
        self._push({"ev": "first_token", "step": step, "t": self._t(),
                    "rid": rid, "slot": slot})

    def finish(self, rid, slot, step, n_generated):
        self._push({"ev": "finish", "step": step, "t": self._t(),
                    "rid": rid, "slot": slot, "n_generated": n_generated})

    def dispatch_begin(self):
        self._t0d = self._t()
        if self._profile_left and not self._profiling:
            self._profiling = self._profiler_start()

    def decode_dispatch(self, k, n_active, n_slots):
        t = self._t()
        self._push({"ev": "decode", "step": self.step, "t": self._t0d,
                    "dur": t - self._t0d, "k": k, "n_active": n_active,
                    "occupancy": n_active / max(1, n_slots)})
        self._profiler_tick()

    def spec_dispatch(self, k, n_active, n_slots):
        t = self._t()
        self._push({"ev": "spec", "step": self.step, "t": self._t0d,
                    "dur": t - self._t0d, "k": k, "n_active": n_active,
                    "occupancy": n_active / max(1, n_slots)})
        self._profiler_tick()

    def spec_slot(self, slot, accepted, committed, proposed):
        self._push({"ev": "spec_slot", "step": self.step, "t": self._t(),
                    "slot": slot, "accepted": accepted,
                    "committed": committed, "proposed": proposed,
                    "rolled_back": proposed - accepted})

    def host_sync(self, kind, n_bytes):
        self._push({"ev": "host_sync", "step": self.step, "t": self._t(),
                    "kind": kind, "bytes": n_bytes})

    def ledger_dispatch(self, step, zero_frac, near_frac, dead_frac,
                        eff_flop_frac, flops_dense, flops_eff):
        """Per-dispatch drained ledger fractions (serve.ledger): rendered
        as Chrome counter tracks alongside occupancy."""
        self._push({"ev": "ledger", "step": step, "t": self._t(),
                    "zero_frac": float(zero_frac),
                    "near_frac": float(near_frac),
                    "dead_frac": float(dead_frac),
                    "eff_flop_frac": float(eff_flop_frac),
                    "flops_dense": float(flops_dense),
                    "flops_eff": float(flops_eff)})

    def quality_probe(self, rid, tier, top1, mad):
        self._push({"ev": "quality_probe", "step": self.step,
                    "t": self._t(), "rid": rid, "tier": tier,
                    "top1": bool(top1), "mad": float(mad)})

    def page_alloc(self, slot, n_shared, n_fresh):
        self._push({"ev": "page_alloc", "step": self.step, "t": self._t(),
                    "slot": slot, "shared": n_shared, "fresh": n_fresh})

    def page_free(self, slot, n_pages):
        self._push({"ev": "page_free", "step": self.step, "t": self._t(),
                    "slot": slot, "n_pages": n_pages})

    def page_evict(self, n_pages):
        self._push({"ev": "page_evict", "step": self.step, "t": self._t(),
                    "n_pages": n_pages})

    def pool_wait(self):
        self._push({"ev": "pool_wait", "step": self.step, "t": self._t()})

    def gather_avoided(self, n_bytes):
        self._push({"ev": "gather_avoided", "step": self.step,
                    "t": self._t(), "bytes": n_bytes})

    def conversation_hit(self, rid, matched):
        self._push({"ev": "conversation_hit", "step": self.step,
                    "t": self._t(), "rid": rid, "matched": matched})

    def tier_change(self, old_tier, new_tier, load):
        self._push({"ev": "tier_change", "step": self.step, "t": self._t(),
                    "old_tier": old_tier, "new_tier": new_tier,
                    "load": load})

    def req_tier(self, rid, tier):
        self._push({"ev": "req_tier", "step": self.step, "t": self._t(),
                    "rid": rid, "tier": tier})

    def shed(self, rid, slot, reason, n_generated):
        self._push({"ev": "shed", "step": self.step, "t": self._t(),
                    "rid": rid, "slot": slot, "reason": reason,
                    "n_generated": n_generated})

    def failover(self, rid, src_replica):
        self._push({"ev": "failover", "step": self.step, "t": self._t(),
                    "rid": rid, "src_replica": src_replica})

    def fault(self, kind, detail):
        self._push({"ev": "fault", "step": self.step, "t": self._t(),
                    "kind": kind, "detail": detail})

    # -- profiler bracket ---------------------------------------------------

    def _profiler_start(self) -> bool:
        try:
            import jax
            jax.profiler.start_trace(self.cfg.profile_dir)
            self._push({"ev": "profile_start", "step": self.step,
                        "t": self._t(), "dir": self.cfg.profile_dir,
                        "dispatches": self.cfg.profile_dispatches})
            return True
        except Exception:           # profiler unavailable on this substrate
            self._profile_left = 0
            return False

    def _profiler_tick(self) -> None:
        if not self._profiling:
            return
        self._profile_left -= 1
        if self._profile_left <= 0:
            try:
                import jax
                jax.profiler.stop_trace()
            except Exception:
                pass
            self._profiling = False
            self._push({"ev": "profile_stop", "step": self.step,
                        "t": self._t()})

    # -- span pairing -------------------------------------------------------

    def request_spans(self) -> Dict[int, Dict[str, Any]]:
        """Per-request timeline folded from the ring buffer, both clocks.

        Step-clock fields reconcile exactly with ServeMetrics.report():
        `ttft_steps` = first_token_step - arrival_step, `latency_steps` =
        finish_step - arrival_step, `tokens` = the request's generated
        count. Requests whose submit edge fell off the ring are omitted."""
        spans: Dict[int, Dict[str, Any]] = {}
        for ev in self.events:
            rid = ev.get("rid")
            if rid is None:
                continue
            kind = ev["ev"]
            if kind == "submit":
                spans[rid] = {
                    "rid": rid, "replica": self.replica,
                    "arrival_step": ev["arrival_step"],
                    "n_prompt": ev["n_prompt"],
                    "submit_step": ev["step"], "submit_t": ev["t"],
                }
            s = spans.get(rid)
            if s is None:
                continue                    # submit edge evicted: skip
            if kind == "admit":
                s.update(admit_step=ev["step"], admit_t=ev["t"],
                         slot=ev["slot"],
                         prefix_matched=ev["prefix_matched"])
            elif kind == "prefill":
                s.update(prefill_tokens=ev["n_tokens"],
                         prefill_padded=ev["n_padded"],
                         suffix_prefill=ev["suffix"])
            elif kind == "first_token":
                s.update(first_token_step=ev["step"], first_token_t=ev["t"])
            elif kind == "finish":
                s.update(finish_step=ev["step"], finish_t=ev["t"],
                         tokens=ev["n_generated"])
            elif kind == "req_tier":
                # tier transitions in admission order: [admit tier, ...]
                s.setdefault("tiers", []).append(ev["tier"])
            elif kind == "shed":
                s.update(shed_step=ev["step"], shed_t=ev["t"],
                         shed_reason=ev["reason"],
                         tokens=ev["n_generated"])
            elif kind == "failover":
                s.update(failover_step=ev["step"],
                         failover_from=ev["src_replica"])
        for s in spans.values():
            if "admit_step" in s:
                s["queue_steps"] = s["admit_step"] - s["arrival_step"]
                s["queue_s"] = s["admit_t"] - s["submit_t"]
            if "first_token_step" in s:
                s["ttft_steps"] = s["first_token_step"] - s["arrival_step"]
                s["ttft_s"] = s["first_token_t"] - s["submit_t"]
            if "finish_step" in s:
                s["latency_steps"] = s["finish_step"] - s["arrival_step"]
                s["latency_s"] = s["finish_t"] - s["submit_t"]
                if "first_token_step" in s:
                    s["decode_steps"] = s["finish_step"] \
                        - s["first_token_step"]
        return spans

    def format_timeline(self, rid: int) -> str:
        """Human-readable one-request timeline (examples/serve_traced)."""
        s = self.request_spans().get(rid)
        if s is None:
            return f"req{rid}: no events retained"
        lines = [f"req{rid} (replica {s['replica']}, "
                 f"slot {s.get('slot', '?')}, "
                 f"prompt {s['n_prompt']} toks, "
                 f"prefix matched {s.get('prefix_matched', 0)}):"]
        for label, step_k, wall_k in (
                ("queue  (submit -> admit)", "queue_steps", "queue_s"),
                ("ttft   (submit -> tok 0)", "ttft_steps", "ttft_s"),
                ("decode (tok 0 -> finish)", "decode_steps", None),
                ("total  (submit -> finish)", "latency_steps", "latency_s")):
            if step_k not in s:
                continue
            wall = f", {s[wall_k] * 1e3:8.2f} ms" if wall_k else ""
            lines.append(f"  {label}: {s[step_k]:4d} steps{wall}")
        if "tokens" in s:
            lines.append(f"  generated {s['tokens']} tokens")
        return "\n".join(lines)

    # -- exports ------------------------------------------------------------

    def export_jsonl(self, path: str) -> int:
        return export_jsonl([self], path)

    def export_chrome(self, path: str) -> int:
        return export_chrome([self], path)

    def export(self) -> None:
        """Write the configured default exports (TraceConfig.out/chrome)."""
        if self.cfg.out:
            self.export_jsonl(self.cfg.out)
        if self.cfg.chrome:
            self.export_chrome(self.cfg.chrome)


def _ensure_dir(path: str) -> None:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)


def export_jsonl(tracers: Sequence[Tracer], path: str) -> int:
    """All tracers' ring buffers as JSONL: one meta line per tracer, then
    its events, each stamped with the replica id. Returns events written."""
    _ensure_dir(path)
    n = 0
    with open(path, "w") as f:
        for tr in tracers:
            # fleet runs (PR 10) stamp the process index into the meta
            # line and every event; single-process output stays
            # BYTE-identical (no key at all when process is None)
            ptag = {} if getattr(tr, "process", None) is None \
                else {"process": tr.process}
            f.write(json.dumps({
                "ev": "meta", **ptag, "replica": tr.replica,
                "epoch_wall": tr.epoch_wall, "dropped": tr.dropped,
                "capacity": tr.cfg.capacity,
                "clocks": {"step": "engine steps",
                           "t": "monotonic seconds since epoch_wall"},
            }) + "\n")
            for ev in tr.events:
                f.write(json.dumps({**ptag, "replica": tr.replica, **ev})
                        + "\n")
                n += 1
    return n


_ADMIT_TID = 0          # queue spans (no slot yet)
_DISPATCH_TID = 9999    # decode/spec dispatch spans


def chrome_events(tr: Tracer) -> List[Dict[str, Any]]:
    """One tracer's events in Chrome trace-event form: pid = replica,
    tid = slot + 1 for request spans (one track per slot), the admission
    queue on tid 0, dispatches on their own track, occupancy as a counter
    series. ts/dur in microseconds on the monotonic clock."""
    proc = getattr(tr, "process", None)
    # fleet runs get a disjoint pid block per PROCESS so two processes'
    # replica 0 tracks never merge; single-process pid stays the replica
    pid = tr.replica if proc is None else proc * 4096 + tr.replica
    pname = f"replica {pid}" if proc is None \
        else f"process {proc} replica {tr.replica}"
    evs: List[Dict[str, Any]] = [
        {"ph": "M", "pid": pid, "name": "process_name",
         "args": {"name": pname}},
        {"ph": "M", "pid": pid, "tid": _ADMIT_TID, "name": "thread_name",
         "args": {"name": "admission queue"}},
        {"ph": "M", "pid": pid, "tid": _DISPATCH_TID, "name": "thread_name",
         "args": {"name": "dispatch"}},
    ]
    named_slots = set()

    def us(t: float) -> float:
        return t * 1e6

    for ev in tr.events:
        if ev["ev"] in ("decode", "spec"):
            evs.append({"ph": "X", "pid": pid, "tid": _DISPATCH_TID,
                        "name": ev["ev"], "cat": "dispatch",
                        "ts": us(ev["t"]), "dur": us(ev["dur"]),
                        "args": {"step": ev["step"], "k": ev["k"],
                                 "n_active": ev["n_active"]}})
            evs.append({"ph": "C", "pid": pid, "name": "occupancy",
                        "ts": us(ev["t"]),
                        "args": {"active": ev["n_active"]}})
        elif ev["ev"] == "host_sync":
            evs.append({"ph": "i", "pid": pid, "tid": _DISPATCH_TID,
                        "name": f"sync:{ev['kind']}", "cat": "sync",
                        "s": "t", "ts": us(ev["t"]),
                        "args": {"bytes": ev["bytes"], "step": ev["step"]}})
        elif ev["ev"] == "ledger":
            # counter tracks: activation ineffectuality + effective-FLOP
            # fraction per dispatch, next to the occupancy series
            evs.append({"ph": "C", "pid": pid, "name": "act_sparsity",
                        "ts": us(ev["t"]),
                        "args": {"zero_frac": ev["zero_frac"],
                                 "dead_kblock_frac": ev["dead_frac"]}})
            evs.append({"ph": "C", "pid": pid, "name": "effective_flops",
                        "ts": us(ev["t"]),
                        "args": {"eff_frac": ev["eff_flop_frac"]}})
        elif ev["ev"] == "quality_probe":
            evs.append({"ph": "i", "pid": pid, "tid": _DISPATCH_TID,
                        "name": f"quality:tier{ev['tier']}", "cat": "quality",
                        "s": "t", "ts": us(ev["t"]),
                        "args": {"rid": ev["rid"], "top1": ev["top1"],
                                 "mad": ev["mad"], "step": ev["step"]}})
    for s in tr.request_spans().values():
        if "admit_t" in s:
            evs.append({"ph": "X", "pid": pid, "tid": _ADMIT_TID,
                        "name": f"req{s['rid']} queued", "cat": "queue",
                        "ts": us(s["submit_t"]),
                        "dur": us(max(0.0, s["queue_s"])),
                        "args": {"queue_steps": s["queue_steps"],
                                 "arrival_step": s["arrival_step"]}})
        if "admit_t" in s and "finish_t" in s:
            tid = s["slot"] + 1
            if tid not in named_slots:
                named_slots.add(tid)
                evs.append({"ph": "M", "pid": pid, "tid": tid,
                            "name": "thread_name",
                            "args": {"name": f"slot {s['slot']}"}})
            evs.append({"ph": "X", "pid": pid, "tid": tid,
                        "name": f"req{s['rid']}", "cat": "request",
                        "ts": us(s["admit_t"]),
                        "dur": us(max(0.0, s["finish_t"] - s["admit_t"])),
                        "args": {k: s[k] for k in
                                 ("ttft_steps", "latency_steps", "tokens",
                                  "n_prompt", "prefix_matched",
                                  "arrival_step") if k in s}})
    return evs


def export_chrome(tracers: Sequence[Tracer], path: str) -> int:
    """Merged chrome://tracing JSON over any number of replica tracers
    (one process per replica). Returns the number of trace events."""
    _ensure_dir(path)
    evs: List[Dict[str, Any]] = []
    for tr in tracers:
        evs.extend(chrome_events(tr))
    with open(path, "w") as f:
        json.dump({"traceEvents": evs, "displayTimeUnit": "ms"}, f)
    return len(evs)
