"""Deterministic fault injection for the serve stack.

Recovery paths that only run during incidents are the least-tested code in
a serving system — so this harness makes incidents a reproducible test
fixture. A `ChaosHarness` wraps a `ReplicaRouter` and fires a SCHEDULE of
faults (explicit `Fault` list, or `seeded_schedule` for a reproducible
pseudo-random storm) at exact router steps, between dispatches — never
mid-dispatch, so every run with the same seed/schedule injects identically
and the recovery tests (tests/test_chaos.py) can assert exact outcomes:
every non-finished request completes on a survivor or sheds with an
explicit terminal state, pools drain to pristine, and the greedy outputs
of unaffected requests are token-identical to a fault-free run.

Fault kinds (all injected at the host/device boundary — the real seam
where a dead accelerator, an OOM, or a NaN'd kernel shows up):

  crash          the replica's decode dispatch raises permanently
                 (ReplicaFault) — the router's failover path marks it
                 dead, evacuates, optionally restarts.
  nan_logits     ONE decode sync returns out-of-vocab tokens (what an
                 argmax over NaN logits degenerates to after an int cast)
                 — exercises the engine's sync validation, which must
                 refuse to emit corrupt tokens and raise ReplicaFault.
  pool_squeeze   temporarily confiscates free pages from a paged pool —
                 admission sees PoolExhausted (pool-wait backoff / shed
                 paths) while resident requests keep decoding; pages are
                 returned at expiry.
  slow_dispatch  each decode dispatch sleeps `delay_s` for `duration`
                 steps — wall-latency degradation without logical-clock
                 drift (the step-deterministic paths are unaffected;
                 wall-deadline requests feel it).

The injectors monkeypatch bound methods on the target replica's BACKEND —
the same surface a real device fault corrupts — and restore them on
expiry. A crashed replica's patches die with it (auto_restart builds a
fresh engine).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.engine import ReplicaFault
from repro.serve.router import ReplicaRouter

FAULT_KINDS = ("crash", "nan_logits", "pool_squeeze", "slow_dispatch")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled injection: `kind` fires on replica `replica` just
    before router step `step` runs; `duration` (steps) bounds the window
    for the reversible kinds. `pages` / `delay_s` parameterize
    pool_squeeze / slow_dispatch."""

    kind: str
    step: int
    replica: int = 0
    duration: int = 1
    pages: int = 0            # pool_squeeze: free pages to confiscate
    delay_s: float = 0.0      # slow_dispatch: sleep per dispatch

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {FAULT_KINDS}")
        if self.duration < 1:
            raise ValueError(f"duration must be >= 1, got {self.duration}")


def seeded_schedule(seed: int, n_steps: int, n_replicas: int, *,
                    kinds: Sequence[str] = FAULT_KINDS,
                    rate: float = 0.05) -> Tuple[Fault, ...]:
    """Reproducible pseudo-random fault storm: same (seed, n_steps,
    n_replicas, kinds, rate) -> byte-identical schedule, every draw off
    one seeded Generator."""
    rng = np.random.default_rng(seed)
    faults: List[Fault] = []
    for step in range(2, n_steps):
        if rng.random() < rate:
            kind = kinds[int(rng.integers(len(kinds)))]
            faults.append(Fault(
                kind=kind, step=step,
                replica=int(rng.integers(n_replicas)),
                duration=int(rng.integers(1, 4)),
                pages=int(rng.integers(1, 8)),
                delay_s=float(rng.uniform(0.001, 0.01))))
    return tuple(faults)


class ChaosHarness:
    """Drive a router step-by-step, firing scheduled faults between
    dispatches. `injected` records every fault actually armed (tests
    assert against it)."""

    def __init__(self, router: ReplicaRouter,
                 faults: Sequence[Fault]) -> None:
        self.router = router
        self.faults = sorted(faults, key=lambda f: (f.step, f.replica))
        self.injected: List[Fault] = []
        self._active: List[Tuple[Fault, Callable[[], None]]] = []

    def step(self) -> None:
        """Expire elapsed faults, arm the ones due, then one router step."""
        upcoming = self.router.step_count + 1
        for entry in list(self._active):
            f, undo = entry
            if f.step + f.duration <= upcoming:
                undo()
                self._active.remove(entry)
        for f in self.faults:
            if f.step == upcoming:
                undo = self._inject(f)
                self.injected.append(f)
                if undo is not None:
                    self._active.append((f, undo))
        self.router.step()

    def run(self, max_steps: Optional[int] = None):
        """router.run(), but through the fault clock; restores any still-
        active reversible fault afterwards so pool invariants can be
        asserted on the drained fleet."""
        rt = self.router
        limit = max_steps if max_steps is not None else \
            10 * sum(r.max_new_tokens + 2 for r in rt.requests) \
            + max([r.arrival_step for r in rt.requests], default=0) \
            + 10 * len(self.faults) + 10
        try:
            while rt.n_waiting or rt.n_active:
                if not any(rt.alive):
                    raise RuntimeError(
                        "every replica is dead with work remaining — "
                        "enable auto_restart or shrink the schedule")
                if limit <= 0:
                    raise RuntimeError(
                        "chaos run did not drain within the step limit")
                self.step()
                limit -= 1
        finally:
            for _, undo in self._active:
                undo()
            self._active.clear()
        return {i: list(r.generated) for i, r in enumerate(rt.requests)}

    # -------------------------------------------------------------- injectors

    def _inject(self, f: Fault) -> Optional[Callable[[], None]]:
        eng = self.router.replicas[f.replica]
        be = eng.backend
        if f.kind == "crash":
            def raiser(*a, **k):
                raise ReplicaFault(
                    f"chaos: injected crash (replica {f.replica}, "
                    f"step {f.step})")
            be.decode_block = raiser
            be.spec_decode_block = raiser
            return None      # permanent: the patched backend dies with
            #                  the replica (failover/restart replaces it)

        if f.kind == "nan_logits":
            # one-shot: the NEXT sync returns out-of-vocab tokens, exactly
            # what `int32(argmax(NaN logits))` degenerates to; the engine's
            # validation must catch it BEFORE any emission side effect
            if eng.cfg.speculate:
                orig = be.spec_decode_block
                k, b = eng.cfg.speculate, eng.cfg.n_slots

                def bad_spec():
                    be.spec_decode_block = orig
                    return (np.full((b, k + 1), -1, np.int32),
                            np.full((b,), k + 1, np.int32),
                            np.zeros((b,), np.int32))
                be.spec_decode_block = bad_spec
            else:
                orig = be.decode_block
                k, b = eng.cfg.decode_chunk, eng.cfg.n_slots

                def bad_block():
                    be.decode_block = orig
                    return np.full((k, b), -1, np.int32)
                be.decode_block = bad_block
            return None      # self-restoring after one sync

        if f.kind == "pool_squeeze":
            pool = eng.pool
            if not hasattr(pool, "_free_pages"):
                raise ValueError(
                    "pool_squeeze targets a paged pool "
                    "(EngineConfig.page_size); replica "
                    f"{f.replica} runs a slab")
            n = min(f.pages or len(pool._free_pages),
                    len(pool._free_pages))
            taken = [pool._free_pages.pop() for _ in range(n)]

            def undo_squeeze():
                pool._free_pages.extend(reversed(taken))
            return undo_squeeze

        if f.kind == "slow_dispatch":
            orig = be.decode_block

            def slow(*a, **k):
                time.sleep(f.delay_s)
                return orig(*a, **k)
            be.decode_block = slow

            def undo_slow():
                be.decode_block = orig
            return undo_slow

        raise AssertionError(f.kind)     # Fault.__post_init__ guards this
