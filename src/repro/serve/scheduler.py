"""Admission scheduling: when does a waiting request get a cache slot?

The engine calls `scheduler.admissible(...)` once per step, BEFORE the slab
decode. Both policies consume the arrived-FIFO in order; they differ only in
when they are willing to admit:

  ContinuousScheduler   admit whenever a slot is free — a finishing request
                        frees its slot and the next arrival joins the very
                        next decode step. Mixed-length traffic keeps the
                        slab full (high occupancy == high tok/step).

  StaticScheduler       the lock-step baseline: admit only when the engine
                        is EMPTY, i.e. compose a batch, run it to
                        completion, then compose the next. Short requests
                        finish early and their slots idle until the longest
                        member of the batch drains — the occupancy loss the
                        continuous policy exists to remove.

Prefill/decode interleaving policy: `max_prefills_per_step` bounds how many
admissions (each one a prefill) may happen before a decode step — new
arrivals must not starve in-flight decodes (head-of-line blocking the other
way). The default of 1 interleaves one prefill between decode steps, the
standard continuous-batching compromise.

Contract with the engine: `admissible` returns a SUBSET of `arrived` in
arrival order and never mutates it; the engine removes the admitted set from
its waiting deque in one pass (no per-request deque.remove). With the
multi-step device loop (EngineConfig.decode_chunk=K) the admission clock
ticks once per K-token decode block, so `max_prefills_per_step` bounds
prefills per BLOCK — the knob's meaning scales with K.

Paged pools add a second admission resource the schedulers do NOT see:
`admissible` gates on free SLOTS, but a paged engine (EngineConfig
.page_size, serve.paging) may then fail the page allocation with
`PoolExhausted` — free slots, not enough free pages even after LRU prefix
eviction. The engine absorbs that by requeueing the admission at the front
of the waiting deque (metrics `pool_waits`), so a scheduler-admitted
request degrades to "retry next step", never to a crashed step.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request and its engine-managed lifecycle state."""

    id: int
    prompt: np.ndarray                      # (S0,) int32 token ids
    max_new_tokens: int
    arrival_step: int = 0                   # simulated-trace admission gate
    temperature: float = 0.0                # 0 => greedy
    eos_id: Optional[int] = None
    extras: Optional[Dict[str, Any]] = None  # frames / img_embeds (B=1 lead)
    on_token: Optional[Callable[["Request", int], None]] = None  # streaming
    # per-request speculation cap: max draft tokens acceptable per dispatch
    # on a speculating engine (None = the engine's K; 0 = opt out — the slot
    # runs exactly one plain target step per cycle). No effect when the
    # engine isn't speculating.
    speculate: Optional[int] = None
    # QoS / deadlines (serve.qos): `deadline_steps` is RELATIVE to
    # arrival_step on the deterministic engine-step clock (the clock tests
    # and benches gate on); `deadline_ms` is a wall-clock bound from submit
    # (perf_counter). Either expiring sheds the request — at admission if
    # it is already doomed (cannot finish in the remaining budget), or
    # mid-flight with its slot/pages freed. None = no deadline. `slo` is a
    # free-form class label carried into spans/records.
    deadline_steps: Optional[int] = None
    deadline_ms: Optional[float] = None
    slo: str = ""

    # engine-managed
    state: str = "waiting"                  # waiting | running | done | shed
    slot: int = -1
    index: int = 0                          # next cache write position
    generated: List[int] = dataclasses.field(default_factory=list)
    # paged engines: prompt tokens whose prefill was skipped because their
    # KV came from shared prefix pages (serve.paging) — 0 on a miss/slab
    prefix_matched: int = 0
    # terminal disposition detail when state == "shed":
    # 'deadline' | 'pool' | 'failover' | 'cancel'
    shed_reason: str = ""
    # cheapest (highest) engine tier this request ever decoded on — tier 0
    # unless a QoS demotion happened while it was resident
    tier: int = 0
    # PoolExhausted backoff (EngineConfig.pool_wait_retries): requeue count
    # and the earliest step the engine may retry the admission
    pool_retries: int = 0
    retry_at_step: int = 0
    # set by ReplicaRouter._fail on evacuation; the adopting engine counts
    # metrics.on_failover() once and clears it
    failover_from: int = -1

    @property
    def done(self) -> bool:
        return self.state == "done"

    @property
    def finished(self) -> bool:
        """Terminal either way: completed ('done') or shed ('shed')."""
        return self.state in ("done", "shed")

    def deadline_step(self) -> Optional[int]:
        """Absolute step-clock deadline, or None."""
        if self.deadline_steps is None:
            return None
        return self.arrival_step + self.deadline_steps


def replica_load(n_active: int, n_free: int, n_waiting: int) -> int:
    """The admission-side load signal shared by the schedulers and the
    replica router (serve.router): committed work minus immediately
    available capacity. A replica with free slots and an empty queue scores
    negative (it can admit NOW); one with a backed-up deque scores by its
    queue depth. The router picks the minimum — least-loaded/deficit
    admission from the same quantities `admissible()` already consumes."""
    return n_active + n_waiting - n_free


class SchedulerBase:
    name = "base"

    def admissible(self, arrived: List[Request], n_active: int,
                   n_free: int) -> List[Request]:
        raise NotImplementedError


class ContinuousScheduler(SchedulerBase):
    name = "continuous"

    def __init__(self, max_prefills_per_step: int = 1):
        self.max_prefills_per_step = max_prefills_per_step

    def admissible(self, arrived: List[Request], n_active: int,
                   n_free: int) -> List[Request]:
        n = min(len(arrived), n_free, self.max_prefills_per_step)
        return arrived[:n]


class StaticScheduler(SchedulerBase):
    name = "static"

    def admissible(self, arrived: List[Request], n_active: int,
                   n_free: int) -> List[Request]:
        if n_active > 0:
            return []                       # drain before refilling
        return arrived[:n_free]
