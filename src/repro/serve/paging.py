"""Block-paged KV pool with radix-tree prefix reuse (serve.prefix).

The slab (serve.cache_pool) reserves one `max_len` stride per slot, so slot
count is `mem / max_len` no matter how short requests actually are, and
every admission prefills its whole prompt even when the prompt's prefix is
already resident in another slot. This pool carves the SAME preallocated
memory into fixed-size PAGES instead:

  * every cache leaf with a positional sequence axis is stored PAGE-MAJOR —
    the page axis sits exactly where the slab's slot axis sat (so the
    layer-stacked 'blocks' leaves keep their leading scan axis and shard
    page-over-data the way slots did: `page_pspecs`), and the sequence
    axis shrinks to `page_size`;
  * each slot owns an int32 row of a `(n_slots, pages_per_slot)` PAGE TABLE
    mapping logical position `p` to physical page `table[slot, p // P]`;
  * page alloc/free is O(1) free-list bookkeeping with REFCOUNTS — a page
    shared by `k` slots (and/or retained by the prefix index) frees only
    when the last reference drops;
  * decode consumes the table NATIVELY: the attention layers read K/V
    straight through the page table (kernels.ops.paged_attention — the
    Pallas kernel's BlockSpec index map translates (slot, kv-block) ->
    page id via scalar prefetch) and write new tokens with in-place
    page-indexed scatters, so no per-dispatch slab materialization exists
    (distributed.steps.make_paged_decode_step). The legacy gather/scatter
    wrap survives behind `native=False` for A/B testing; `GATHER_EVENTS`
    records every gather/scatter trace so tests can assert the hot path
    stays gather-free.

Leaf classification (PageLayout): a leaf is PAGED when its second-to-last
axis is the `cache_len` positional sequence axis — full-window attention
k/v, MLA `c_kv`/`k_rope`. Everything else is RESIDENT and keeps the
slot-major slab layout inside the same store: recurrent SSM `conv`/`ssm`
state (O(1) per slot — nothing to page), `cross` encoder caches (written
once at prefill), and circular sliding-window leaves (size W < cache_len;
their position->slot map wraps, so page identity is not position identity).

Page 0 is the reserved WRITE SINK: freed slots' table rows reset to it, so
an idle slot's garbage decode writes land in a page nobody reads (under
the slab they landed in the freed slot's own row) instead of corrupting a
page that was recycled to a live slot or retained by the prefix index.
Rows past a slot's allocated length also point at page 0; the per-slot
validity masks keep those positions inert exactly as they keep the slab's
unwritten tail inert.

Prefix reuse: `prefix_match` returns the longest PAGE-ALIGNED cached
prefix of a prompt (capped at prompt_len - 1 so at least one suffix token
remains to produce the first-sample logits); admission bumps the shared
pages' refcounts (`alloc_pages`), prefills only the suffix through the
existing s>1 decode-form block write (steps.make_suffix_prefill_step), and
publishes the request's full-prompt pages into the radix tree
(`prefix_insert`). At request FINISH the engine additionally publishes the
whole conversation — prompt + generated tokens — via
`conversation_insert`, so a multi-turn follow-up (new prompt = old
conversation + new user text) skips prefill over everything said so far,
not just the shared system prompt. Sharing needs no copy-on-write copy:
only pages with COMPLETE, final KV are ever published (full prompt pages
at admission; conversation pages up to the last token whose KV decode
actually wrote), so a sharer's first own write lands strictly past the
shared region, and speculative write-headroom pages are private by the
same argument. Under page pressure, allocation first evicts LRU
tree pages nobody else references; if that still doesn't cover the
request, `PoolExhausted` surfaces to the scheduler (the engine requeues
the admission) instead of crashing the step.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.instrument import REGISTRY
from repro.models import transformer as T
from repro.serve.cache_pool import PoolExhausted, quiet_donation
from repro.serve.prefix import PrefixIndex
from repro.serve.trace import NULL_TRACER

# (op, n_paged_leaves, slab_view_bytes) appended at TRACE time whenever a
# full gather/scatter materializes the slab view — the paged analogue of
# kernels.pallas_compat.SKINNY_M_EVENTS. Native paged decode must trace
# ZERO of these; tests and serve_bench assert it. Registry-backed
# (repro.instrument.REGISTRY, stream "gather") with scoped reset; the
# historical name aliases the same list. (gather_one/scatter_one —
# admission-path slot installs — do not count: they are off the decode hot
# path by design.)
GATHER_EVENTS = REGISTRY.event_list("gather")


def prefix_supported(cfg: T.ModelConfig) -> bool:
    """Archs whose WHOLE per-request cache state is positional and paged —
    the precondition for prefix sharing to reproduce a prefill exactly.
    Recurrent state (SSM/hybrid) is not positional, circular windows
    overwrite position identity, enc-dec/vision prompts carry non-token
    conditioning the token-ID radix key cannot see."""
    return not (cfg.is_ssm or cfg.attn_period or cfg.enc_dec
                or cfg.n_img_tokens or cfg.window or cfg.frontend)


@dataclasses.dataclass(frozen=True)
class _LeafSpec:
    name: str            # dotted path, for describe()/dry-run printing
    paged: bool
    batch_axis: int      # 0 prelude leaves, 1 layer-stacked 'blocks' leaves


class PageLayout:
    """Leaf classification + gather/scatter between page store and slab.

    The store is the flat leaf list of `T.make_caches(cfg, n_slots,
    cache_len)` with every PAGED leaf re-laid out page-major: slab
    `(..., B at batch_axis, ..., cache_len, d)` becomes
    `(..., n_pages at batch_axis, ..., page_size, d)` — the page axis
    REPLACES the slot axis in place (a page belongs to whichever slots
    reference it), which keeps the layer-stacked 'blocks' leaves' leading
    scan axis where `T.forward`'s lax.scan expects it, so the native paged
    decode can hand store leaves straight to the attention layers.
    RESIDENT leaves keep the slab layout. `gather` rebuilds the exact slab
    tree (view sliced to `cache_len`, bit-identical to the slab rows on
    every valid position); `scatter` splits the view back into pages
    (zero-padding the final partial page, which is private by construction
    — see module docstring). Both are now the LEGACY path (native decode
    reads through the table instead) and trace into `GATHER_EVENTS`.
    """

    def __init__(self, cfg: T.ModelConfig, n_slots: int, cache_len: int,
                 page_size: int, dtype=jnp.float32):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.cfg, self.n_slots = cfg, n_slots
        self.cache_len, self.page_size = cache_len, page_size
        self.pp = -(-cache_len // page_size)          # pages per slot
        template = jax.eval_shape(
            lambda: T.make_caches(cfg, n_slots, cache_len, dtype))
        flat, self.treedef = jax.tree_util.tree_flatten_with_path(template)
        self.slab_shapes = [leaf.shape for _, leaf in flat]
        self.dtypes = [leaf.dtype for _, leaf in flat]
        self.specs: List[_LeafSpec] = []
        for path, leaf in flat:
            names = [str(k.key) for k in path if hasattr(k, "key")]
            bax = 1 if names and names[0] == "blocks" else 0
            resident = any(n in ("conv", "ssm", "cross") for n in names)
            paged = (not resident and leaf.ndim >= 3
                     and leaf.shape[-2] == cache_len)
            self.specs.append(_LeafSpec(".".join(names), paged, bax))
        self.has_paged = any(s.paged for s in self.specs)

    # ------------------------------------------------------------- shapes

    def store_shapes(self, n_pages: int) -> List[Tuple[int, ...]]:
        out = []
        for shape, spec in zip(self.slab_shapes, self.specs):
            if not spec.paged:
                out.append(tuple(shape))
                continue
            shp = list(shape)
            shp[spec.batch_axis] = n_pages   # page axis replaces slot axis
            shp[-2] = self.page_size
            out.append(tuple(shp))
        return out

    def make_store(self, n_pages: int) -> List[jnp.ndarray]:
        return [jnp.zeros(s, d)
                for s, d in zip(self.store_shapes(n_pages), self.dtypes)]

    def slab_view_bytes(self) -> int:
        """Bytes of the full slab view a gather materializes (paged leaves
        only) — the per-direction cost the native path avoids."""
        return sum(int(np.prod(shape)) * jnp.dtype(dt).itemsize
                   for shape, dt, spec in zip(self.slab_shapes, self.dtypes,
                                              self.specs) if spec.paged)

    # ------------------------------------------------------ gather/scatter

    def _gather_leaf(self, store_leaf, table, spec: _LeafSpec):
        bax = spec.batch_axis
        idx = (slice(None),) * bax + (table,)
        g = store_leaf[idx]                       # (..., B, pp, ..., P, d)
        g = jnp.moveaxis(g, bax + 1, -3)          # (..., B, ..., pp, P, d)
        g = g.reshape(*g.shape[:-3], g.shape[-3] * g.shape[-2], g.shape[-1])
        return jax.lax.slice_in_dim(g, 0, self.cache_len, axis=-2)

    def _scatter_leaf(self, store_leaf, table, slab_leaf, spec: _LeafSpec):
        bax = spec.batch_axis
        x = slab_leaf
        pad = self.pp * self.page_size - self.cache_len
        if pad:   # final partial page: private by construction (docstring)
            x = jnp.concatenate(
                [x, jnp.zeros((*x.shape[:-2], pad, x.shape[-1]), x.dtype)],
                axis=-2)
        x = x.reshape(*x.shape[:-2], self.pp, self.page_size, x.shape[-1])
        x = jnp.moveaxis(x, -3, bax + 1)          # (..., B, pp, ..., P, d)
        idx = (slice(None),) * bax + (table,)
        return store_leaf.at[idx].set(x.astype(store_leaf.dtype))

    def gather(self, store: List[jnp.ndarray], page_table) -> Dict:
        """Page store + (n_slots, pp) table -> the full slab cache tree.

        LEGACY path (steps' native=False A/B form): traces a GATHER_EVENTS
        entry so hot-path tests can prove native decode never calls it."""
        GATHER_EVENTS.append(("gather", sum(s.paged for s in self.specs),
                              self.slab_view_bytes()))
        out = [leaf if not spec.paged
               else self._gather_leaf(leaf, page_table, spec)
               for leaf, spec in zip(store, self.specs)]
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def scatter(self, store, page_table, caches) -> List[jnp.ndarray]:
        """Slab cache tree -> page store (resident leaves adopt the
        forward's functional update; paged leaves scatter into their
        pages — shared pages receive back the identical values they
        contributed, private pages the new writes). LEGACY path; traces
        a GATHER_EVENTS entry like `gather`."""
        GATHER_EVENTS.append(("scatter", sum(s.paged for s in self.specs),
                              self.slab_view_bytes()))
        leaves = jax.tree_util.tree_leaves(caches)
        return [leaf if not spec.paged
                else self._scatter_leaf(sl, page_table, leaf, spec)
                for sl, leaf, spec in zip(store, leaves, self.specs)]

    # ----------------------------------------------------- native (no copy)

    def as_tree(self, store: List[jnp.ndarray]) -> Dict:
        """Zero-cost cache-tree view of the page store for the NATIVE paged
        forward: same treedef as the slab tree (the page axis sits exactly
        where the slot axis sat), paged leaves ARE the store leaves. The
        attention layers detect the paged leaves via the `pages` operand
        and read/write them through the table."""
        return jax.tree_util.tree_unflatten(self.treedef, list(store))

    def from_tree(self, caches: Dict) -> List[jnp.ndarray]:
        """Inverse of `as_tree` (flat store leaf list, functional updates
        from the forward included)."""
        return list(jax.tree_util.tree_leaves(caches))

    def gather_one(self, store, table_row, slot) -> Dict:
        """Batch-1 view of one slot (suffix prefill / slot install)."""
        out = []
        for leaf, spec in zip(store, self.specs):
            if spec.paged:
                out.append(self._gather_leaf(leaf, table_row[None], spec))
            else:
                out.append(jax.lax.dynamic_slice_in_dim(
                    leaf, slot, 1, axis=spec.batch_axis))
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def scatter_one(self, store, table_row, slot, caches):
        leaves = jax.tree_util.tree_leaves(caches)
        out = []
        for sl, leaf, spec in zip(store, leaves, self.specs):
            if spec.paged:
                out.append(self._scatter_leaf(sl, table_row[None], leaf,
                                              spec))
            else:
                out.append(jax.lax.dynamic_update_slice_in_dim(
                    sl, leaf.astype(sl.dtype), slot, axis=spec.batch_axis))
        return out


def _install_one(layout: PageLayout):
    """(store, single, page_table, slot) -> store: slot install, jittable
    with (store, single) donated — the paged analogue of CachePool._write."""
    def install(store, single, page_table, slot):
        row = jax.lax.dynamic_index_in_dim(page_table, slot, axis=0,
                                           keepdims=False)
        return layout.scatter_one(store, row, slot, single)
    return install


def _set_row(page_table, slot, row):
    return page_table.at[slot].set(row)


class PagedCachePool:
    """Fixed-page KV pool: refcounted pages + per-slot page tables.

    Drop-in for `CachePool` behind the execution backends (same
    alloc/free/n_free/n_active/write_slot surface) plus the paging and
    prefix-reuse surface the engine's admission path drives:
    `prefix_match` -> `alloc_pages` -> (suffix) prefill -> `prefix_insert`.
    `max_len` counts cache positions per slot INCLUDING any speculative
    write headroom, exactly like CachePool.
    """

    # re-pointed at the engine's Tracer when tracing is on (page alloc/
    # free/evict events); admission-path only, never the decode hot path
    tracer = NULL_TRACER

    def __init__(self, cfg: T.ModelConfig, n_slots: int, max_len: int,
                 dtype=jnp.float32, *, page_size: int,
                 n_pages: Optional[int] = None, prefix_cache: bool = True,
                 mesh=None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.cfg, self.n_slots = cfg, n_slots
        self.max_len, self.dtype, self.mesh = max_len, dtype, mesh
        self.layout = PageLayout(cfg, n_slots, max_len, page_size, dtype)
        self.page_size, self.pp = page_size, self.layout.pp
        # +1: page 0 is the reserved write sink, never allocated
        self.n_pages = n_pages if n_pages is not None \
            else n_slots * self.pp + 1
        if self.layout.has_paged and self.n_pages < self.pp + 1:
            raise ValueError(
                f"n_pages={self.n_pages} cannot hold even one full slot "
                f"({self.pp} pages) plus the reserved sink page")
        self.store = self.layout.make_store(self.n_pages)
        self.page_table = jnp.zeros((n_slots, self.pp), jnp.int32)
        self._table = np.zeros((n_slots, self.pp), np.int32)
        self.refs = np.zeros(self.n_pages, np.int32)
        self.refs[0] = 1                       # the sink is never freeable
        self._free_pages: List[int] = list(range(self.n_pages - 1, 0, -1))
        self._slot_pages: List[List[int]] = [[] for _ in range(n_slots)]
        self._free_slots: List[int] = list(range(n_slots - 1, -1, -1))
        self.index = PrefixIndex(page_size) \
            if (prefix_cache and self.layout.has_paged
                and prefix_supported(cfg)) else None
        self.shardings = None
        self.table_sharding = None
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            from repro.distributed import sharding as SH
            pspecs = SH.page_pspecs(
                jax.eval_shape(lambda: T.make_caches(cfg, n_slots, max_len,
                                                     dtype)),
                self.layout, mesh, self.n_pages)
            self.shardings = [NamedSharding(mesh, s) for s in pspecs]
            self.store = jax.device_put(self.store, self.shardings)
            slot_spec = SH.batch_pspec(mesh, n_slots)
            self.table_sharding = NamedSharding(
                mesh, P(*(tuple(slot_spec) + (None,))))
            self.page_table = jax.device_put(self.page_table,
                                             self.table_sharding)
            self._write = jax.jit(_install_one(self.layout),
                                  donate_argnums=(0, 1),
                                  out_shardings=self.shardings)
            self._set = jax.jit(_set_row, donate_argnums=(0,),
                                out_shardings=self.table_sharding)
        else:
            self._write = jax.jit(_install_one(self.layout),
                                  donate_argnums=(0, 1))
            self._set = jax.jit(_set_row, donate_argnums=(0,))

    # -------------------------------------------------------------- slots

    @property
    def n_free(self) -> int:
        return len(self._free_slots)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free_slots)

    def alloc(self) -> int:
        if not self._free_slots:
            raise PoolExhausted(
                f"all {self.n_slots} cache slots in use; admission must wait")
        return self._free_slots.pop()

    def free(self, slot: int) -> None:
        """Release a slot AND its page references. Pages retained by the
        prefix index survive (refcount >= 1); private suffix/headroom pages
        return to the free list. The slot's table row resets to the sink
        page so its stale decode writes can never touch a recycled page."""
        if not (0 <= slot < self.n_slots):
            raise ValueError(f"slot {slot} out of range [0, {self.n_slots})")
        if slot in self._free_slots:
            raise ValueError(f"double-free of slot {slot}")
        self.tracer.page_free(slot, len(self._slot_pages[slot]))
        for p in self._slot_pages[slot]:
            self._release(p)
        self._slot_pages[slot] = []
        self._table[slot] = 0
        with quiet_donation():
            self.page_table = self._set(
                self.page_table, jnp.asarray(slot, jnp.int32),
                jnp.zeros((self.pp,), jnp.int32))
        self._free_slots.append(slot)

    # -------------------------------------------------------------- pages

    def _retain(self, page: int) -> None:
        self.refs[page] += 1

    def _release(self, page: int) -> None:
        self.refs[page] -= 1
        assert self.refs[page] >= 0, f"refcount underflow on page {page}"
        if self.refs[page] == 0:
            self._free_pages.append(page)

    def pages_needed(self, n_positions: int) -> int:
        if not self.layout.has_paged:
            return 0
        return -(-min(n_positions, self.max_len) // self.page_size)

    @property
    def n_usable_pages(self) -> int:
        return self.n_pages - 1

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - 1 - len(self._free_pages)

    def page_stats(self) -> Tuple[int, int]:
        return self.pages_in_use, self.n_usable_pages

    def alloc_pages(self, slot: int, n_positions: int,
                    shared: Sequence[int] = ()) -> None:
        """Install a slot's page-table row: `shared` prefix pages (refcount
        bump — from `prefix_match`, which must be called in the same
        admission, before any eviction can run) followed by fresh private
        pages covering `n_positions`. Under pressure, LRU tree-only pages
        are evicted first; a request the pool still cannot hold raises
        `PoolExhausted` with every refcount restored."""
        need = self.pages_needed(n_positions)
        shared = list(shared)
        assert len(shared) <= need, (len(shared), need)
        for p in shared:
            self._retain(p)     # before eviction: a matched page is pinned
        n_new = need - len(shared)
        if n_new > len(self._free_pages) and self.index is not None:
            free_before = len(self._free_pages)
            self.index.evict(n_new - len(self._free_pages),
                             can_free=lambda p: self.refs[p] == 1,
                             release=self._release)
            freed = len(self._free_pages) - free_before
            if freed:
                self.tracer.page_evict(freed)
        if n_new > len(self._free_pages):
            for p in shared:
                self._release(p)
            raise PoolExhausted(
                f"{n_new} pages needed, {len(self._free_pages)} free "
                f"(of {self.n_usable_pages}); admission must wait")
        fresh = [self._free_pages.pop() for _ in range(n_new)]
        for p in fresh:
            self.refs[p] = 1
        self.tracer.page_alloc(slot, len(shared), n_new)
        pages = shared + fresh
        self._slot_pages[slot] = pages
        row = np.zeros((self.pp,), np.int32)
        row[:len(pages)] = pages
        self._table[slot] = row
        with quiet_donation():
            self.page_table = self._set(self.page_table,
                                        jnp.asarray(slot, jnp.int32),
                                        jnp.asarray(row))

    # ------------------------------------------------------------- prefix

    def prefix_match(self, tokens) -> Tuple[int, List[int], bool]:
        """(matched token count, shared page ids, conversation hit) for the
        longest cached page-aligned prefix — capped at len(tokens) - 1 so
        the suffix prefill always has at least one token to produce logits
        from. The third element is True when the match reached pages
        published at a request FINISH (whole-conversation reuse)."""
        if self.index is None:
            return 0, [], False
        pages, conversation = self.index.match(tokens)
        cap = max(0, (len(tokens) - 1) // self.page_size)
        pages = pages[:cap]
        return len(pages) * self.page_size, pages, conversation and \
            bool(pages)

    def prefix_insert(self, tokens, slot: int) -> int:
        """Publish the slot's FULL prompt pages (never the partial tail —
        it will receive this request's generated tokens) into the tree."""
        if self.index is None:
            return 0
        n_full = len(tokens) // self.page_size
        return self.index.insert(tokens, self._slot_pages[slot][:n_full],
                                 retain=self._retain)

    def conversation_insert(self, tokens, slot: int) -> int:
        """Publish a FINISHED request's whole conversation (prompt +
        generated tokens) so a follow-up turn skips prefill over all of it.

        Only pages with complete KV coverage publish: decode never writes
        KV for the final emitted token (it is sampled, not fed back), so
        valid KV ends at len(tokens) - 2 and the publishable page count is
        (len(tokens) - 1) // page_size. Rolled-back speculative writes and
        post-finish garbage all land at positions >= len(tokens) - 1 —
        strictly past every published page."""
        if self.index is None:
            return 0
        n_full = max(0, (len(tokens) - 1) // self.page_size)
        return self.index.insert(tokens, self._slot_pages[slot][:n_full],
                                 retain=self._retain, generated=True)

    # ------------------------------------------------------------ install

    def write_slot(self, slot: int, single: Dict) -> None:
        """Scatter a prefilled batch-1 cache view into the slot's pages
        (and its resident rows). Shared prefix pages receive back the
        values they themselves supplied to the view — a value-level no-op."""
        with quiet_donation():
            self.store = self._write(self.store, single, self.page_table,
                                     jnp.asarray(slot, jnp.int32))

    # ------------------------------------------------------ introspection

    def gather_bytes_per_dispatch(self) -> int:
        """Bytes a legacy gather+scatter dispatch would have materialized —
        what the native page-table-reading decode avoids, per dispatch.
        Static in the layout (host-computed, no device sync)."""
        if not self.layout.has_paged:
            return 0
        return 2 * self.layout.slab_view_bytes()

    def bytes(self) -> int:
        return sum(l.size * l.dtype.itemsize for l in self.store) \
            + self.page_table.size * self.page_table.dtype.itemsize

    def describe(self) -> Dict[str, Any]:
        return {
            "page_size": self.page_size,
            "pages_per_slot": self.pp,
            "n_pages": self.n_pages,
            "usable_pages": self.n_usable_pages,
            "pages_in_use": self.pages_in_use,
            "prefix_cache": self.index is not None,
            "prefix_nodes": self.index.n_nodes if self.index else 0,
            "bytes": self.bytes(),
            "paged_leaves": sum(s.paged for s in self.specs_list()),
            "resident_leaves": sum(not s.paged for s in self.specs_list()),
        }

    def specs_list(self) -> List[_LeafSpec]:
        return self.layout.specs
