"""Serving metrics: throughput, latency percentiles, batch occupancy.

Everything is recorded in two clocks:

  * wall seconds — what an operator sees (includes jit compiles, host
    sampling, python overhead);
  * engine steps — the deterministic clock the scheduler runs on (one slab
    decode micro-step per step). Step-based numbers are what benchmarks
    compare across scheduling policies, since they are immune to
    compile-time noise.

Device-loop accounting (PR 2): `decode_steps` counts DISPATCHES (one
compiled call, K micro-steps in multi-step mode), so tokens_per_step is
"tokens per launched step" — the quantity the device-resident loop improves.
`on_host_sync` counts host<->device crossings on the serving path, split by
kind: the legacy host loop costs 3 per step (logits pull + token and index
uploads); the fused loop costs 1 per K-step dispatch (the (K, B) int32 token
block). `host_syncs_per_token` in the report divides decode-kind syncs by
DECODED tokens (tokens_generated minus the per-request first tokens, which
come from prefill).

Clock discipline: every INTERVAL (wall elapsed, request latency, TTFT) is
measured on `time.perf_counter()` — monotonic, immune to NTP slews and
clock jumps. The per-request `submit_time` wall timestamp (`time.time()`)
is kept purely as a human-readable log anchor and is never subtracted.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional


def percentile(values: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) without numpy dependence."""
    if not values:
        return float("nan")
    xs = sorted(values)
    rank = max(0, min(len(xs) - 1, int(round(q / 100.0 * (len(xs) - 1)))))
    return float(xs[rank])


@dataclasses.dataclass
class RequestRecord:
    request_id: int
    arrival_step: int
    start_step: int = -1            # step the request entered a slot
    first_token_step: int = -1
    finish_step: int = -1
    n_prompt: int = 0
    n_generated: int = 0
    submit_time: float = 0.0        # wall clock, for logs only (never
    #                                 subtracted — see module docstring)
    first_token_time: float = 0.0   # monotonic (perf_counter)
    finish_time: float = 0.0        # monotonic (perf_counter)
    submit_mono: float = 0.0        # monotonic submit: interval baseline


class ServeMetrics:
    """Engine-side counters; one instance per engine run."""

    def __init__(self) -> None:
        self.t0 = time.perf_counter()     # monotonic: intervals only
        self.decode_steps = 0                 # dispatches (K micro-steps each)
        self.micro_steps = 0                  # slab forwards actually run
        self.idle_steps = 0
        self.prefills = 0
        self.tokens_generated = 0
        self.rejected = 0                     # bounded-deque submit rejections
        self.host_syncs: Dict[str, int] = {"decode": 0, "prefill": 0}
        self.occupancy: List[float] = []      # active / n_slots per dispatch
        self.records: Dict[int, RequestRecord] = {}
        # speculative decode (serve.speculative)
        self.spec_dispatches = 0              # propose-then-verify cycles
        self.draft_proposed = 0               # draft tokens offered to verify
        self.draft_accepted = 0               # ... accepted by the target
        self.draft_flop_fraction = 0.0        # static draft/target FLOP ratio
        self.slot_acceptance: Dict[int, List[int]] = {}  # slot: [acc, prop]
        # paged KV + prefix reuse (serve.paging)
        self.prefix_lookups = 0               # paged admissions
        self.prefix_hits = 0                  # ... that matched a prefix
        self.prefill_tokens_skipped = 0       # prompt tokens never prefilled
        self.prefill_tokens_computed = 0      # prompt tokens prefilled
        self.pool_waits = 0                   # admissions requeued on pages
        self.page_samples: List[int] = []     # pages_in_use per dispatch
        self.page_capacity = 0                # usable pages in the pool
        # page-table-native decode (PR 8): bytes the legacy gather+scatter
        # wrap would have moved per dispatch (zero when running the legacy
        # paged path or the slab), and whole-conversation prefix reuse
        self.gather_bytes_avoided = 0         # summed across dispatches
        self.conversation_prefix_hits = 0     # admissions resuming a chat
        self.conversation_tokens_reused = 0   # ... tokens matched there
        # resilience (serve.qos / chaos / failover)
        self.tier_demotions = 0               # engine moved to a cheaper tier
        self.tier_promotions = 0              # ... back toward full quality
        self.shed = 0                         # requests given a terminal
        #                                       "shed" state (all reasons)
        self.deadline_missed = 0              # ... shed on deadline expiry
        self.shed_pool_pressure = 0           # ... shed after the
        #                                       pool_wait_retries cap
        self.failovers = 0                    # requests re-admitted HERE off
        #                                       a dead replica (destination-
        #                                       side count: sums cleanly)
        # ineffectual-work ledger (serve.ledger): exact integer counts
        # drained from the device matrix, accumulated in float64
        self.ledger_dispatches = 0            # dispatches with a drain
        self.act_probe_elems = 0.0            # activation elements probed
        self.act_zeros = 0.0                  # exact zeros among them
        self.act_near_zeros = 0.0             # |x| <= threshold
        self.act_groups = 0.0                 # histogram groups probed
        self.act_kblocks = 0.0                # k-blocks examined
        self.act_dead_kblocks = 0.0           # ... entirely near-zero
        self.flops_dense = 0.0                # dense FLOPs at probed GEMMs
        self.flops_effective = 0.0            # minus the dead-k-block share
        self.bytes_dense = 0.0
        self.bytes_effective = 0.0
        # per-tier quality probe: tier -> [n, top1 matches, mad sum]
        self.quality_probes = 0
        self.quality: Dict[int, List[float]] = {}
        # trace ring-buffer losses (the tracer's cumulative drop count,
        # mirrored here per dispatch so report()/telemetry surface it)
        self.trace_dropped = 0

    # -- recording hooks (called by the engine) -----------------------------

    def on_submit(self, request_id: int, arrival_step: int, n_prompt: int) -> None:
        self.records[request_id] = RequestRecord(
            request_id=request_id, arrival_step=arrival_step,
            n_prompt=n_prompt, submit_time=time.time(),
            submit_mono=time.perf_counter())

    def on_start(self, request_id: int, step: int) -> None:
        rec = self.records[request_id]
        rec.start_step = step
        self.prefills += 1

    def on_token(self, request_id: int, step: int) -> None:
        rec = self.records[request_id]
        if rec.first_token_step < 0:
            rec.first_token_step = step
            rec.first_token_time = time.perf_counter()
        rec.n_generated += 1
        self.tokens_generated += 1

    def on_finish(self, request_id: int, step: int) -> None:
        rec = self.records[request_id]
        rec.finish_step = step
        rec.finish_time = time.perf_counter()

    def on_decode_step(self, n_active: int, n_slots: int,
                       micro_steps: int = 1) -> None:
        self.decode_steps += 1
        self.micro_steps += micro_steps
        self.occupancy.append(n_active / max(1, n_slots))

    def on_idle_step(self) -> None:
        self.idle_steps += 1

    def on_reject(self) -> None:
        """A submit bounced off the bounded waiting deque
        (EngineConfig.max_waiting) — the router's spill-over signal."""
        self.rejected += 1

    def on_host_sync(self, kind: str, n: int = 1) -> None:
        """Record `n` host<->device crossings of the given kind
        ('decode' | 'prefill' | 'quality'). Quality-probe pulls are metered
        under their own kind so `host_syncs_decode` stays EXACTLY the
        decode-dispatch count — the no-extra-syncs contract the ledger
        tests gate."""
        self.host_syncs[kind] = self.host_syncs.get(kind, 0) + n

    def on_spec_dispatch(self, proposed: int, accepted: int) -> None:
        """One propose-then-verify cycle: `proposed` draft tokens offered
        across live slots, `accepted` of them committed by the verify.
        Rolled-back tokens (proposed - accepted) cost draft FLOPs + a slab
        index rewind but no host traffic."""
        self.spec_dispatches += 1
        self.draft_proposed += proposed
        self.draft_accepted += accepted

    def on_slot_speculation(self, slot: int, accepted: int,
                            proposed: int) -> None:
        """Per-slot acceptance accounting (examples/serve_speculative)."""
        acc = self.slot_acceptance.setdefault(slot, [0, 0])
        acc[0] += accepted
        acc[1] += proposed

    def on_prefix(self, matched: int, n_prompt: int) -> None:
        """One paged admission: `matched` of `n_prompt` prompt tokens came
        from shared prefix pages (their prefill was SKIPPED); the rest were
        computed (full prefill, or the unmatched suffix)."""
        self.prefix_lookups += 1
        self.prefix_hits += int(matched > 0)
        self.prefill_tokens_skipped += matched
        self.prefill_tokens_computed += n_prompt - matched

    def on_gather_avoided(self, n_bytes: int) -> None:
        """One page-table-native decode dispatch: `n_bytes` is what the
        legacy gather+scatter wrap would have materialised (2x the slots'
        slab view — gather in, scatter back) and the native path did not."""
        self.gather_bytes_avoided += n_bytes

    def on_conversation_hit(self, matched: int) -> None:
        """A paged admission whose prefix match ran through pages a
        finished request PUBLISHED from its generated tokens — a chat
        resuming its own prior turn; `matched` tokens skipped prefill."""
        self.conversation_prefix_hits += 1
        self.conversation_tokens_reused += matched

    def on_pool_wait(self) -> None:
        """An admission bounced off page pressure (PoolExhausted after LRU
        eviction) and was requeued — free slots existed, pages didn't."""
        self.pool_waits += 1

    def on_tier_change(self, old_tier: int, new_tier: int) -> None:
        """The engine swapped its resident packed tier (serve.qos): a move
        to a HIGHER tier index is a demotion (cheaper Kratos point), a move
        back toward tier 0 a promotion."""
        if new_tier > old_tier:
            self.tier_demotions += 1
        elif new_tier < old_tier:
            self.tier_promotions += 1

    def on_shed(self, reason: str) -> None:
        """A request reached the terminal "shed" state instead of "done".
        `reason` is 'deadline' (expired before/while running), 'pool'
        (pool_wait_retries exhausted under page pressure), or 'failover'
        (could not be re-homed off a dead replica)."""
        self.shed += 1
        if reason == "deadline":
            self.deadline_missed += 1
        elif reason == "pool":
            self.shed_pool_pressure += 1

    def on_failover(self) -> None:
        """A request evacuated off a dead replica was re-admitted HERE
        (counted on the destination so fleet sums stay exact)."""
        self.failovers += 1

    def on_pages(self, in_use: int, capacity: int) -> None:
        """Per-dispatch page-pool gauge (pages referenced by live slots or
        retained by the prefix index, out of the usable pool)."""
        self.page_samples.append(in_use)
        self.page_capacity = capacity

    def on_ledger(self, *, elems: float, zeros: float, near: float,
                  groups: float, kblocks: float, dead_kblocks: float,
                  flops_dense: float, flops_eff: float, bytes_dense: float,
                  bytes_eff: float) -> None:
        """One drained dispatch delta from the device ineffectual-work
        ledger (serve.ledger LedgerSink.on_drain). All values are exact
        integer counts carried in float64."""
        self.ledger_dispatches += 1
        self.act_probe_elems += elems
        self.act_zeros += zeros
        self.act_near_zeros += near
        self.act_groups += groups
        self.act_kblocks += kblocks
        self.act_dead_kblocks += dead_kblocks
        self.flops_dense += flops_dense
        self.flops_effective += flops_eff
        self.bytes_dense += bytes_dense
        self.bytes_effective += bytes_eff

    def on_quality_probe(self, tier: int, top1: bool, mad: float) -> None:
        """One shadow-prefill quality sample against tier 0 (serve.ledger):
        whether the probed slot's top-1 token agreed, and the mean absolute
        logit difference over the sampled column."""
        self.quality_probes += 1
        q = self.quality.setdefault(tier, [0.0, 0.0, 0.0])
        q[0] += 1.0
        q[1] += 1.0 if top1 else 0.0
        q[2] += mad

    def quality_by_tier(self) -> Dict[int, Dict[str, float]]:
        """Per-(sparsity, bits)-tier quality gauges from the shadow
        probes: sample count, top-1 agreement rate vs tier 0, mean
        |Δlogit| over the probed columns."""
        return {t: {"probes": q[0],
                    "top1_rate": q[1] / max(1.0, q[0]),
                    "logit_mad": q[2] / max(1.0, q[0])}
                for t, q in sorted(self.quality.items())}

    # -- cross-process transport (serve.control) ----------------------------

    def to_payload(self) -> Dict:
        """JSON-safe snapshot of every counter and record, so a fleet
        worker can ship its metrics through the control plane and the
        coordinator can rebuild ServeMetrics objects and reuse
        `aggregate` unchanged. Monotonic anchors don't cross processes:
        only the elapsed interval travels; `from_payload` re-bases it on
        the receiver's own perf_counter."""
        d = {k: v for k, v in self.__dict__.items() if k != "t0"}
        d["elapsed"] = time.perf_counter() - self.t0
        d["records"] = {str(rid): dataclasses.asdict(r)
                        for rid, r in self.records.items()}
        d["quality"] = {str(t): list(q) for t, q in self.quality.items()}
        d["slot_acceptance"] = {str(s): list(a)
                                for s, a in self.slot_acceptance.items()}
        return d

    @classmethod
    def from_payload(cls, payload: Dict) -> "ServeMetrics":
        """Rebuild a ServeMetrics from `to_payload` output. Per-request
        monotonic timestamps are from the SENDER's clock — useless here —
        so wall intervals are zeroed; the step-clock fields (everything
        the deterministic gates read) survive exactly."""
        m = cls()
        elapsed = float(payload.get("elapsed", 0.0))
        m.t0 = time.perf_counter() - elapsed
        rec_fields = {f.name for f in dataclasses.fields(RequestRecord)}
        for k, v in payload.items():
            if k in ("elapsed", "records", "quality", "slot_acceptance"):
                continue
            if hasattr(m, k):
                setattr(m, k, v)
        for rid, rd in payload.get("records", {}).items():
            rec = RequestRecord(**{k: v for k, v in rd.items()
                                   if k in rec_fields})
            rec.submit_mono = rec.first_token_time = rec.finish_time = 0.0
            m.records[int(rid)] = rec
        m.quality = {int(t): list(q)
                     for t, q in payload.get("quality", {}).items()}
        m.slot_acceptance = {int(s): list(a) for s, a in
                             payload.get("slot_acceptance", {}).items()}
        return m

    # -- report -------------------------------------------------------------

    def report(self) -> Dict[str, float]:
        elapsed = max(time.perf_counter() - self.t0, 1e-9)
        tokens_per_dispatch = self.tokens_generated / max(1, self.decode_steps)
        done = [r for r in self.records.values() if r.finish_step >= 0]
        lat_steps = [float(r.finish_step - r.arrival_step) for r in done]
        ttft_steps = [float(r.first_token_step - r.arrival_step)
                      for r in done if r.first_token_step >= 0]
        lat_wall = [r.finish_time - r.submit_mono for r in done]
        decoded = max(0, self.tokens_generated - self.prefills)
        return {
            "requests_completed": float(len(done)),
            "tokens_generated": float(self.tokens_generated),
            "rejected": float(self.rejected),
            "decode_steps": float(self.decode_steps),
            "micro_steps": float(self.micro_steps),
            "idle_steps": float(self.idle_steps),
            "host_syncs_decode": float(self.host_syncs.get("decode", 0)),
            "host_syncs_prefill": float(self.host_syncs.get("prefill", 0)),
            "host_syncs_quality": float(self.host_syncs.get("quality", 0)),
            "host_syncs_per_token": self.host_syncs.get("decode", 0)
            / max(1, decoded),
            "wall_seconds": elapsed,
            "tok_per_s": self.tokens_generated / elapsed,
            # one number, two names: "per step" is the historical engine
            # clock, "per dispatch" the speculation-era reading — aliased
            # so the serve_bench gates can never diverge from the clock
            "tokens_per_step": tokens_per_dispatch,
            "tokens_per_dispatch": tokens_per_dispatch,
            "mean_occupancy": (sum(self.occupancy) / len(self.occupancy))
            if self.occupancy else 0.0,
            "latency_steps_p50": percentile(lat_steps, 50),
            "latency_steps_p99": percentile(lat_steps, 99),
            "latency_s_p50": percentile(lat_wall, 50),
            "latency_s_p99": percentile(lat_wall, 99),
            "ttft_steps_p50": percentile(ttft_steps, 50),
            "ttft_steps_p99": percentile(ttft_steps, 99),
            # speculative decode: acceptance + rollback + cost ratio
            "spec_dispatches": float(self.spec_dispatches),
            "draft_proposed": float(self.draft_proposed),
            "draft_accepted": float(self.draft_accepted),
            "draft_rolled_back": float(self.draft_proposed
                                       - self.draft_accepted),
            "acceptance_rate": self.draft_accepted
            / max(1, self.draft_proposed),
            "draft_verify_flop_ratio": self.draft_flop_fraction,
            # paged KV + prefix reuse
            "prefix_hit_rate": self.prefix_hits
            / max(1, self.prefix_lookups),
            "prefill_tokens_skipped": float(self.prefill_tokens_skipped),
            "prefill_skip_fraction": self.prefill_tokens_skipped
            / max(1, self.prefill_tokens_skipped
                  + self.prefill_tokens_computed),
            "pool_waits": float(self.pool_waits),
            "gather_bytes_avoided": float(self.gather_bytes_avoided),
            "conversation_prefix_hits": float(self.conversation_prefix_hits),
            "conversation_tokens_reused": float(
                self.conversation_tokens_reused),
            "pages_in_use": (sum(self.page_samples)
                             / len(self.page_samples))
            if self.page_samples else 0.0,
            "page_occupancy": (sum(self.page_samples)
                               / (len(self.page_samples)
                                  * self.page_capacity))
            if (self.page_samples and self.page_capacity) else 0.0,
            # resilience: QoS tier churn, shed/deadline accounting, failover
            "tier_demotions": float(self.tier_demotions),
            "tier_promotions": float(self.tier_promotions),
            "shed": float(self.shed),
            "deadline_missed": float(self.deadline_missed),
            "shed_pool_pressure": float(self.shed_pool_pressure),
            "failovers": float(self.failovers),
            # ineffectual-work ledger: exact counters + derived fractions
            "ledger_dispatches": float(self.ledger_dispatches),
            "act_probe_elems": float(self.act_probe_elems),
            "act_zeros": float(self.act_zeros),
            "act_near_zeros": float(self.act_near_zeros),
            "act_groups": float(self.act_groups),
            "act_kblocks": float(self.act_kblocks),
            "act_dead_kblocks": float(self.act_dead_kblocks),
            "act_zero_fraction": self.act_zeros
            / max(1.0, self.act_probe_elems),
            "act_near_zero_fraction": self.act_near_zeros
            / max(1.0, self.act_probe_elems),
            "dead_kblock_fraction": self.act_dead_kblocks
            / max(1.0, self.act_kblocks),
            "flops_dense": float(self.flops_dense),
            "flops_effective": float(self.flops_effective),
            "effective_flop_fraction": self.flops_effective
            / max(1.0, self.flops_dense),
            "bytes_dense": float(self.bytes_dense),
            "bytes_effective": float(self.bytes_effective),
            # per-tier quality probe (pooled; per-tier via quality_by_tier)
            "quality_probes": float(self.quality_probes),
            "quality_top1_rate": sum(q[1] for q in self.quality.values())
            / max(1.0, float(self.quality_probes)),
            "quality_logit_mad": sum(q[2] for q in self.quality.values())
            / max(1.0, float(self.quality_probes)),
            # trace ring-buffer losses
            "trace_dropped": float(self.trace_dropped),
        }

    @staticmethod
    def aggregate(metrics_list: List["ServeMetrics"]) -> Dict[str, float]:
        """Cross-replica aggregate (serve.router): counters SUM, latency
        percentiles pool the union of per-request records (not a mean of
        per-replica percentiles — p99 of a fleet is a fleet-level quantile),
        occupancy is dispatch-weighted. Step-clock rates are left to the
        router, which owns the shared clock (tokens_per_router_step).

        Schema contract: the returned key set is exactly `report()`'s plus
        the documented FLEET-ONLY keys (`n_replicas`) — a serve_bench gate
        that reads a key off a single engine's report must find the same
        key here (tests/test_metrics.py gates the parity)."""
        done = [r for m in metrics_list for r in m.records.values()
                if r.finish_step >= 0]
        lat_steps = [float(r.finish_step - r.arrival_step) for r in done]
        ttft_steps = [float(r.first_token_step - r.arrival_step)
                      for r in done if r.first_token_step >= 0]
        lat_wall = [r.finish_time - r.submit_mono for r in done]
        dispatches = sum(m.decode_steps for m in metrics_list)
        occ_num = sum(sum(m.occupancy) for m in metrics_list)
        occ_den = sum(len(m.occupancy) for m in metrics_list)
        tokens = sum(m.tokens_generated for m in metrics_list)
        decoded = max(0, tokens - sum(m.prefills for m in metrics_list))
        syncs_d = sum(m.host_syncs.get("decode", 0) for m in metrics_list)
        proposed = sum(m.draft_proposed for m in metrics_list)
        accepted = sum(m.draft_accepted for m in metrics_list)
        # fleet-pooled prefix/paging: hit rate over the union of paged
        # admissions and page occupancy dispatch-weighted against each
        # replica's own capacity — same pooling discipline as acceptance
        # (never a mean of per-replica rates)
        lookups = sum(m.prefix_lookups for m in metrics_list)
        hits = sum(m.prefix_hits for m in metrics_list)
        skipped = sum(m.prefill_tokens_skipped for m in metrics_list)
        computed = sum(m.prefill_tokens_computed for m in metrics_list)
        page_num = sum(sum(m.page_samples) for m in metrics_list)
        page_den = sum(len(m.page_samples) for m in metrics_list)
        page_cap = sum(len(m.page_samples) * m.page_capacity
                       for m in metrics_list)
        elapsed = max(max((time.perf_counter() - m.t0 for m in metrics_list),
                          default=0.0), 1e-9)
        tokens_per_dispatch = tokens / max(1, dispatches)
        # fleet-pooled ledger: counters sum; fractions re-derive from the
        # pooled numerators/denominators (never a mean of per-replica rates)
        led_elems = sum(m.act_probe_elems for m in metrics_list)
        led_zeros = sum(m.act_zeros for m in metrics_list)
        led_near = sum(m.act_near_zeros for m in metrics_list)
        led_kb = sum(m.act_kblocks for m in metrics_list)
        led_dead = sum(m.act_dead_kblocks for m in metrics_list)
        led_fd = sum(m.flops_dense for m in metrics_list)
        led_fe = sum(m.flops_effective for m in metrics_list)
        qn = sum(float(m.quality_probes) for m in metrics_list)
        q_top1 = sum(q[1] for m in metrics_list
                     for q in m.quality.values())
        q_mad = sum(q[2] for m in metrics_list
                    for q in m.quality.values())
        return {
            "n_replicas": float(len(metrics_list)),
            "requests_completed": float(len(done)),
            "tokens_generated": float(tokens),
            "rejected": float(sum(m.rejected for m in metrics_list)),
            "decode_steps": float(dispatches),
            "micro_steps": float(sum(m.micro_steps for m in metrics_list)),
            "idle_steps": float(sum(m.idle_steps for m in metrics_list)),
            "host_syncs_decode": float(syncs_d),
            "host_syncs_prefill": float(sum(
                m.host_syncs.get("prefill", 0) for m in metrics_list)),
            "host_syncs_quality": float(sum(
                m.host_syncs.get("quality", 0) for m in metrics_list)),
            "host_syncs_per_token": syncs_d / max(1, decoded),
            "wall_seconds": elapsed,
            "tok_per_s": tokens / elapsed,
            # aliased exactly like report() — serve_bench gates read either
            # name, so the fleet report must expose both or a gate that
            # works on a single engine silently breaks on the fleet
            "tokens_per_step": tokens_per_dispatch,
            "tokens_per_dispatch": tokens_per_dispatch,
            # fleet-pooled speculation: acceptance is accepted/proposed over
            # the union of cycles, not a mean of per-replica rates
            "spec_dispatches": float(sum(m.spec_dispatches
                                         for m in metrics_list)),
            "draft_proposed": float(proposed),
            "draft_accepted": float(accepted),
            "draft_rolled_back": float(proposed - accepted),
            "acceptance_rate": accepted / max(1, proposed),
            # proposal-weighted across replicas (0.0 when no one speculates)
            "draft_verify_flop_ratio": sum(
                m.draft_flop_fraction * m.draft_proposed
                for m in metrics_list) / max(1, proposed),
            # fleet-pooled paged/prefix metrics
            "prefix_hit_rate": hits / max(1, lookups),
            "prefill_tokens_skipped": float(skipped),
            "prefill_skip_fraction": skipped / max(1, skipped + computed),
            "pool_waits": float(sum(m.pool_waits for m in metrics_list)),
            "gather_bytes_avoided": float(sum(
                m.gather_bytes_avoided for m in metrics_list)),
            "conversation_prefix_hits": float(sum(
                m.conversation_prefix_hits for m in metrics_list)),
            "conversation_tokens_reused": float(sum(
                m.conversation_tokens_reused for m in metrics_list)),
            "pages_in_use": page_num / page_den if page_den else 0.0,
            "page_occupancy": page_num / page_cap if page_cap else 0.0,
            # resilience counters sum exactly (failovers are counted on the
            # destination replica only, shed on the shedding replica only)
            "tier_demotions": float(sum(m.tier_demotions
                                        for m in metrics_list)),
            "tier_promotions": float(sum(m.tier_promotions
                                         for m in metrics_list)),
            "shed": float(sum(m.shed for m in metrics_list)),
            "deadline_missed": float(sum(m.deadline_missed
                                         for m in metrics_list)),
            "shed_pool_pressure": float(sum(m.shed_pool_pressure
                                            for m in metrics_list)),
            "failovers": float(sum(m.failovers for m in metrics_list)),
            # fleet-pooled ineffectual-work ledger
            "ledger_dispatches": float(sum(m.ledger_dispatches
                                           for m in metrics_list)),
            "act_probe_elems": float(led_elems),
            "act_zeros": float(led_zeros),
            "act_near_zeros": float(led_near),
            "act_groups": float(sum(m.act_groups for m in metrics_list)),
            "act_kblocks": float(led_kb),
            "act_dead_kblocks": float(led_dead),
            "act_zero_fraction": led_zeros / max(1.0, led_elems),
            "act_near_zero_fraction": led_near / max(1.0, led_elems),
            "dead_kblock_fraction": led_dead / max(1.0, led_kb),
            "flops_dense": float(led_fd),
            "flops_effective": float(led_fe),
            "effective_flop_fraction": led_fe / max(1.0, led_fd),
            "bytes_dense": float(sum(m.bytes_dense for m in metrics_list)),
            "bytes_effective": float(sum(m.bytes_effective
                                         for m in metrics_list)),
            "quality_probes": float(qn),
            "quality_top1_rate": q_top1 / max(1.0, qn),
            "quality_logit_mad": q_mad / max(1.0, qn),
            "trace_dropped": float(sum(m.trace_dropped
                                       for m in metrics_list)),
            "mean_occupancy": occ_num / occ_den if occ_den else 0.0,
            "latency_steps_p50": percentile(lat_steps, 50),
            "latency_steps_p99": percentile(lat_steps, 99),
            "latency_s_p50": percentile(lat_wall, 50),
            "latency_s_p99": percentile(lat_wall, 99),
            "ttft_steps_p50": percentile(ttft_steps, 50),
            "ttft_steps_p99": percentile(ttft_steps, 99),
        }

    def format_report(self) -> str:
        r = self.report()
        spec = ""
        if self.spec_dispatches:
            spec = (f" | accept {r['acceptance_rate']:.2f} "
                    f"({int(r['draft_rolled_back'])} rolled back, "
                    f"draft/verify flops {r['draft_verify_flop_ratio']:.2f})")
        if self.prefix_lookups:
            spec += (f" | prefix hit {r['prefix_hit_rate']:.2f} "
                     f"({int(r['prefill_tokens_skipped'])} prefill toks "
                     f"skipped, pages {r['page_occupancy']:.2f} full)")
            if self.conversation_prefix_hits:
                spec += (f" | conv hits {self.conversation_prefix_hits} "
                         f"({self.conversation_tokens_reused} toks reused)")
            if self.gather_bytes_avoided:
                spec += (f" | gather avoided "
                         f"{self.gather_bytes_avoided / 1e6:.1f} MB")
        if self.ledger_dispatches:
            spec += (f" | act zeros {r['act_zero_fraction']:.2f} "
                     f"(dead k-blocks {r['dead_kblock_fraction']:.2f}, "
                     f"eff flops {r['effective_flop_fraction']:.2f})")
            if self.quality_probes:
                spec += (f" | quality top1 {r['quality_top1_rate']:.2f} "
                         f"over {self.quality_probes} probes")
        if self.shed or self.tier_demotions or self.failovers:
            spec += (f" | shed {self.shed} "
                     f"(deadline {self.deadline_missed}, "
                     f"pool {self.shed_pool_pressure})"
                     f" | demotions {self.tier_demotions}"
                     f" | failovers {self.failovers}")
        return (f"{int(r['requests_completed'])} reqs, "
                f"{int(r['tokens_generated'])} toks in {r['wall_seconds']:.2f}s"
                f" | {r['tok_per_s']:.1f} tok/s wall, "
                f"{r['tokens_per_step']:.2f} tok/step"
                f" | {r['host_syncs_per_token']:.2f} syncs/tok"
                f" | occupancy {r['mean_occupancy']:.2f}"
                f" | latency p50/p99 {r['latency_steps_p50']:.0f}/"
                f"{r['latency_steps_p99']:.0f} steps"
                f" | ttft p50 {r['ttft_steps_p50']:.0f} steps" + spec)
