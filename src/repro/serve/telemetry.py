"""Live telemetry: counter/gauge/histogram registry, Prometheus, JSONL.

The tracer (serve.trace) answers "what happened to THIS request/dispatch";
telemetry answers "what does the fleet look like RIGHT NOW". A
`TelemetryRegistry` holds typed series; a `TelemetryExporter` snapshots a
sample function (engine_sample / router_sample below) on a configurable
cadence, pushing every numeric value into the registry and appending one
JSON line per snapshot — so a run leaves a time SERIES of `ServeMetrics`
(+ page-pool + router queue depths), not just the final summary line.

Prometheus: `render_prometheus()` emits the text exposition format, and
`TelemetryExporter(port=...)` serves it from a stdlib `http.server`
endpoint (`GET /metrics`) on a daemon thread — point a scraper (or
`curl :PORT/metrics`) at a live serve run. No third-party client library:
the text format is a dozen lines of string building, and the stdlib server
is enough for a scrape endpoint that returns one small document.

Everything also works threadless for tests and benches: call
`exporter.sample()` directly instead of `start()`.
"""

from __future__ import annotations

import dataclasses
import http.server
import json
import os
import re
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _sanitize(name: str) -> str:
    """Prometheus metric names: [a-zA-Z_:][a-zA-Z0-9_:]*."""
    name = _NAME_RE.sub("_", name)
    return ("_" + name) if name[:1].isdigit() else name


class Counter:
    """Monotonically increasing count (resets only with the process)."""

    kind = "counter"

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        assert n >= 0, "counters only go up"
        self.value += n

    def set(self, v: float) -> None:
        """Adopt an externally accumulated monotone total (the ServeMetrics
        counters already accumulate; re-counting them here would double)."""
        self.value = float(v)

    def render(self, name: str, labels: str = "") -> List[str]:
        tag = "{" + labels + "}" if labels else ""
        return [f"{name}{tag} {self.value:g}"]


class Gauge:
    """Point-in-time value (queue depth, occupancy, pages in use)."""

    kind = "gauge"

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def render(self, name: str, labels: str = "") -> List[str]:
        tag = "{" + labels + "}" if labels else ""
        return [f"{name}{tag} {self.value:g}"]


class Histogram:
    """Fixed-bucket histogram (cumulative, Prometheus-style `le` buckets)."""

    kind = "histogram"

    def __init__(self, buckets: Sequence[float] = (
            0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0)) -> None:
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * len(self.buckets)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.sum += v
        self.count += 1
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                self.counts[i] += 1

    def render(self, name: str, labels: str = "") -> List[str]:
        # `le` joins any shared labels inside the same brace set.
        pre = labels + "," if labels else ""
        tag = "{" + labels + "}" if labels else ""
        out = []
        for ub, c in zip(self.buckets, self.counts):
            out.append(f'{name}_bucket{{{pre}le="{ub:g}"}} {c}')
        out.append(f'{name}_bucket{{{pre}le="+Inf"}} {self.count}')
        out.append(f"{name}_sum{tag} {self.sum:g}")
        out.append(f"{name}_count{tag} {self.count}")
        return out


class TelemetryRegistry:
    """Named metric store with get-or-create accessors and rendering."""

    def __init__(self, prefix: str = "serve",
                 process_index: Optional[int] = None) -> None:
        self.prefix = prefix
        # When several fleet processes export on one host their metric
        # names collide at the scraper; a process_index label keeps the
        # series apart. None (single-process) renders byte-identical to
        # the pre-fleet format: no label, no braces.
        self.process_index = process_index
        self._metrics: Dict[str, Tuple[Any, str]] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, factory: Callable[[], Any],
             help_: str) -> Any:
        name = _sanitize(name)
        with self._lock:
            if name not in self._metrics:
                self._metrics[name] = (factory(), help_)
            m = self._metrics[name][0]
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        m = self._get(name, Counter, help)
        assert isinstance(m, Counter), f"{name} already registered as {m.kind}"
        return m

    def gauge(self, name: str, help: str = "") -> Gauge:
        m = self._get(name, Gauge, help)
        assert isinstance(m, Gauge), f"{name} already registered as {m.kind}"
        return m

    def histogram(self, name: str, buckets: Sequence[float] = (
            0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0),
            help: str = "") -> Histogram:
        m = self._get(name, lambda: Histogram(buckets), help)
        assert isinstance(m, Histogram), \
            f"{name} already registered as {m.kind}"
        return m

    def snapshot(self) -> Dict[str, float]:
        """Scalar view (histograms as _sum/_count) for JSONL snapshots."""
        out: Dict[str, float] = {}
        with self._lock:
            items = list(self._metrics.items())
        for name, (m, _) in items:
            if isinstance(m, Histogram):
                out[f"{name}_sum"] = m.sum
                out[f"{name}_count"] = float(m.count)
            else:
                out[name] = m.value
        return out

    def render_prometheus(self) -> str:
        labels = ("" if self.process_index is None
                  else f'process_index="{self.process_index}"')
        lines: List[str] = []
        with self._lock:
            items = sorted(self._metrics.items())
        for name, (m, help_) in items:
            full = f"{self.prefix}_{name}" if self.prefix else name
            if help_:
                lines.append(f"# HELP {full} {help_}")
            lines.append(f"# TYPE {full} {m.kind}")
            lines.extend(m.render(full, labels))
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------- samplers

def engine_sample(engine) -> Dict[str, float]:
    """One engine's live picture: the full ServeMetrics report plus the
    queue/slot/page state a report() alone cannot show mid-run."""
    s = dict(engine.metrics.report())
    s["n_active"] = float(engine.pool.n_active)
    s["n_waiting"] = float(engine.n_waiting)
    s["n_slots"] = float(engine.cfg.n_slots)
    stats = engine.backend.page_stats()
    if stats is not None:
        s["pages_in_use_now"], s["pages_usable"] = map(float, stats)
    return s


def router_sample(router) -> Dict[str, float]:
    """Fleet picture: the pooled aggregate plus per-replica queue depths
    (the router's rebalance signal, and the first thing to look at when
    one replica backs up)."""
    s = dict(router.report())
    s["overflow_depth"] = float(len(router._overflow))
    for i, eng in enumerate(router.replicas):
        s[f"replica{i}_n_active"] = float(eng.pool.n_active)
        s[f"replica{i}_n_waiting"] = float(eng.n_waiting)
        s[f"replica{i}_alive"] = float(router.alive[i])
        s[f"replica{i}_tier"] = float(eng.tier)
    return s


# ---------------------------------------------------------------- exporter

# report() keys that accumulate monotonically -> Prometheus counters;
# everything else a sample produces is a point-in-time gauge.
_COUNTER_KEYS = frozenset((
    "tokens_generated", "decode_steps", "micro_steps", "idle_steps",
    "requests_completed", "rejected", "host_syncs_decode",
    "host_syncs_prefill", "spec_dispatches", "draft_proposed",
    "draft_accepted", "draft_rolled_back", "prefill_tokens_skipped",
    "pool_waits", "gather_bytes_avoided", "conversation_prefix_hits",
    "conversation_tokens_reused",
    "spills", "overflowed", "rebalanced", "router_steps",
    # resilience: QoS tier churn, shed/deadline accounting, failover
    "tier_demotions", "tier_promotions", "shed", "deadline_missed",
    "shed_pool_pressure", "failovers", "rejected_fleet", "replica_deaths",
    "restarts",
    # ineffectual-work ledger + quality probes (serve.ledger)
    "ledger_dispatches", "act_probe_elems", "act_zeros", "act_near_zeros",
    "act_groups", "act_kblocks", "act_dead_kblocks",
    "flops_dense", "flops_effective", "bytes_dense", "bytes_effective",
    "quality_probes", "host_syncs_quality", "trace_dropped",
))


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Exporter knobs (launch/serve flags map here)."""

    interval: float = 1.0              # snapshot cadence, seconds
    port: Optional[int] = None         # Prometheus endpoint (0 = ephemeral)
    jsonl: Optional[str] = None        # append one JSON line per snapshot
    process_index: Optional[int] = None  # fleet label; None = unlabeled


class TelemetryExporter:
    """Cadenced snapshots of a sample function into a registry + JSONL,
    with an optional Prometheus scrape endpoint.

    sample_fn: () -> Dict[str, number] (wrap engine_sample/router_sample
    with the target bound). start() runs the cadence on a daemon thread
    and, with a port, the HTTP endpoint; stop() tears both down and takes
    one final snapshot so short runs always leave at least one line."""

    def __init__(self, sample_fn: Callable[[], Dict[str, float]],
                 cfg: TelemetryConfig = TelemetryConfig(), *,
                 registry: Optional[TelemetryRegistry] = None) -> None:
        self.sample_fn = sample_fn
        self.cfg = cfg
        self.registry = registry or TelemetryRegistry(
            process_index=cfg.process_index)
        self.n_samples = 0
        self.port: Optional[int] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._server: Optional[http.server.ThreadingHTTPServer] = None
        self._server_thread: Optional[threading.Thread] = None

    # -- one snapshot -------------------------------------------------------

    def sample(self) -> Dict[str, float]:
        s = self.sample_fn()
        for k, v in s.items():
            if not isinstance(v, (int, float)):
                continue
            if k in _COUNTER_KEYS:
                self.registry.counter(k).set(float(v))
            else:
                self.registry.gauge(k).set(float(v))
        self.n_samples += 1
        if self.cfg.jsonl:
            d = os.path.dirname(self.cfg.jsonl)
            if d:
                os.makedirs(d, exist_ok=True)
            ptag = ({} if self.registry.process_index is None
                    else {"process": self.registry.process_index})
            with open(self.cfg.jsonl, "a") as f:
                f.write(json.dumps({"ts": time.time(),
                                    "sample": self.n_samples,
                                    **ptag, **s}) + "\n")
        return s

    # -- cadence + endpoint -------------------------------------------------

    def start(self) -> "TelemetryExporter":
        if self.cfg.port is not None:
            self._start_server(self.cfg.port)
        try:
            self.sample()          # immediate first point: a scrape right
        except Exception:          # after start() never sees an empty page
            pass
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="telemetry")
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.cfg.interval):
            try:
                self.sample()
            except Exception:
                pass                     # a racing report() never kills serve

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        try:
            self.sample()                # final snapshot: short runs get >= 1
        except Exception:
            pass
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
            if self._server_thread is not None:
                self._server_thread.join(timeout=5.0)
            self._server = None
            self._server_thread = None

    def __enter__(self) -> "TelemetryExporter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- http ---------------------------------------------------------------

    def _start_server(self, port: int) -> None:
        registry = self.registry

        class Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):              # noqa: N802 (stdlib casing)
                if self.path.rstrip("/") in ("", "/metrics"):
                    body = registry.render_prometheus().encode()
                    self.send_response(200)
                    self.send_header("Content-Type",
                                     "text/plain; version=0.0.4")
                    self.send_header("Content-Length", str(len(body)))
                    self.end_headers()
                    self.wfile.write(body)
                else:
                    self.send_error(404)

            def log_message(self, *a):     # scrapes must not spam stdout
                pass

        self._server = http.server.ThreadingHTTPServer(
            ("127.0.0.1", port), Handler)
        self.port = self._server.server_address[1]
        self._server_thread = threading.Thread(
            target=self._server.serve_forever, daemon=True,
            name="telemetry-http")
        self._server_thread.start()
