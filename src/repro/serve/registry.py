"""Packed-model registry: load once, pack once, serve many.

`pack_model_params` walks a training parameter tree and replaces every
Kratos-able projection leaf (`{"w": ...}` dicts created by `kratos.init`)
with a `kratos.PackedLinear` — the packed serving buffers (gathered sparse
blocks, bit-packed int codes, per-channel scales). Because `kratos.apply`
dispatches `PackedLinear` leaves to `apply_packed`, the packed tree is a
drop-in for the dense one: the same `steps.make_decode_step` serves both,
but the packed tree's hot path reads (1 - sparsity) * bits/16 of the weight
bytes.

The registry keys models by `(arch, KratosSpec)` — the same trained
architecture served dense, sparse, and quantized are three distinct serving
artifacts, exactly like the paper's one-bitstream-per-(sparsity, precision)
benchmark grid.

Not packed (by design):
  * `router` / `head` / `embed` — consumed by raw einsums, not kr.apply;
  * MoE routed-expert stacks (raw (E, d, f) arrays) — dispatched per-expert
    at apply time; with a tree spec they still run the gathered-block path,
    just from dense-format storage;
  * `dt_proj` and other non-GEMM leaves.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as C
from repro.core import kratos as kr
from repro.models import transformer as T

# parent-key names of projections that route through kr.apply (attention,
# MLP, MLA low-rank factors, Mamba in/x/out) — the packable surface.
PACKABLE = frozenset({
    "wq", "wk", "wv", "wo", "wq_a", "wq_b", "wkv_a", "wkv_b",
    "w_gate", "w_up", "w_down", "in_proj", "x_proj", "out_proj",
})


def _is_packable(node, name: str) -> bool:
    """The single predicate both the packer and the stats walk share."""
    return (isinstance(node, dict) and set(node) == {"w"}
            and name in PACKABLE and hasattr(node["w"], "ndim")
            and node["w"].ndim in (2, 3))


def pack_model_params(params: Dict[str, Any], spec: kr.KratosSpec,
                      ) -> Tuple[Dict[str, Any], int]:
    """Replace packable `{"w"}` leaves with PackedLinear; returns (tree, n)."""
    count = [0]

    def walk(node, name: str):
        if _is_packable(node, name):
            count[0] += 1
            return kr.pack_linear(node, spec)
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v, name) for v in node]
        return node

    packed = walk(params, "")
    return packed, count[0]


def _tree_bytes(tree) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree))


@dataclasses.dataclass
class PackedModel:
    """A named serving artifact: config + packed parameter tree + stats.

    With `draft_spec` (serve.speculative.DraftSpec) the artifact ALSO
    carries a self-draft: the same dense weights re-packed at the draft's
    (sparsity, bits) point — optionally layer-truncated — used by the
    speculative decode path. The draft is part of the artifact identity
    (registry key + name), never a mutation of a cached target.

    With `tier_specs` (serve.qos) the artifact carries a QoS degradation
    LADDER: the same dense weights re-packed at 1-2 cheaper (sparsity,
    bits) points, full depth, same cache layout (qos.check_tier_spec), so
    an overloaded engine can swap the live decode step onto tier i without
    touching resident KV state. tier 0 is `params` itself; `tier_params[i]`
    backs engine tier i+1."""

    name: str
    cfg: T.ModelConfig
    params: Dict[str, Any]          # tree with PackedLinear leaves
    spec: kr.KratosSpec
    n_packed: int                   # projections converted to PackedLinear
    packed_bytes: int               # serving bytes of the packed projections
    dense_bytes: int                # training bytes of the same projections
    draft_spec: Any = None          # speculative.DraftSpec or None
    draft_cfg: Optional[T.ModelConfig] = None
    draft_params: Optional[Dict[str, Any]] = None
    draft_packed: int = 0           # projections packed in the draft tree
    tier_specs: Tuple = ()          # QoS ladder (DraftSpec per cheap tier)
    tier_params: Tuple = ()         # matching packed trees (same cache tree)

    @property
    def compression(self) -> float:
        return self.dense_bytes / max(1, self.packed_bytes)

    @property
    def has_draft(self) -> bool:
        return self.draft_params is not None

    @property
    def n_tiers(self) -> int:
        """Resident quality tiers: the full-quality tree plus the ladder."""
        return 1 + len(self.tier_params)

    def tier_tree(self, tier: int) -> Dict[str, Any]:
        """Packed parameter tree backing engine tier `tier` (0 = full)."""
        return self.params if tier == 0 else self.tier_params[tier - 1]

    def draft_cost_fraction(self) -> float:
        """Analytic draft/target FLOPs-per-token ratio (speculative)."""
        from repro.serve import speculative as SP
        if not self.has_draft:
            return 1.0
        return SP.draft_cost_fraction(self.cfg, self.draft_cfg)

    def pspecs(self, mesh) -> Any:
        """Parameter PartitionSpec tree for serving this artifact on `mesh`
        (sharding.param_pspecs): name-rule FSDP x TP where shapes divide;
        PackedLinear buffers fall through the name rules and REPLICATE —
        the packed-kernel contract (gathered sparse blocks, bit-packed
        codes) never crosses a shard boundary. Used by ShardedBackend and
        the `launch.serve --dry-run` sharding printer."""
        from repro.distributed import sharding as SH
        return SH.param_pspecs(self.params, mesh)


class ModelRegistry:
    """Named store of packed models, keyed by (arch, KratosSpec).

    The cache key also carries (smoke, seed): a reduced smoke artifact and
    the production-config artifact of the same (arch, spec) — or two seeds
    of fresh weights — are distinct serving models."""

    def __init__(self) -> None:
        self._models: Dict[Tuple, PackedModel] = {}
        self._by_name: Dict[str, PackedModel] = {}

    def load(self, arch: str, spec: Optional[kr.KratosSpec] = None, *,
             params: Optional[Dict[str, Any]] = None, seed: int = 0,
             name: Optional[str] = None, smoke: bool = True,
             draft_spec=None, tier_specs=None) -> PackedModel:
        """Load (or return the cached) packed model for (arch, spec).

        params: trained parameter tree; freshly initialized when omitted
        (benchmarks/tests). smoke=True uses the reduced CPU config.
        draft_spec (speculative.DraftSpec): ALSO derive a self-draft
        artifact from the same dense weights — required by
        `EngineConfig.speculate`. The draft spec is part of the cache key
        AND the default name (`_spec_tag`), so a drafted and an undrafted
        artifact of the same (arch, spec) never collide in `get`.
        tier_specs (tuple of DraftSpec, cheapest LAST): also keep a QoS
        degradation ladder resident — the same dense weights packed at
        each cheaper (sparsity, bits) point, validated KV-compatible by
        `qos.check_tier_spec`. Required by `EngineConfig.qos`.
        """
        getter = C.get_smoke if smoke else C.get_config
        cfg = getter(arch)
        spec = cfg.kratos if spec is None else spec
        cfg = dataclasses.replace(cfg, kratos=spec)
        tier_specs = tuple(tier_specs or ())
        key = (arch, spec, smoke, seed, draft_spec, tier_specs)
        if key in self._models and params is None:
            return self._models[key]
        if params is None:
            params = T.init(jax.random.PRNGKey(seed), cfg)

        dense_leaves = [
            p["w"] for p in _iter_packable(params)]
        dense_bytes = sum(int(np.prod(w.shape)) * w.dtype.itemsize
                          for w in dense_leaves)
        draft = {}
        if draft_spec is not None:
            from repro.serve import speculative as SP
            dcfg, dparams, dn = SP.derive_draft(params, cfg, spec, draft_spec)
            draft = dict(draft_spec=draft_spec, draft_cfg=dcfg,
                         draft_params=dparams, draft_packed=dn)
        tier_params = ()
        if tier_specs:
            from repro.serve import qos as Q
            # pack the ladder off the DENSE tree, before the target pack
            # consumes `params` by reference (pack_model_params is pure, but
            # each tier must see the dense leaves, not PackedLinear ones)
            tier_params = tuple(
                pack_model_params(params, Q.check_tier_spec(ts)
                                  .kratos_spec(spec))[0]
                for ts in tier_specs)
        packed, n_packed = pack_model_params(params, spec)
        if n_packed == 0:
            raise ValueError(f"{arch}: no packable projections found — "
                             "packed serving would be a no-op")
        packed_bytes = sum(pl.packed_bytes for pl in _iter_packed(packed))
        default_name = (f"{arch}@{_spec_tag(spec, draft_spec, tier_specs)}"
                        + ("" if smoke else "-full")
                        + (f"#s{seed}" if seed else ""))
        model = PackedModel(
            name=name or default_name, cfg=cfg, params=packed,
            spec=spec, n_packed=n_packed, packed_bytes=packed_bytes,
            dense_bytes=dense_bytes, tier_specs=tier_specs,
            tier_params=tier_params, **draft)
        self._models[key] = model
        self._by_name[model.name] = model
        return model

    def get(self, name: str) -> PackedModel:
        if name not in self._by_name:
            raise KeyError(f"no model '{name}'; loaded: {sorted(self._by_name)}")
        return self._by_name[name]

    def names(self):
        return sorted(self._by_name)

    def __len__(self) -> int:
        return len(self._by_name)


def _spec_tag(spec: kr.KratosSpec, draft_spec=None, tier_specs=()) -> str:
    """Artifact-identity tag: every field that changes the serving buffers.

    The draft-spec fields are INCLUDED when present — a drafted artifact
    and its plain twin are different serving models and must never collide
    under one name in `Registry.get`. Same for the QoS tier ladder."""
    tag = kr.spec_tag(spec.sparsity, spec.bits, spec.act_bits, spec.impl)
    if draft_spec is not None:
        tag += f"+draft[{draft_spec.tag}]"
    if tier_specs:
        tag += "+tiers[" + ",".join(ts.tag for ts in tier_specs) + "]"
    return tag


def _iter_packable(params):
    def walk(node, name):
        if _is_packable(node, name):
            yield node
        elif isinstance(node, dict):
            for k, v in node.items():
                yield from walk(v, k)
        elif isinstance(node, list):
            for v in node:
                yield from walk(v, name)
    yield from walk(params, "")


def _iter_packed(params):
    def walk(node):
        if isinstance(node, kr.PackedLinear):
            yield node
        elif isinstance(node, dict):
            for v in node.values():
                yield from walk(v)
        elif isinstance(node, list):
            for v in node:
                yield from walk(v)
    yield from walk(params)
