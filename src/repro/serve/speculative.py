"""Speculative decode: the Kratos grid as a SELF-DRAFT axis.

The paper's central result is that fine-grained sparsity and low bit-width
preserve a model's function while cutting its weight traffic and FLOPs —
which is exactly the recipe for a cheap draft model. A *self-draft* is the
SAME trained weights re-packed through `core/quantize` + `core/sparsity` at
a more aggressive (sparsity, bits) point (optionally truncated to a leading
layer prefix): the draft proposes K tokens with the cheap artifact, the
full-precision target verifies the whole K-block in one batched forward,
and per-slot accept/reject masking commits the longest agreeing prefix plus
one target-sampled bonus token. Correctness never depends on the draft —
greedy speculative decode is token-identical to plain decode for any draft,
and temperature>0 uses the standard rejection-sampling correction so the
committed stream is still distributed exactly as the target.

What lives here (the registry/policy side of the subsystem):

  DraftSpec        how to derive the draft artifact from the target: weight
                   bits, sparsity (block geometry inherited from the target
                   spec unless overridden), optional `keep_layers` layer
                   truncation, optional draft KV-cache dtype.
  derive_draft     dense params + target spec + DraftSpec -> (draft config,
                   packed draft tree). Called by `ModelRegistry.load(...,
                   draft_spec=...)`; the draft shares the target's embed /
                   final-norm / head so its logit geometry matches.
  draft_cost_fraction  analytic draft/target FLOPs-per-token ratio (layer
                   fraction x (1 - sparsity) on the 'tree' impl) — reported
                   by ServeMetrics as `draft_verify_flop_ratio`.
  check_supported  archs whose KV rollback is free vs impossible: a rolled-
                   back slot just rewinds its per-slot index clock (stale
                   positions are masked and later overwritten), EXCEPT
                   circular sliding-window caches, where the speculative
                   writes would evict still-valid history — those are
                   refused with an explanation rather than silently wrong.

The execution side — the fused propose-then-verify step, per-slot
accepted-length vectors, recurrent-state (SSM) snapshot/rollback — lives in
`distributed.steps.make_speculative_decode_step`; the slab/slot plumbing in
`serve.backend`; the `speculate=K` knobs in `serve.engine` /
`serve.scheduler.Request`.

Slot-clock sharing: the draft slab is a second `CachePool` with the SAME
slot assignment and the SAME per-slot index vector as the target slab
(`steps.make_decode_state`). At every dispatch boundary the two clocks are
equal by construction — the draft consumed exactly the committed prefix —
so no extra per-slot draft state exists.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax

from repro.core import kratos as kr
from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class DraftSpec:
    """How to derive a self-draft artifact from the target weights.

    bits / sparsity / impl / act_bits mirror `kratos.KratosSpec` but apply
    to the DRAFT repack only; bk/bn default to the target spec's block
    geometry (None = inherit). keep_layers truncates the draft to the first
    `keep_layers` layers (must keep the whole prelude plus a whole number
    of scan periods); the truncated draft still shares the target's embed,
    final norm and head, so the logit spaces align. cache_dtype overrides
    the draft KV slab dtype (None = the engine's cache dtype).
    """

    bits: Optional[int] = 8
    sparsity: float = 0.0
    impl: str = "tree"
    act_bits: Optional[int] = None
    bk: Optional[int] = None           # None -> inherit from target spec
    bn: Optional[int] = None
    keep_layers: Optional[int] = None  # None -> full depth
    cache_dtype: Optional[str] = None  # None -> engine cache dtype

    def __post_init__(self):
        if self.keep_layers is not None and self.keep_layers < 1:
            raise ValueError(f"keep_layers must be >= 1, got "
                             f"{self.keep_layers}")

    @classmethod
    def from_args(cls, bits: int, sparsity: float,
                  keep_layers: int) -> "DraftSpec":
        """The shared CLI policy (launch/serve.py --draft-*, serve_bench
        --draft-*): bits=0 means native precision, any sparsity uses the
        8x8 block grid every smoke d_model divides, keep_layers=0 keeps
        full depth."""
        return cls(bits=bits or None, sparsity=sparsity,
                   bk=8 if sparsity else None, bn=8 if sparsity else None,
                   keep_layers=keep_layers or None)

    def kratos_spec(self, base: kr.KratosSpec) -> kr.KratosSpec:
        """The KratosSpec the draft packs with (geometry from `base`)."""
        return dataclasses.replace(
            base, bits=self.bits, sparsity=self.sparsity, impl=self.impl,
            act_bits=self.act_bits,
            bk=self.bk if self.bk is not None else base.bk,
            bn=self.bn if self.bn is not None else base.bn)

    @property
    def tag(self) -> str:
        """Registry-name fragment — every field that changes the artifact
        (shared base formatter with registry._spec_tag, plus the
        draft-only fields: block geometry overrides, layer truncation,
        cache dtype)."""
        t = kr.spec_tag(self.sparsity, self.bits, self.act_bits, self.impl)
        if self.bk is not None or self.bn is not None:
            t += f"-b{self.bk or 'i'}x{self.bn or 'i'}"   # 'i' = inherited
        if self.keep_layers is not None:
            t += f"-l{self.keep_layers}"
        if self.cache_dtype:
            t += f"-c{self.cache_dtype}"
        return t


def draft_config(cfg: T.ModelConfig, dspec: DraftSpec,
                 base_spec: kr.KratosSpec) -> T.ModelConfig:
    """The draft's ModelConfig: target arch at the draft Kratos point,
    optionally truncated to a leading layer prefix."""
    n = cfg.n_layers
    if dspec.keep_layers is not None:
        n = dspec.keep_layers
        prelude, period = cfg.prelude_layers, cfg.scan_period
        if n > cfg.n_layers:
            raise ValueError(f"keep_layers={n} > n_layers={cfg.n_layers}")
        if n < prelude + period or (n - prelude) % period:
            raise ValueError(
                f"keep_layers={n} must keep the {prelude}-layer prelude "
                f"plus a whole number of scan periods (period={period})")
    return dataclasses.replace(cfg, n_layers=n,
                               kratos=dspec.kratos_spec(base_spec))


def truncate_layers(params: Dict[str, Any], cfg: T.ModelConfig,
                    draft_cfg: T.ModelConfig) -> Dict[str, Any]:
    """Keep the first draft_cfg.n_layers layers of a parameter tree.

    The prelude list is untouched (truncation below the prelude is rejected
    by `draft_config`); each scanned slot stack keeps its first
    (n_layers - prelude) / scan_period entries. Embed / final norm / head /
    encoder stacks are shared with the target unchanged.
    """
    m = (draft_cfg.n_layers - cfg.prelude_layers) // cfg.scan_period
    out = dict(params)
    out["blocks"] = [jax.tree_util.tree_map(lambda l: l[:m], slot)
                     for slot in params["blocks"]]
    return out


def derive_draft(params: Dict[str, Any], cfg: T.ModelConfig,
                 target_spec: kr.KratosSpec, dspec: DraftSpec,
                 ) -> Tuple[T.ModelConfig, Dict[str, Any], int]:
    """(draft config, packed draft tree, n packed) from DENSE target params.

    The draft is packed from the same dense weights the target artifact was
    packed from — `pack_model_params` with the draft KratosSpec — so the two
    artifacts are two points on the paper's (sparsity, precision) grid over
    one set of trained weights.
    """
    from repro.serve.registry import pack_model_params   # deferred: cycle
    dcfg = draft_config(cfg, dspec, target_spec)
    dparams = params
    if dcfg.n_layers < cfg.n_layers:
        dparams = truncate_layers(params, cfg, dcfg)
    packed, n = pack_model_params(dparams, dcfg.kratos)
    if n == 0:
        raise ValueError("draft spec packs no projections — a draft that "
                         "serves dense training weights is not a draft")
    return dcfg, packed, n


def draft_cost_fraction(cfg: T.ModelConfig, draft_cfg: T.ModelConfig) -> float:
    """Analytic draft/target FLOPs-per-token ratio (the metrics'
    `draft_verify_flop_ratio`): active params scaled by the 'tree' impl's
    (1 - sparsity) compute discount. Quantization changes bytes, not FLOPs,
    so bits don't enter."""
    def cost(c: T.ModelConfig) -> float:
        s = c.kratos
        frac = (1.0 - s.sparsity) if (s.sparsity and s.impl == "tree") else 1.0
        return 2.0 * c.active_param_count() * frac
    return cost(draft_cfg) / max(1.0, cost(cfg))


def check_supported(cfg: T.ModelConfig, cache_len: int) -> None:
    """Refuse archs whose KV layout cannot roll back.

    Rollback after a rejected draft suffix is a per-slot index rewind: the
    stale cache positions are masked by the per-slot validity clocks and
    later overwritten in place. That argument fails for CIRCULAR
    sliding-window caches (window < allocated positions): the speculative
    writes at positions index..index+K land on slots (pos % W) that still
    hold live history from positions pos - W, and rewinding the clock
    cannot resurrect what was evicted. Windowed archs whose window covers
    the whole padded slab never wrap and are fine.
    """
    if cfg.window is not None and cfg.window < cache_len:
        raise ValueError(
            f"speculative decode unsupported: sliding-window cache "
            f"(window={cfg.window} < {cache_len} positions) is circular — "
            f"rolling back rejected draft tokens would need the history "
            f"their writes evicted. Serve with max_len + K <= window, or "
            f"without speculation.")
