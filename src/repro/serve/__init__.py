"""repro.serve — continuous-batching inference over packed Kratos weights.

The serving subsystem that makes the paper's contribution visible at
inference time: models are loaded through a registry that calls
`kratos.pack()` ONCE per projection (sparse gather plans, bit-packed codes),
and every decode step dispatches through `kratos.apply_packed` — the packed
buffers, not the dense training weights, are what the hot path reads.

The decode loop is DEVICE-RESIDENT (PR 2): sampling is fused into the
compiled step (on-device argmax / per-slot-temperature Gumbel with a
threaded jax.random key), the token/index/lifecycle state is a donated
device tree, the KV slab is donated so it updates in place, and
`decode_chunk` (K) micro-steps run per dispatch under one lax.scan — only a
(K, n_slots) int32 token block ever crosses to the host. The decode GEMMs
run at m = n_slots through the kernels' skinny-m path (sublane padding), so
the packed sparse/quant Pallas kernels serve the hot loop, not just prefill.

Layout:

  registry.py    named packed-model store keyed by (arch, KratosSpec);
                 `pack_model_params` re-points a training parameter tree at
                 `PackedLinear` serving buffers.
  cache_pool.py  slab-allocated KV-cache pool: one `T.make_caches` slab of
                 `n_slots` rows, per-request slot assignment / LIFO reuse;
                 slot installs donate the slab (in-place row writes).
  scheduler.py   request admission policy: `ContinuousScheduler` (join the
                 decode batch whenever a slot frees) vs `StaticScheduler`
                 (drain-then-refill lock-step baseline).
  engine.py      the request lifecycle + step loop: per-request prefill into
                 a slot, K-micro-step slab decode dispatches with PER-SLOT
                 cache clocks and on-device EOS/length masking, streaming
                 token callbacks replayed from the synced block.
  metrics.py     tok/s, tokens/dispatch, host syncs per decoded token,
                 p50/p99 latency, time-to-first-token, batch occupancy.

Quickstart:

    from repro.serve import EngineConfig, InferenceEngine, ModelRegistry
    from repro.core.kratos import KratosSpec

    reg = ModelRegistry()
    model = reg.load("h2o-danube-1.8b", KratosSpec(sparsity=0.5, bits=8,
                                                   bk=8, bn=8))
    eng = InferenceEngine(model, EngineConfig(n_slots=4, max_len=96))
    req = eng.submit(prompt_tokens, max_new_tokens=16)
    eng.run()
    print(req.generated, eng.metrics.report())
"""

from repro.serve.cache_pool import CachePool, PoolExhausted
from repro.serve.engine import EngineConfig, InferenceEngine
from repro.serve.metrics import ServeMetrics
from repro.serve.registry import ModelRegistry, PackedModel, pack_model_params
from repro.serve.scheduler import (ContinuousScheduler, Request,
                                   StaticScheduler)

__all__ = [
    "CachePool", "PoolExhausted", "EngineConfig", "InferenceEngine",
    "ServeMetrics", "ModelRegistry", "PackedModel", "pack_model_params",
    "ContinuousScheduler", "StaticScheduler", "Request",
]
