"""repro.serve — continuous-batching inference over packed Kratos weights.

The serving subsystem that makes the paper's contribution visible at
inference time: models are loaded through a registry that calls
`kratos.pack()` ONCE per projection (sparse gather plans, bit-packed codes),
and every decode step dispatches through `kratos.apply_packed` — the packed
buffers, not the dense training weights, are what the hot path reads.

The decode loop is DEVICE-RESIDENT (PR 2): sampling is fused into the
compiled step (on-device argmax / per-slot-temperature Gumbel with a
threaded jax.random key), the token/index/lifecycle state is a donated
device tree, the KV slab is donated so it updates in place, and
`decode_chunk` (K) micro-steps run per dispatch under one lax.scan — only a
(K, n_slots) int32 token block ever crosses to the host. The decode GEMMs
run at m = n_slots through the kernels' skinny-m path (sublane padding), so
the packed sparse/quant Pallas kernels serve the hot loop, not just prefill.

Placement is a BACKEND, not an engine concern (PR 3): the engine holds pure
request lifecycle; `serve.backend.ExecutionBackend` owns where the params /
KV slab / loop state live and how the steps are jitted. `LocalBackend` is
the single-device path above; `ShardedBackend` runs the SAME donated decode
step SPMD over a (data, model) mesh — params placed by the FSDP x TP name
rules, the slab's slot axis sharded like batch, the per-slot state vectors
sharded by `steps.decode_state_pspecs` — with greedy outputs token-identical
to the local path. `serve.router.ReplicaRouter` fronts N engine replicas
(least-loaded admission off the shared `scheduler.replica_load` signal,
spill-over on `EngineSaturated` bounded-queue rejections, waiting-queue
rebalance, aggregated metrics).

Layout:

  registry.py    named packed-model store keyed by (arch, KratosSpec);
                 `pack_model_params` re-points a training parameter tree at
                 `PackedLinear` serving buffers; `PackedModel.pspecs(mesh)`
                 resolves the artifact's parameter placement.
  cache_pool.py  slab-allocated KV-cache pool: one `T.make_caches` slab of
                 `n_slots` rows, per-request slot assignment / LIFO reuse;
                 slot installs donate the slab (in-place row writes);
                 `mesh=` places the slab via cache_pspecs(slab=True).
  scheduler.py   request admission policy: `ContinuousScheduler` (join the
                 decode batch whenever a slot frees) vs `StaticScheduler`
                 (drain-then-refill lock-step baseline); `replica_load` is
                 the router's least-loaded signal.
  backend.py     execution backends: LocalBackend (jax-default placement),
                 ShardedBackend (mesh placement, sharded donated decode).
  engine.py      the request lifecycle + step loop: per-request prefill into
                 a slot, K-micro-step slab decode dispatches with PER-SLOT
                 cache clocks and on-device EOS/length masking, streaming
                 token callbacks replayed from the synced block; bounded
                 waiting deque (`max_waiting`) raising `EngineSaturated`.
  router.py      `ReplicaRouter`: least-loaded/deficit admission across N
                 engine replicas, overflow hold + drain, queue rebalance,
                 aggregate metrics (tokens_per_router_step). `FleetRouter`
                 (PR 10) lifts the same semantics one process boundary up:
                 least-loaded admission off possibly-stale control-plane
                 snapshots, heartbeat-timeout failover with evacuate-style
                 re-prefill on a surviving process.
  control.py     cross-process control plane (PR 10): newline-framed JSON
                 messages over stdlib sockets (load/occupancy/QoS/liveness
                 heartbeats, submits, token progress, final metric
                 reports), `FleetState` (staleness-bounded least-loaded
                 with in-flight submit credits, terminal death on
                 heartbeat silence, resurrection drops), and the
                 LocalProcess/RemoteProcess/WorkerServer process faces the
                 FleetRouter and launch.fleet compose from.
  speculative.py speculative decode (PR 4): `DraftSpec` derives a SELF-DRAFT
                 artifact — the same weights re-packed through
                 core/quantize + core/sparsity at a cheaper (sparsity, bits)
                 point, optionally layer-truncated — and the engine's
                 `speculate=K` runs a fused propose-then-verify cycle
                 (draft proposes K, target verifies the block in one
                 batched forward, per-slot accept/reject masking + index
                 rollback commit 1..K+1 tokens per dispatch). Greedy output
                 is token-identical to plain decode for any draft.
  paging.py      paged KV pool (PR 5): fixed-size pages carved from one
                 preallocated store, per-slot int32 page tables (donated
                 device state through every dispatch), O(1) refcounted page
                 alloc/free, LRU eviction of unreferenced prefix pages —
                 slot capacity becomes `mem / actual_tokens` instead of
                 `mem / max_len`. `EngineConfig.page_size` switches both
                 backends to it; greedy decode is token-identical to the
                 slab because the paged dispatch gathers each slot's pages
                 into exactly the slab layout and runs the unchanged step.
  prefix.py      radix-tree prefix index over token-ID pages: admission
                 matches the longest page-aligned cached prefix, shares its
                 pages by refcount bump, prefills ONLY the unmatched suffix
                 (the decode-form s>1 block write), and publishes the
                 prompt's full pages for future requests — redundant
                 prefill across requests sharing a system prompt drops to
                 zero.
  metrics.py     tok/s, tokens/dispatch, host syncs per decoded token,
                 p50/p99 latency, time-to-first-token, batch occupancy,
                 rejections, draft acceptance/rollback rates, prefix hit
                 rate / prefill tokens skipped / page-pool occupancy;
                 `ServeMetrics.aggregate` pools replicas.
  trace.py       ring-buffer lifecycle/dispatch tracer (PR 6): every edge —
                 submit/admit/prefill/first-token/finish, decode and
                 speculative dispatches, host syncs, page traffic — in BOTH
                 clocks (engine step + monotonic wall); span pairing into
                 per-request TTFT/decode/queue timelines that reconcile
                 exactly with ServeMetrics; JSONL + chrome://tracing
                 exports; a jax.profiler bracket around the first traced
                 dispatches. `EngineConfig.trace=None` serves the shared
                 NULL_TRACER — zero-cost disabled (gated by test).
  ledger.py      ineffectual-work ledger (PR 9): a device-resident
                 (n_layers, width) counter matrix carried through the fused
                 decode/spec/suffix-prefill dispatches as DONATED loop
                 state, updated in-graph by thresholded probes around the
                 packed GEMMs (activation zero / near-zero fractions,
                 per-group zero histograms, dead k-block counts, effective
                 vs dense FLOPs/bytes) and drained once per dispatch INSIDE
                 the existing token device_get — no extra host syncs.
                 `LedgerSink` turns per-dispatch deltas into ServeMetrics
                 counters + tracer counter tracks; `quality_every` shadow-
                 runs sampled prefills through tier 0 for per-tier logit
                 agreement. `EngineConfig.ledger=None` serves NULL_LEDGER —
                 zero-cost disabled (gated by an allocation test).
  telemetry.py   live counter/gauge/histogram registry snapshotting
                 ServeMetrics + page pool + router queue depths on a
                 cadence; Prometheus text over stdlib http.server
                 (`GET /metrics`) and JSONL time-series snapshots.
  qos.py         QoS degradation tiers (PR 7): the registry keeps 2-3
                 packed (sparsity, bits) tiers of the same weights
                 resident (`tier_specs=`, KV-compatible by construction);
                 `QoSController` demotes/promotes the live decode between
                 them off queue depth + page pressure with hysteresis —
                 in-flight streams continue across swaps. Plus per-request
                 deadlines (`deadline_steps` / `deadline_ms`), admission-
                 time doom shedding, mid-flight cancellation, and bounded
                 PoolExhausted retries (`pool_wait_retries`).
  chaos.py       deterministic fault injection (PR 7): a scheduled storm
                 (replica crash, NaN logits at the sync boundary, page-
                 pool squeeze, slow dispatch) driven between router steps;
                 recovery is exact — failover re-admits evacuated requests
                 token-identically, pools drain to pristine.

Quickstart:

    from repro.serve import EngineConfig, InferenceEngine, ModelRegistry
    from repro.core.kratos import KratosSpec

    reg = ModelRegistry()
    model = reg.load("h2o-danube-1.8b", KratosSpec(sparsity=0.5, bits=8,
                                                   bk=8, bn=8))
    eng = InferenceEngine(model, EngineConfig(n_slots=4, max_len=96))
    req = eng.submit(prompt_tokens, max_new_tokens=16)
    eng.run()
    print(req.generated, eng.metrics.report())
"""

from repro.serve.backend import (DistributedBackend, ExecutionBackend,
                                 LocalBackend, ShardedBackend,
                                 ensure_distributed)
from repro.serve.cache_pool import CachePool, PoolExhausted
from repro.serve.chaos import ChaosHarness, Fault, seeded_schedule
from repro.serve.control import (ControlListener, Endpoint, FleetConfig,
                                 FleetState, LocalProcess, ProcessStatus,
                                 RemoteProcess, WorkerServer, connect,
                                 decode_message, encode_message)
from repro.serve.engine import (EngineConfig, EngineSaturated,
                                InferenceEngine, ReplicaFault)
from repro.serve.ledger import (NULL_LEDGER, LedgerConfig, LedgerSink,
                                hist_checksum)
from repro.serve.metrics import ServeMetrics
from repro.serve.qos import (QoSConfig, QoSController, check_tier_spec,
                             parse_tiers)
from repro.serve.paging import PagedCachePool, PageLayout, prefix_supported
from repro.serve.prefix import PrefixIndex
from repro.serve.registry import ModelRegistry, PackedModel, pack_model_params
from repro.serve.router import FleetRequest, FleetRouter, ReplicaRouter
from repro.serve.scheduler import (ContinuousScheduler, Request,
                                   StaticScheduler, replica_load)
from repro.serve.speculative import DraftSpec
from repro.serve.telemetry import (TelemetryConfig, TelemetryExporter,
                                   TelemetryRegistry, engine_sample,
                                   router_sample)
from repro.serve.trace import (NULL_TRACER, TraceConfig, Tracer,
                               export_chrome, export_jsonl)

__all__ = [
    "CachePool", "PoolExhausted", "DraftSpec", "EngineConfig",
    "EngineSaturated", "InferenceEngine", "ReplicaFault", "ExecutionBackend",
    "LocalBackend", "ShardedBackend", "DistributedBackend",
    "ensure_distributed", "PagedCachePool", "PageLayout",
    "PrefixIndex", "prefix_supported", "ReplicaRouter", "ServeMetrics",
    "FleetRequest", "FleetRouter",
    "ControlListener", "Endpoint", "FleetConfig", "FleetState",
    "LocalProcess", "ProcessStatus", "RemoteProcess", "WorkerServer",
    "connect", "decode_message", "encode_message",
    "ModelRegistry", "PackedModel", "pack_model_params",
    "ContinuousScheduler", "StaticScheduler", "Request", "replica_load",
    "QoSConfig", "QoSController", "check_tier_spec", "parse_tiers",
    "ChaosHarness", "Fault", "seeded_schedule",
    "NULL_TRACER", "TraceConfig", "Tracer", "export_chrome", "export_jsonl",
    "NULL_LEDGER", "LedgerConfig", "LedgerSink", "hist_checksum",
    "TelemetryConfig", "TelemetryExporter", "TelemetryRegistry",
    "engine_sample", "router_sample",
]
