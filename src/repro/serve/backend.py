"""Execution backends: WHERE the serving steps run is not the engine's job.

The engine (serve.engine) owns request lifecycle — admission, emission,
streaming, slot bookkeeping. Everything about device placement lives here:
which devices hold the params / KV slab / decode state, how the compiled
prefill / decode / install steps are jitted, and what crosses the host
boundary. Swapping `LocalBackend` for `ShardedBackend` changes nothing
about the engine's step loop or its outputs (greedy decode is
token-identical), only the placement of the SPMD program underneath it.

  LocalBackend     single-device (or jax-default) placement — exactly the
                   PR-2 device-resident loop, plus the PR-1 host loop
                   (`EngineConfig.device_loop=False`) kept as the measured
                   baseline.

  ShardedBackend   the production-mesh form: params placed by
                   `sharding.param_shardings` (FSDP x TP where divisible;
                   PackedLinear serving buffers replicate — the packed
                   kernel contract stays intact while the fabric around it
                   scales out), the KV slab placed by
                   `sharding.cache_pspecs(..., slab=True)` (leading slot
                   axis sharded like batch), the per-slot loop state by
                   `steps.decode_state_pspecs`, and the decode step jitted
                   with explicit in/out NamedShardings so DONATION STILL
                   ALIASES: out_shardings pin the slab/state placement to
                   the donated inputs' placement, otherwise XLA would have
                   to copy into a re-placed output. All traces run under
                   `sharding.use_mesh` so model-internal logical-axis
                   constraints resolve against this backend's mesh.

Speculative decode (EngineConfig.speculate=K, serve.speculative): the
backend additionally owns the DRAFT side of the artifact — a second slab
(`draft_pool`, same slot assignment and per-slot index clocks as the target
slab), the draft's batch-1 prefill (run at admission right after the
target's, its cache donated into the draft slab row), and the fused
propose-then-verify step (`steps.make_speculative_decode_step`), jitted
with (target slab, draft slab, state) ALL donated. Both slabs are padded by
K positions of write headroom so the deepest speculative write stays in
bounds before rollback. On the mesh, draft params are REPLICATED (the draft
is small by construction — that is the point of it) while the verify step
runs SPMD exactly like the plain decode, with out_shardings pinned to the
donated inputs so aliasing survives pjit.

Paged KV + prefix reuse (EngineConfig.page_size, serve.paging): the pool
becomes a `PagedCachePool` — fixed-size pages carved from one preallocated
store, per-slot int32 page tables, refcounted sharing — and the decode /
speculative dispatches become their paged twins
(steps.make_paged_decode_step). In the NATIVE form (the default,
EngineConfig.paged_native) the page table rides into the fused step as an
operand and attention reads/writes the page-major store directly — no
per-dispatch gather/scatter materialisation at all; the legacy
gather-run-scatter wrap survives under paged_native=False as the measured
baseline and the A/B oracle. Either way the store AND the page table are
donated device state. Admission grows a prefix path the engine drives:
`prefix_match` (longest page-aligned cached prefix, plus a flag for
whether the hit crossed into a published CONVERSATION — generated tokens
of a finished request), `alloc_pages` (refcount-bump the shared pages +
fresh private pages; LRU eviction of tree-only pages under pressure;
`PoolExhausted` surfaces to the scheduler), `prefill_suffix` (only the
unmatched suffix runs, through the decode-form block write),
`prefix_insert` (publish the prompt's full pages into the radix tree),
`conversation_insert` (publish prompt + GENERATED pages at finish so the
next turn of the same chat skips prefill over the whole prior
conversation). On the mesh the store's page axis shards exactly like the
slab's slot axis (`sharding.page_pspecs`), with out_shardings pinned so
donation aliasing survives pjit. The draft slab of a speculating engine
stays an unpaged CachePool (small by construction; its write headroom needs
no sharing story).

Contract shared by all backends (what the engine calls):

  build(model, cfg)                 compile steps, allocate pool/state
  prefill(batch, exact)             -> (logits, batch-1 caches), on device
                                    (speculating: also runs + stashes the
                                    draft prefill for the same prompt)
  write_slot(slot, caches)          install a prefilled row into the slab
                                    (and the stashed draft row)
  first_token(row, rid, temp)       sample the prefill token (device loop)
  install(slot, tok, idx, ...)      write the slot's row of the loop state
  decode_block()                    ONE donated dispatch, K micro-steps;
                                    returns the synced (K, B) int32 block
  spec_decode_block()               ONE donated propose-then-verify cycle;
                                    returns (commit (B, K+1), n_commit (B,),
                                    n_accept (B,)) int32 on host
  decode_host(tokens, indices)      PR-1 host-loop step (LocalBackend only)
  prefix_match / alloc_pages /      paged-pool admission surface (no-ops /
    prefix_insert / page_stats      zeros on the slab pool)
  prefill_suffix(sfx, full, slot,   prefix-hit admission: prefill only the
    index)                          unmatched suffix into the slot's pages
  describe()                        placement facts for metrics/benchmarks
"""

from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import steps as ST
from repro.models import transformer as T
from repro.serve.cache_pool import CachePool, quiet_donation
from repro.serve.paging import PagedCachePool


class ExecutionBackend:
    """Placement + compiled-step owner behind an InferenceEngine.

    The dispatch methods live HERE, once: a backend's build() compiles the
    steps and places the buffers, and `_ctx()` scopes every dispatch (the
    base is a no-op; ShardedBackend installs its mesh context). The
    paged/slab branch is taken per call off the pool type, so the engine,
    both placements, and both pool forms share one dispatch body each."""

    name = "base"

    def __init__(self) -> None:
        self.pool: Optional[CachePool] = None
        self.params: Any = None
        self.state: Any = None                 # device-resident loop state
        self.draft_pool: Optional[CachePool] = None   # speculative slab
        self.draft_params: Any = None
        self._pending_draft: Any = None        # draft prefill awaiting slot
        self.tier = 0                          # active QoS tier (0 = full)
        self._ledger = None                    # serve.ledger.LedgerConfig
        self.ledger_buf: Any = None            # donated device counter matrix
        self.last_ledger: Optional[np.ndarray] = None  # cum @ last drain

    # -- lifecycle ----------------------------------------------------------

    def build(self, model, cfg) -> None:
        raise NotImplementedError

    def _ctx(self):
        """Scope for every compiled dispatch (ShardedBackend: the mesh)."""
        return contextlib.nullcontext()

    # -- admission / prefill ------------------------------------------------

    def prefill(self, batch: Dict[str, Any], exact: bool):
        fn = self._prefill_last if exact else self._prefill_full
        with self._ctx():
            if not self.cfg.device_loop:       # PR-1 host-loop baseline
                return fn(self.params, batch, self.pool.single_template)
            out = fn(self.params, batch)
            if self.draft_pool is not None:
                # the draft consumes the same prompt; its logits are unused
                # (the first token is sampled from the TARGET's prefill)
                _, self._pending_draft = self._draft_prefill(
                    self.draft_params, batch)
            return out

    def write_slot(self, slot: int, caches) -> None:
        with self._ctx():
            self.pool.write_slot(slot, caches)
            if self.draft_pool is not None:
                # the draft slab row shares the slot id and (from the next
                # dispatch on) the per-slot index clock with the target row
                self.draft_pool.write_slot(slot, self._pending_draft)
                self._pending_draft = None

    def first_token(self, row, rid: int, temperature: float) -> int:
        key = jax.random.fold_in(self._first_key, rid)
        temp = jnp.full((1,), temperature, jnp.float32)
        with self._ctx():
            return int(self._sample_first(row, key, temp)[0])

    def install(self, slot: int, token: int, index: int, temperature: float,
                eos: int, remaining: int, spec_limit: int = 0) -> None:
        with self._ctx(), quiet_donation():
            self.state = self._install(self.state, slot, token, index,
                                       temperature, eos, remaining,
                                       spec_limit)

    def release_slot(self, slot: int) -> None:
        """Park a mid-flight slot's loop-state row inert (cancel / shed /
        evacuate): remaining=0 means the fused step treats the row exactly
        like a finished request's — its writes land in positions nothing
        will ever read, the same guarantee `_emit`'s done path relies on."""
        self.install(slot, 0, 0, 0.0, -1, 0, 0)

    # -- QoS tiers (serve.qos) ----------------------------------------------

    @property
    def n_tiers(self) -> int:
        """Resident quality tiers this backend can swap between."""
        return 1

    def set_tier(self, tier: int) -> None:
        """Swap the live decode onto packed tier `tier` (0 = full quality).

        KV-compatible by construction (qos.check_tier_spec): only the
        params operand of the compiled steps changes — slab/pages, page
        tables, and the loop state stay put, so every resident request's
        token stream continues from its exact position."""
        if tier != 0:
            raise NotImplementedError(
                f"{self.name} backend was built without tier_specs "
                "(registry.load(..., tier_specs=...))")

    # -- decode -------------------------------------------------------------

    def decode_block(self) -> np.ndarray:
        with self._ctx(), quiet_donation():
            if self.ledger_buf is not None:
                if self.paged:
                    (tok_block, self.pool.store, self.pool.page_table,
                     self.state, led) = self._decode(
                        self.params, self.pool.store, self.pool.page_table,
                        self.state, self.ledger_buf)
                else:
                    (tok_block, self.pool.caches, self.state,
                     led) = self._decode(self.params, self.pool.caches,
                                         self.state, self.ledger_buf)
                self.ledger_buf = led
                # ledger rides the dispatch's one existing sync — the drain
                # costs zero extra host round-trips by construction
                tok_block, self.last_ledger = jax.device_get(
                    (tok_block, led))            # the ONLY decode sync
                return np.asarray(tok_block)
            if self.paged:
                (tok_block, self.pool.store, self.pool.page_table,
                 self.state) = self._decode(self.params, self.pool.store,
                                            self.pool.page_table, self.state)
            else:
                tok_block, self.pool.caches, self.state = self._decode(
                    self.params, self.pool.caches, self.state)
        return np.asarray(tok_block)             # the ONLY decode sync

    def spec_decode_block(self):
        if not hasattr(self, "_spec_decode"):
            raise NotImplementedError(
                f"{self.name} backend was not built with "
                "EngineConfig.speculate")
        led = None
        with self._ctx(), quiet_donation():
            if self.ledger_buf is not None:
                if self.paged:
                    (commit, n_commit, n_accept, self.pool.store,
                     self.pool.page_table, self.draft_pool.caches,
                     self.state, led) = self._spec_decode(
                        self.params, self.draft_params, self.pool.store,
                        self.pool.page_table, self.draft_pool.caches,
                        self.state, self.ledger_buf)
                else:
                    (commit, n_commit, n_accept, self.pool.caches,
                     self.draft_pool.caches, self.state,
                     led) = self._spec_decode(
                        self.params, self.draft_params, self.pool.caches,
                        self.draft_pool.caches, self.state, self.ledger_buf)
                self.ledger_buf = led
            elif self.paged:
                (commit, n_commit, n_accept, self.pool.store,
                 self.pool.page_table, self.draft_pool.caches,
                 self.state) = self._spec_decode(
                    self.params, self.draft_params, self.pool.store,
                    self.pool.page_table, self.draft_pool.caches, self.state)
            else:
                (commit, n_commit, n_accept, self.pool.caches,
                 self.draft_pool.caches, self.state) = self._spec_decode(
                    self.params, self.draft_params, self.pool.caches,
                    self.draft_pool.caches, self.state)
        if led is not None:
            commit, n_commit, n_accept, self.last_ledger = jax.device_get(
                (commit, n_commit, n_accept, led))  # the ONLY decode sync
        else:
            commit, n_commit, n_accept = jax.device_get(
                (commit, n_commit, n_accept))    # the ONLY decode sync
        return (np.asarray(commit), np.asarray(n_commit),
                np.asarray(n_accept))

    # -- ineffectual-work ledger (serve.ledger) -----------------------------

    def maybe_rebase_ledger(self) -> bool:
        """Zero the device counter matrix before any cell can lose f32
        exactness (counts are integers, exact up to 2**24). Called by the
        engine right after draining `last_ledger`; returning True tells the
        LedgerSink to reset its cumulative snapshot to match."""
        if (self.last_ledger is None
                or float(self.last_ledger.max()) < float(2 ** 23)):
            return False
        with self._ctx():
            self.ledger_buf = self._place_ledger_zeros()
        self.last_ledger = None
        return True

    def _place_ledger_zeros(self):
        return jnp.zeros((self.model.cfg.n_layers, self._ledger.width),
                         jnp.float32)

    def quality_shadow(self, batch: Dict[str, Any], exact: bool):
        """Shadow-run one admitted prompt's prefill through TIER-0 params
        and return its logits on host (the engine's per-tier quality
        probe). A deliberate, metered host sync (ServeMetrics
        kind='quality') at admission frequency / quality_every — never in
        the decode hot path."""
        fn = self._prefill_last if exact else self._prefill_full
        with self._ctx():
            logits, _ = fn(self._tier0_params(), batch)
        return np.asarray(logits)

    def _tier0_params(self):
        return self.params            # single-tier backend: tier 0 is live

    def decode_host(self, tokens: np.ndarray, indices: np.ndarray):
        raise NotImplementedError(
            f"{self.name} backend has no host decode loop "
            "(EngineConfig.device_loop=False is a LocalBackend baseline)")

    # -- paged admission surface (no-ops on the slab pool) ------------------

    @property
    def paged(self) -> bool:
        return isinstance(self.pool, PagedCachePool)

    def prefix_match(self, prompt):
        """(matched token count, shared page ids, conversation hit) —
        (0, [], False) without a prefix-caching paged pool. The third
        element is True when the match runs through pages a finished
        request PUBLISHED from its generated tokens (conversation_insert),
        i.e. a multi-turn chat resuming its own history."""
        return (self.pool.prefix_match(prompt) if self.paged
                else (0, [], False))

    def alloc_slot_pages(self, slot: int, n_positions: int,
                         shared=()) -> None:
        """Reserve the slot's pages (raises PoolExhausted under pressure);
        a no-op on the slab pool, whose slot IS its storage."""
        if self.paged:
            self.pool.alloc_pages(slot, n_positions, shared)

    def prefix_insert(self, prompt, slot: int) -> int:
        return self.pool.prefix_insert(prompt, slot) if self.paged else 0

    def conversation_insert(self, tokens, slot: int) -> int:
        """Publish a finished request's full conversation (prompt +
        generated tokens) into the radix tree from the slot's own pages;
        a no-op without a prefix-caching paged pool."""
        if self.paged and self.pool.index is not None:
            return self.pool.conversation_insert(tokens, slot)
        return 0

    def gather_bytes_per_dispatch(self) -> int:
        """Bytes a legacy gather+scatter decode dispatch would move
        (0 on the slab pool, or when running the legacy paged path)."""
        if self.paged and getattr(self.cfg, "paged_native", True):
            return self.pool.gather_bytes_per_dispatch()
        return 0

    def page_stats(self):
        """(pages_in_use, usable_pages) or None on the slab pool."""
        return self.pool.page_stats() if self.paged else None

    def prefill_suffix(self, batch, full_batch, slot: int, index: int):
        """Prefix-hit admission: run only the unmatched (bucketed) suffix
        through the decode-form block write into the slot's pages (store
        donated, so the install is in place); returns the full (1, S,
        vocab) suffix logits — the engine reads the true suffix-end column.
        A speculating engine still prefills the FULL prompt into the draft
        slab — the draft has no page sharing and the prefill FLOP saving is
        the target's."""
        if not hasattr(self, "_suffix_prefill"):
            raise NotImplementedError(
                f"{self.name} backend was not built with a prefix-caching "
                "paged pool (EngineConfig.page_size + prefix_cache)")
        with self._ctx(), quiet_donation():
            if self.ledger_buf is not None:
                # ledger stays device-resident: drained at the next decode
                # dispatch's sync, never here
                (logits, self.pool.store,
                 self.ledger_buf) = self._suffix_prefill(
                    self.params, batch, self.pool.store,
                    self.pool.page_table, jnp.asarray(slot, jnp.int32),
                    jnp.asarray(index, jnp.int32), self.ledger_buf)
            else:
                logits, self.pool.store = self._suffix_prefill(
                    self.params, batch, self.pool.store,
                    self.pool.page_table, jnp.asarray(slot, jnp.int32),
                    jnp.asarray(index, jnp.int32))
            if self.draft_pool is not None:
                _, draft = self._draft_prefill(self.draft_params, full_batch)
                self.draft_pool.write_slot(slot, draft)
        return logits

    # -- introspection ------------------------------------------------------

    def describe(self) -> Dict[str, Any]:
        return {"backend": self.name, "mesh_shape": [1, 1]}


class LocalBackend(ExecutionBackend):
    """jax-default placement: the PR-2 loop (and the PR-1 host baseline)."""

    name = "local"

    def build(self, model, cfg) -> None:
        self.model, self.cfg = model, cfg
        self.params = model.params
        # QoS ladder: the compiled steps take params as a non-donated
        # operand, so a tier swap is a pointer swap; each tier's distinct
        # packed-buffer shapes land in their own jit-cache entry.
        self._tier_params = [model.params, *model.tier_params]
        mcfg = model.cfg
        # speculate=K pads the slab: the verify writes K+1 positions from a
        # per-slot clock that can sit at max_len-1; rollback masks them.
        cache_len = cfg.max_len + cfg.speculate
        cdtype = jnp.dtype(cfg.cache_dtype)
        if cfg.page_size:
            # paged pool: same cache positions, carved into refcounted
            # pages (speculative headroom lands in the slot's private tail
            # pages — see steps.make_paged_speculative_decode_step).
            self.pool = PagedCachePool(
                mcfg, cfg.n_slots, cache_len, cdtype,
                page_size=cfg.page_size, n_pages=cfg.n_pages,
                prefix_cache=cfg.prefix_cache)
        else:
            self.pool = CachePool(mcfg, cfg.n_slots, cache_len, cdtype)
        # device loop: prefill allocates its batch-1 caches inside the
        # compiled step (no host template copied in); host loop (PR-1
        # comparison baseline) keeps the template-operand form.
        pkw = dict(cache_len=cache_len, cache_dtype=cdtype) \
            if cfg.device_loop else {}
        self._prefill_last = jax.jit(
            ST.make_prefill_step(mcfg, cfg.backend, last_only=True, **pkw))
        self._prefill_full = jax.jit(
            ST.make_prefill_step(mcfg, cfg.backend, last_only=False, **pkw))
        ledger = getattr(cfg, "ledger", None) if cfg.device_loop else None
        self._ledger = ledger
        if ledger is not None:
            self.ledger_buf = jnp.zeros((mcfg.n_layers, ledger.width),
                                        jnp.float32)
        if cfg.device_loop:
            if cfg.page_size:
                self._decode = jax.jit(
                    ST.make_paged_decode_step(
                        mcfg, cfg.backend, n_steps=cfg.decode_chunk,
                        layout=self.pool.layout,
                        native=getattr(cfg, "paged_native", True),
                        ledger=ledger),
                    # store + table + state (+ ledger) update in place
                    donate_argnums=(1, 2, 3) if ledger is None
                    else (1, 2, 3, 4))
                if self.pool.index is not None:
                    self._suffix_prefill = jax.jit(
                        ST.make_suffix_prefill_step(
                            mcfg, cfg.backend, layout=self.pool.layout,
                            ledger=ledger),
                        # store (+ ledger) update in place
                        donate_argnums=(2,) if ledger is None else (2, 6))
            else:
                self._decode = jax.jit(
                    ST.make_decode_step(mcfg, cfg.backend,
                                        n_steps=cfg.decode_chunk,
                                        ledger=ledger),
                    # slab + state (+ ledger) update in place
                    donate_argnums=(1, 2) if ledger is None else (1, 2, 3))
            self._install = jax.jit(ST.install_slot, donate_argnums=(0,))
            self.state = ST.make_decode_state(cfg.n_slots, cfg.seed)
            self._sample_first = jax.jit(T.sample_tokens)
            self._first_key = jax.random.PRNGKey(cfg.seed)
        else:
            self._decode = jax.jit(ST.make_decode_step(mcfg, cfg.backend))
        if cfg.speculate:
            dcfg = model.draft_cfg
            self.draft_params = model.draft_params
            ddtype = jnp.dtype(cfg.draft_cache_dtype or cfg.cache_dtype)
            self.draft_pool = CachePool(dcfg, cfg.n_slots, cache_len, ddtype)
            self._draft_prefill = jax.jit(
                ST.make_prefill_step(dcfg, cfg.backend, last_only=True,
                                     cache_len=cache_len, cache_dtype=ddtype))
            if cfg.page_size:
                self._spec_decode = jax.jit(
                    ST.make_paged_speculative_decode_step(
                        mcfg, dcfg, cfg.backend, n_draft=cfg.speculate,
                        layout=self.pool.layout,
                        native=getattr(cfg, "paged_native", True),
                        ledger=ledger),
                    # store+table+draft+state (+ ledger)
                    donate_argnums=(2, 3, 4, 5) if ledger is None
                    else (2, 3, 4, 5, 6))
            else:
                self._spec_decode = jax.jit(
                    ST.make_speculative_decode_step(
                        mcfg, dcfg, cfg.backend, n_draft=cfg.speculate,
                        ledger=ledger),
                    # both slabs + state (+ ledger) in place
                    donate_argnums=(2, 3, 4) if ledger is None
                    else (2, 3, 4, 5))

    def _tier0_params(self):
        return self._tier_params[0]

    def decode_host(self, tokens, indices):
        logits, self.pool.caches = self._decode(
            self.params, self.pool.caches,
            jnp.asarray(tokens), jnp.asarray(indices))
        return np.asarray(logits[:, -1])

    @property
    def n_tiers(self) -> int:
        return len(self._tier_params)

    def set_tier(self, tier: int) -> None:
        if not 0 <= tier < self.n_tiers:
            raise ValueError(f"tier {tier} out of range "
                             f"(n_tiers={self.n_tiers})")
        self.params = self._tier_params[tier]
        self.tier = tier


class ShardedBackend(ExecutionBackend):
    """Mesh placement: the donated decode step runs SPMD over (data, model).

    mesh: an explicit `jax.sharding.Mesh` with ('data', 'model') axes (a
    replica submesh from `launch.mesh.replica_meshes`, or the production
    mesh itself). mesh_shape: build a local (data, model) mesh over the
    visible devices instead. Greedy decode is token-identical to
    LocalBackend — the step is the same pure function; only its
    partitioning changes.
    """

    name = "sharded"

    def __init__(self, mesh=None, *,
                 mesh_shape: Optional[Tuple[int, int]] = None):
        super().__init__()
        if mesh is not None and mesh_shape is not None:
            raise ValueError("pass mesh OR mesh_shape, not both")
        self._mesh = mesh
        self._mesh_shape = mesh_shape

    def build(self, model, cfg) -> None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.distributed import sharding as SH
        from repro.launch import mesh as M

        if not cfg.device_loop:
            raise ValueError("ShardedBackend requires device_loop=True: the "
                             "host loop pulls full-vocab logits every step, "
                             "which is exactly the cross-boundary traffic a "
                             "mesh placement must avoid")
        self.model, self.cfg = model, cfg
        mcfg = model.cfg
        if self._mesh is None:
            shape = self._mesh_shape or (len(jax.devices()), 1)
            self._mesh = M.make_local_mesh(*shape)
        mesh = self.mesh = self._mesh
        self._ctx = lambda: SH.use_mesh(mesh)
        cache_len = cfg.max_len + cfg.speculate    # see LocalBackend.build
        with self._ctx():
            # params: FSDP x TP name rules; PackedLinear buffers fall
            # through the rules and replicate — the packed-kernel contract
            # (gathered blocks, bit-packed codes) is placement-opaque.
            self.param_shardings = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), model.pspecs(mesh))
            self.params = jax.device_put(model.params, self.param_shardings)
            if cfg.page_size:
                # page store sharded on its page axis exactly like the slab
                # shards its slot axis (sharding.page_pspecs)
                self.pool = PagedCachePool(
                    mcfg, cfg.n_slots, cache_len,
                    jnp.dtype(cfg.cache_dtype), page_size=cfg.page_size,
                    n_pages=cfg.n_pages, prefix_cache=cfg.prefix_cache,
                    mesh=mesh)
            else:
                self.pool = CachePool(mcfg, cfg.n_slots, cache_len,
                                      jnp.dtype(cfg.cache_dtype), mesh=mesh)
            state_specs = ST.decode_state_pspecs(mesh, cfg.n_slots)
            self.state_shardings = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), state_specs)
            self.state = jax.device_put(
                ST.make_decode_state(cfg.n_slots, cfg.seed),
                self.state_shardings)
            slot_spec = SH.batch_pspec(mesh, cfg.n_slots)
            self._slot_spec = slot_spec
            self._tok_sharding = NamedSharding(
                mesh, P(None, *tuple(slot_spec)))
            # ledger counter matrix: REPLICATED — probe sums over the
            # sharded slot axis all-reduce under GSPMD, and the drained
            # matrix must read identically from every device
            ledger = getattr(cfg, "ledger", None)
            self._ledger = ledger
            if ledger is not None:
                self._ledger_sharding = NamedSharding(mesh, P())
                self.ledger_buf = jax.device_put(
                    jnp.zeros((mcfg.n_layers, ledger.width), jnp.float32),
                    self._ledger_sharding)
            if cfg.page_size and self.pool.index is not None:
                sfx_out = (NamedSharding(mesh, P()), self.pool.shardings)
                if ledger is not None:
                    sfx_out = sfx_out + (self._ledger_sharding,)
                self._suffix_prefill = jax.jit(
                    ST.make_suffix_prefill_step(
                        mcfg, cfg.backend, layout=self.pool.layout,
                        ledger=ledger),
                    donate_argnums=(2,) if ledger is None else (2, 6),
                    # logits replicated; store pinned to the donated
                    # input placement so aliasing survives pjit
                    out_shardings=sfx_out)
            self._install = jax.jit(ST.install_slot, donate_argnums=(0,),
                                    out_shardings=self.state_shardings)
            # batch-1 prefill: nothing to shard on the request axis; params
            # are committed so XLA propagates their placement through the
            # compiled step. Caches allocate inside the jit (donation form).
            pkw = dict(cache_len=cache_len,
                       cache_dtype=jnp.dtype(cfg.cache_dtype))
            self._prefill_last = jax.jit(
                ST.make_prefill_step(mcfg, cfg.backend, last_only=True,
                                     **pkw))
            self._prefill_full = jax.jit(
                ST.make_prefill_step(mcfg, cfg.backend, last_only=False,
                                     **pkw))
            self._sample_first = jax.jit(T.sample_tokens)
            self._first_key = jax.random.PRNGKey(cfg.seed)
            if cfg.speculate:
                self._build_speculative(mesh, cache_len)
            # QoS ladder: each tier's packed tree has its OWN pytree
            # structure (PackedLinear leaf sets differ per (sparsity,
            # bits)) and therefore its own sharding tree — and the hot
            # dispatches pin params via explicit in_shardings — so a tier
            # swap re-jits the dispatches (lazily, cached per tier in
            # `_tier_steps`) instead of pointer-swapping like LocalBackend.
            self._tier_placed = [self.params]
            self._tier_shardings = [self.param_shardings]
            for tp in model.tier_params:
                sh = jax.tree_util.tree_map(
                    lambda s: NamedSharding(mesh, s),
                    SH.param_pspecs(tp, mesh))
                self._tier_placed.append(jax.device_put(tp, sh))
                self._tier_shardings.append(sh)
            self._tier_steps: Dict[int, Dict[str, Any]] = {}
            self.set_tier(0)

    def _compile_dispatch(self) -> Dict[str, Any]:
        """Jit the hot dispatches (decode, and the fused speculative cycle
        when built with speculate) against the CURRENT
        `self.param_shardings`. Called once per active tier — the params
        operand's in_shardings are tier-specific — with the executables
        cached in `_tier_steps`.

        donation + sharding: out_shardings for (slab, state) — and the
        page store / table in paged mode — must equal the donated inputs'
        shardings or the aliasing is lost (XLA would copy into the
        re-placed output buffer)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg, mcfg, mesh = self.cfg, self.model.cfg, self.mesh
        tok_sharding = self._tok_sharding
        ledger = self._ledger
        led_in = () if ledger is None else (self._ledger_sharding,)
        if cfg.page_size:
            decode = jax.jit(
                ST.make_paged_decode_step(
                    mcfg, cfg.backend, n_steps=cfg.decode_chunk,
                    layout=self.pool.layout,
                    native=getattr(cfg, "paged_native", True),
                    ledger=ledger),
                donate_argnums=(1, 2, 3) if ledger is None
                else (1, 2, 3, 4),
                in_shardings=(self.param_shardings, self.pool.shardings,
                              self.pool.table_sharding,
                              self.state_shardings) + led_in,
                out_shardings=(tok_sharding, self.pool.shardings,
                               self.pool.table_sharding,
                               self.state_shardings) + led_in)
        else:
            decode = jax.jit(
                ST.make_decode_step(mcfg, cfg.backend,
                                    n_steps=cfg.decode_chunk,
                                    ledger=ledger),
                donate_argnums=(1, 2) if ledger is None else (1, 2, 3),
                in_shardings=(self.param_shardings, self.pool.shardings,
                              self.state_shardings) + led_in,
                out_shardings=(tok_sharding, self.pool.shardings,
                               self.state_shardings) + led_in)
        steps = {"decode": decode}
        if cfg.speculate:
            dcfg = self.model.draft_cfg
            slot_spec = self._slot_spec
            vec_sharding = NamedSharding(mesh, slot_spec)
            commit_sharding = NamedSharding(mesh, P(*tuple(slot_spec), None))
            if cfg.page_size:
                steps["spec"] = jax.jit(
                    ST.make_paged_speculative_decode_step(
                        mcfg, dcfg, cfg.backend, n_draft=cfg.speculate,
                        layout=self.pool.layout,
                        native=getattr(cfg, "paged_native", True),
                        ledger=ledger),
                    donate_argnums=(2, 3, 4, 5) if ledger is None
                    else (2, 3, 4, 5, 6),
                    in_shardings=(self.param_shardings,
                                  self.draft_shardings,
                                  self.pool.shardings,
                                  self.pool.table_sharding,
                                  self.draft_pool.shardings,
                                  self.state_shardings) + led_in,
                    out_shardings=(commit_sharding, vec_sharding,
                                   vec_sharding, self.pool.shardings,
                                   self.pool.table_sharding,
                                   self.draft_pool.shardings,
                                   self.state_shardings) + led_in)
            else:
                steps["spec"] = jax.jit(
                    ST.make_speculative_decode_step(mcfg, dcfg, cfg.backend,
                                                    n_draft=cfg.speculate,
                                                    ledger=ledger),
                    donate_argnums=(2, 3, 4) if ledger is None
                    else (2, 3, 4, 5),
                    in_shardings=(self.param_shardings,
                                  self.draft_shardings,
                                  self.pool.shardings,
                                  self.draft_pool.shardings,
                                  self.state_shardings) + led_in,
                    out_shardings=(commit_sharding, vec_sharding,
                                   vec_sharding, self.pool.shardings,
                                   self.draft_pool.shardings,
                                   self.state_shardings) + led_in)
        return steps

    @property
    def n_tiers(self) -> int:
        return len(self._tier_placed)

    def _tier0_params(self):
        return self._tier_placed[0]

    def _place_ledger_zeros(self):
        return jax.device_put(
            jnp.zeros((self.model.cfg.n_layers, self._ledger.width),
                      jnp.float32), self._ledger_sharding)

    def set_tier(self, tier: int) -> None:
        if not 0 <= tier < self.n_tiers:
            raise ValueError(f"tier {tier} out of range "
                             f"(n_tiers={self.n_tiers})")
        self.params = self._tier_placed[tier]
        self.param_shardings = self._tier_shardings[tier]
        if tier not in self._tier_steps:
            with self._ctx():
                self._tier_steps[tier] = self._compile_dispatch()
        steps = self._tier_steps[tier]
        self._decode = steps["decode"]
        if "spec" in steps:
            self._spec_decode = steps["spec"]
        self.tier = tier

    def _build_speculative(self, mesh, cache_len) -> None:
        """Draft side on the mesh: draft params REPLICATED (the draft is
        small by design; replication keeps its packed-kernel contract and
        removes its collectives from the hot cycle) and the draft slab
        sharded exactly like the target slab. The fused propose-then-verify
        jit itself lives in `_compile_dispatch` — its params in_shardings
        are per-tier."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg, model = self.cfg, self.model
        dcfg = model.draft_cfg
        self.draft_shardings = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), model.draft_params)
        self.draft_params = jax.device_put(model.draft_params,
                                           self.draft_shardings)
        ddtype = jnp.dtype(cfg.draft_cache_dtype or cfg.cache_dtype)
        self.draft_pool = CachePool(dcfg, cfg.n_slots, cache_len, ddtype,
                                    mesh=mesh)
        self._draft_prefill = jax.jit(
            ST.make_prefill_step(dcfg, cfg.backend, last_only=True,
                                 cache_len=cache_len, cache_dtype=ddtype))

    def describe(self):
        return {"backend": self.name,
                "mesh_shape": [int(self.mesh.shape[a])
                               for a in self.mesh.axis_names],
                "mesh_axes": list(self.mesh.axis_names),
                "n_devices": int(self.mesh.size)}


_DISTRIBUTED_INITIALIZED = False


def ensure_distributed(coordinator_address: str, num_processes: int,
                       process_id: int) -> None:
    """Idempotent `jax.distributed.initialize`. Must run BEFORE any jax
    backend use in the process (device queries included) — launch.fleet
    worker processes call it first thing, before weights exist. A second
    call with the same identity is a no-op; jax itself rejects a second
    call with a different one."""
    global _DISTRIBUTED_INITIALIZED
    if _DISTRIBUTED_INITIALIZED:
        return
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id)
    _DISTRIBUTED_INITIALIZED = True


class DistributedBackend(ShardedBackend):
    """One fleet process's placement: `jax.distributed.initialize` (when a
    coordinator address is given), then the SAME donated/sharded decode
    steps as ShardedBackend on a replica submesh of this process's LOCAL
    devices (`launch.mesh.process_meshes`).

    The inheritance is the design: a fleet process is a ShardedBackend
    whose mesh happens to come from local_devices, so every placement
    rule, donation alias, tier swap and dispatch jit is reused verbatim
    — token-identical to ShardedBackend on the same devices (gated by
    tests/test_fleet.py). Cross-PROCESS coordination is not jax's job
    here: each process's replicas decode independently and the control
    plane (serve.control) moves requests/results, so a local CPU fleet
    may run with no coordinator at all (coordinator_address=None) —
    jax.distributed only needs to exist when a deployment wants the
    global device view (real multi-host meshes, DCN collectives).
    """

    name = "distributed"

    def __init__(self, *, mesh_shape: Tuple[int, int], n_replicas: int = 1,
                 replica: int = 0, coordinator_address: Optional[str] = None,
                 num_processes: int = 1, process_id: int = 0):
        if coordinator_address:
            ensure_distributed(coordinator_address, num_processes, process_id)
        meshes = None

        # defer mesh construction to build() so constructing backends for
        # several replicas stays cheap, but resolve the submesh list once
        self._fleet = dict(mesh_shape=tuple(mesh_shape),
                           n_replicas=n_replicas, replica=replica)
        if not 0 <= replica < n_replicas:
            raise ValueError(f"replica {replica} out of range "
                             f"(n_replicas={n_replicas})")
        super().__init__(mesh=meshes)

    def build(self, model, cfg) -> None:
        from repro.launch import mesh as M
        f = self._fleet
        if self._mesh is None:
            self._mesh = M.process_meshes(*f["mesh_shape"],
                                          f["n_replicas"])[f["replica"]]
        super().build(model, cfg)

    def describe(self):
        d = super().describe()
        d.update({"process_index": int(jax.process_index()),
                  "num_processes": int(jax.process_count()),
                  "replica": int(self._fleet["replica"])})
        return d
