"""Deterministic, step-indexable synthetic LM data pipeline.

Fault-tolerance contract: `batch(step)` is a pure function of
(seed, step) — a job restarted from a step-N checkpoint consumes *exactly*
the batches it would have seen had it never failed (tested in
tests/test_fault_tolerance.py). No filesystem state, no iterator position to
persist.

Two sources:
  * 'markov'  — a seeded random bigram machine with noise: next token is a
    deterministic function of the previous one with prob (1-noise). A model
    can learn this (loss -> ~noise-entropy), so examples show real learning
    curves.
  * 'uniform' — i.i.d. tokens (irreducible loss = ln V) for pure-throughput
    benchmarks.

Multimodal stubs: whisper frames / llava patch embeddings are generated as
seeded gaussians with the correct shapes (the frontends are stubs per the
brief — `input_specs()` provides precomputed embeddings).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    source: str = "markov"          # 'markov' | 'uniform'
    noise: float = 0.1
    # multimodal stubs
    frames: int = 0                 # whisper encoder positions
    d_model: int = 0
    img_tokens: int = 0             # llava vision tokens


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, 0xDA7A]))
        # fixed bigram successor table
        self._succ = rng.integers(0, cfg.vocab, size=(cfg.vocab,),
                                  dtype=np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, 0xBA7C4, int(step)]))
        b, s, v = cfg.batch, cfg.seq, cfg.vocab
        if cfg.source == "uniform":
            seq = rng.integers(0, v, size=(b, s + 1), dtype=np.int32)
        else:
            seq = np.empty((b, s + 1), np.int32)
            seq[:, 0] = rng.integers(0, v, size=(b,))
            noise_mask = rng.random((b, s)) < cfg.noise
            noise_tok = rng.integers(0, v, size=(b, s), dtype=np.int32)
            for t in range(1, s + 1):
                nxt = self._succ[seq[:, t - 1]]
                seq[:, t] = np.where(noise_mask[:, t - 1], noise_tok[:, t - 1],
                                     nxt)
        out = {
            "tokens": seq[:, :-1],
            "labels": seq[:, 1:].astype(np.int32),
        }
        if cfg.frames:
            out["frames"] = rng.standard_normal(
                (b, cfg.frames, cfg.d_model)).astype(np.float32) * 0.1
        if cfg.img_tokens:
            out["img_embeds"] = rng.standard_normal(
                (b, cfg.img_tokens, cfg.d_model)).astype(np.float32) * 0.1
        return out


def make_pipeline(cfg: DataConfig) -> SyntheticLM:
    return SyntheticLM(cfg)
