"""Block-pattern transformer covering all 10 assigned architectures.

One `ModelConfig` describes any of: dense decoder (GQA/MLA/SWA/local-global/
softcap), MoE decoder, Mamba/attention hybrid, pure SSM, and the whisper
encoder-decoder. Per-layer structure is a *periodic pattern* (`scan_period`,
plus `prelude_layers` un-scanned leading layers, e.g. deepseek's first dense
layer); parameters of layers in the same pattern slot are stacked and the
stack is consumed by one `lax.scan` — a 96-layer nemotron lowers to
period-size HLO, which is what keeps 512-device dry-run compile times sane.

Residual blocks are (optionally) wrapped in `jax.checkpoint` with a
configurable policy — required to fit the 340B train step in 16 GB/chip.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import kratos as kr
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    n_layers: int = 2
    d_model: int = 64
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 128
    vocab: int = 256
    head_dim: int = 0                     # 0 -> d_model // n_heads
    activation: str = "silu"
    gated_mlp: Optional[bool] = None      # None -> infer from activation
    norm: str = "rmsnorm"                 # 'rmsnorm' | 'layernorm'
    norm_eps: float = 1e-6
    rmsnorm_plus_one: bool = False        # gemma convention
    sandwich_norm: bool = False           # gemma2 pre+post norms
    tie_embeddings: bool = True
    use_rope: bool = True
    rope_theta: float = 10000.0
    emb_scale: float = 1.0
    residual_scale: float = 1.0           # minicpm scale_depth / sqrt(L)
    logit_softcap: Optional[float] = None
    attn_softcap: Optional[float] = None
    attn_scale: Optional[float] = None
    qk_norm: bool = False
    # windows: `window` applies to all attn layers; local_global_period=2
    # alternates local(window)/global (gemma2, local first)
    window: Optional[int] = None
    local_global_period: Optional[int] = None
    # MLA
    mla: bool = False
    q_lora_rank: Optional[int] = None
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    moe_period: int = 1                   # every Nth layer is MoE
    moe_offset: int = 0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.001
    # Mamba / hybrid
    is_ssm: bool = False                  # all-mamba (falcon)
    attn_period: int = 0                  # jamba: attn layer every N (else mamba)
    attn_offset: int = 4
    d_state: int = 16
    d_conv: int = 4
    mamba_expand: int = 2
    bcdt_rms: bool = False
    ssm_chunk: int = 256
    # enc-dec (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_positions: int = 1500             # whisper 30 s of frames
    # frontend stubs
    frontend: Optional[str] = None        # 'audio' | 'vision'
    n_img_tokens: int = 0                 # vision tokens prepended (llava)
    # scanning / remat
    scan_period: int = 1
    prelude_layers: int = 0
    remat: bool = True
    remat_policy: str = "nothing"         # 'nothing' | 'dots' | 'none'
    # the paper's technique, attachable to every projection
    kratos: kr.KratosSpec = kr.DENSE
    # compute dtypes
    param_dtype: str = "float32"
    dtype: str = "float32"                # activation dtype

    # ---- derived ----
    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def adtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline accounting)."""
        cfg = self
        d, v = cfg.d_model, cfg.vocab
        total = v * d  # embeddings
        if not cfg.tie_embeddings:
            total += v * d
        for i in range(cfg.n_layers):
            kind = layer_kind(cfg, i)
            if kind["mixer"] == "attn":
                if cfg.mla:
                    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
                    if cfg.q_lora_rank:
                        total += d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads * qd
                    else:
                        total += d * cfg.n_heads * qd
                    total += d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                    total += cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
                    total += cfg.n_heads * cfg.v_head_dim * d
                else:
                    total += d * cfg.n_heads * cfg.dh + 2 * d * cfg.n_kv_heads * cfg.dh \
                        + cfg.n_heads * cfg.dh * d
            else:
                di, r, st = cfg.d_inner, max(1, -(-d // 16)), cfg.d_state
                total += d * 2 * di + cfg.d_conv * di + di * (r + 2 * st) \
                    + r * di + di * st + di + di * d
            if kind["ffn"] == "moe":
                total += d * cfg.n_experts  # router
                total += cfg.n_experts * 3 * d * cfg.d_ff_expert
                total += cfg.n_shared_experts * 3 * d * cfg.d_ff_expert
            elif kind["ffn"] == "mlp":
                gated = cfg.gated_mlp if cfg.gated_mlp is not None \
                    else cfg.activation in ("silu", "gelu", "gelu_tanh")
                total += (3 if gated else 2) * d * cfg.d_ff
        if cfg.enc_dec:
            for _ in range(cfg.n_enc_layers):
                total += 4 * d * cfg.n_heads * cfg.dh + 3 * d * cfg.d_ff
            # decoder cross-attn
            total += cfg.n_layers * 4 * d * cfg.n_heads * cfg.dh
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k only) for 6·N_active·D."""
        cfg = self
        if not cfg.n_experts:
            return self.param_count()
        total = self.param_count()
        # subtract inactive routed experts
        for i in range(cfg.n_layers):
            if layer_kind(cfg, i)["ffn"] == "moe":
                inactive = cfg.n_experts - cfg.top_k
                total -= inactive * 3 * cfg.d_model * cfg.d_ff_expert
        return int(total)


# ---------------------------------------------------------------------------
# Per-layer pattern
# ---------------------------------------------------------------------------

def layer_kind(cfg: ModelConfig, i: int) -> Dict[str, Any]:
    """What lives at layer i: mixer ('attn'|'mamba') + window + ffn kind."""
    if cfg.is_ssm:
        mixer = "mamba"
    elif cfg.attn_period:
        mixer = "attn" if i % cfg.attn_period == cfg.attn_offset else "mamba"
    else:
        mixer = "attn"
    window = cfg.window
    if cfg.local_global_period and mixer == "attn":
        window = cfg.window if i % cfg.local_global_period == 0 else None
    if cfg.n_experts and i >= cfg.prelude_layers \
            and i % cfg.moe_period == cfg.moe_offset:
        ffn = "moe"
    elif cfg.n_experts and i < cfg.prelude_layers:
        ffn = "mlp"
    elif mixer == "mamba" and cfg.is_ssm:
        ffn = "none"                       # pure mamba blocks have no FFN
    elif cfg.attn_period and cfg.n_experts:
        ffn = "moe" if i % cfg.moe_period == cfg.moe_offset else "mlp"
    else:
        ffn = "mlp"
    return {"mixer": mixer, "window": window, "ffn": ffn}


def attn_cfg_for(cfg: ModelConfig, kind: Dict) -> A.AttnConfig:
    return A.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.dh, rope_theta=cfg.rope_theta, use_rope=cfg.use_rope,
        causal=True, window=kind.get("window"), softcap=cfg.attn_softcap,
        qk_norm=cfg.qk_norm, attn_scale=cfg.attn_scale, mla=cfg.mla,
        q_lora_rank=cfg.q_lora_rank, kv_lora_rank=cfg.kv_lora_rank,
        qk_nope_dim=cfg.qk_nope_dim, qk_rope_dim=cfg.qk_rope_dim,
        v_head_dim=cfg.v_head_dim)


def moe_cfg_for(cfg: ModelConfig) -> M.MoEConfig:
    return M.MoEConfig(
        d_model=cfg.d_model, n_experts=cfg.n_experts, top_k=cfg.top_k,
        d_ff_expert=cfg.d_ff_expert, n_shared=cfg.n_shared_experts,
        capacity_factor=cfg.capacity_factor, aux_loss_coef=cfg.aux_loss_coef,
        activation=cfg.activation)


def mamba_cfg_for(cfg: ModelConfig) -> S.MambaConfig:
    return S.MambaConfig(
        d_model=cfg.d_model, d_inner=cfg.d_inner, d_state=cfg.d_state,
        d_conv=cfg.d_conv, bcdt_rms=cfg.bcdt_rms, chunk=cfg.ssm_chunk)


def _norm_init(cfg: ModelConfig):
    return (L.layernorm_init if cfg.norm == "layernorm" else L.rmsnorm_init)(
        cfg.d_model, cfg.pdtype())


def _norm(cfg: ModelConfig, p, x):
    if cfg.norm == "layernorm":
        return L.layernorm(p, x, cfg.norm_eps)
    return L.rmsnorm(p, x, cfg.norm_eps, scale_plus_one=cfg.rmsnorm_plus_one)


# ---------------------------------------------------------------------------
# Layer init / apply
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, i: int, cross: bool = False) -> Dict:
    kind = layer_kind(cfg, i)
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"pre_norm": _norm_init(cfg)}
    if cfg.sandwich_norm:
        p["post_norm"] = _norm_init(cfg)
    if kind["mixer"] == "attn":
        p["mixer"] = A.attn_init(ks[0], attn_cfg_for(cfg, kind), cfg.kratos,
                                 cfg.pdtype())
    else:
        p["mixer"] = S.mamba_init(ks[0], mamba_cfg_for(cfg), cfg.kratos,
                                  cfg.pdtype())
    if cross:
        p["cross_norm"] = _norm_init(cfg)
        ccfg = dataclasses.replace(attn_cfg_for(cfg, kind), cross=True,
                                   causal=False, use_rope=False)
        p["cross"] = A.attn_init(ks[1], ccfg, cfg.kratos, cfg.pdtype())
    if kind["ffn"] == "mlp":
        p["ffn_norm"] = _norm_init(cfg)
        if cfg.sandwich_norm:
            p["ffn_post_norm"] = _norm_init(cfg)
        gated = cfg.gated_mlp if cfg.gated_mlp is not None \
            else cfg.activation in ("silu", "gelu", "gelu_tanh")
        p["ffn"] = L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, gated=gated,
                              spec=cfg.kratos, dtype=cfg.pdtype())
    elif kind["ffn"] == "moe":
        p["ffn_norm"] = _norm_init(cfg)
        p["ffn"] = M.moe_init(ks[2], moe_cfg_for(cfg), cfg.kratos, cfg.pdtype())
    return p


def _probe_fanout(cfg: ModelConfig, kind: Dict, site: str) -> int:
    """Dense GEMM fan-out a probed activation feeds (serve.ledger probes:
    the FLOP/byte columns are fan-out-weighted trace-time constants)."""
    if site == "mixer":
        if kind["mixer"] == "mamba":
            return 2 * cfg.d_inner
        if cfg.mla:
            q = cfg.q_lora_rank if cfg.q_lora_rank \
                else cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
            return int(q + cfg.kv_lora_rank + cfg.qk_rope_dim)
        return (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.dh
    if kind["ffn"] == "moe":
        return int(cfg.n_experts
                   + 2 * (cfg.top_k + cfg.n_shared_experts) * cfg.d_ff_expert)
    gated = cfg.gated_mlp if cfg.gated_mlp is not None \
        else cfg.activation in ("silu", "gelu", "gelu_tanh")
    return (2 if gated else 1) * cfg.d_ff


def _layer_apply(p: Dict, x, cfg: ModelConfig, kind: Dict, *, backend="ref",
                 positions=None, cache=None, index=None, enc_out=None,
                 cross_cache=None, pages=None, probe=None):
    """One residual block. Returns (x, aux, new_cache, new_cross_cache).

    pages: page-table operand for native paged decode — consumed by the
    ATTENTION mixer only (mamba state is O(1) resident, cross caches are
    written once at prefill; both keep the slab layout in the page store).
    probe: serve.ledger probe (or None) — taps the normalized mixer/FFN
    GEMM inputs (and, inside attention, the pre-wo merged heads) at trace
    time; the forward drains one summed row per layer.
    """
    aux = jnp.zeros((), jnp.float32)
    rs = jnp.asarray(cfg.residual_scale, x.dtype)
    # batch-pinning constraints are differentiable: the transpose constrains
    # the COTANGENT too, which stops GSPMD from all-gathering the full
    # microbatch in backward dx/dW dots (4.5 GiB/layer on nemotron-340b).
    # 'dm_in' resolves to None in training and to 'data' under the 2D-TP
    # serving rules (weights stay fully sharded; activations psum instead).
    h = L.shard(_norm(cfg, p["pre_norm"], x), "batch", None, "dm_in")
    if probe is not None:
        probe.tap(h, _probe_fanout(cfg, kind, "mixer"))
    new_cache = new_cross = None
    if kind["mixer"] == "attn":
        h, new_cache = A.attn_apply(
            p["mixer"], h, attn_cfg_for(cfg, kind), spec=cfg.kratos,
            backend=backend, positions=positions, cache=cache, index=index,
            pages=pages, probe=probe)
    else:
        h, new_cache = S.mamba_apply(
            p["mixer"], h, mamba_cfg_for(cfg), spec=cfg.kratos,
            backend=backend, cache=cache, index=index)
    if cfg.sandwich_norm:
        h = _norm(cfg, p["post_norm"], h)
    x = x + h * rs
    if "cross" in p:
        h = L.shard(_norm(cfg, p["cross_norm"], x), "batch", None, "dm_in")
        ccfg = dataclasses.replace(attn_cfg_for(cfg, kind), cross=True,
                                   causal=False, use_rope=False)
        h, new_cross = A.attn_apply(
            p["cross"], h, ccfg, spec=cfg.kratos, backend=backend,
            kv_source=enc_out, cache=cross_cache, index=index, probe=probe)
        x = x + h * rs
    if kind["ffn"] != "none":
        h = L.shard(_norm(cfg, p["ffn_norm"], x), "batch", None, "dm_in")
        if probe is not None:
            probe.tap(h, _probe_fanout(cfg, kind, "ffn"))
        if kind["ffn"] == "moe":
            h, aux = M.moe_apply(p["ffn"], h, moe_cfg_for(cfg),
                                 spec=cfg.kratos, backend=backend)
        else:
            h = L.mlp_apply(p["ffn"], h, activation=cfg.activation,
                            spec=cfg.kratos, backend=backend, probe=probe)
        if cfg.sandwich_norm:
            h = _norm(cfg, p["ffn_post_norm"], h)
        x = x + h * rs
    # 'seq_res' = sequence-sharded residual stream (SP): the remat carry that
    # lives across the whole layer scan is sharded over the 'model' axis, which
    # is what fits 96-layer x 4k-seq saved activations in 16 GB/chip. The cast
    # keeps the carry in the activation dtype — mixed-precision dots upcast to
    # f32 and the saved stack must not inherit that (2x remat memory).
    x = L.shard(x.astype(cfg.adtype()), "batch", "seq_res", None)
    return x, aux, new_cache, new_cross


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def _stack_layers(layer_params: List[Dict]) -> Dict:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layer_params)


def init(key, cfg: ModelConfig) -> Dict:
    """Build the full parameter tree."""
    n, period, prelude = cfg.n_layers, cfg.scan_period, cfg.prelude_layers
    if (n - prelude) % period:
        raise ValueError(f"(n_layers - prelude) = {n - prelude} not divisible "
                         f"by scan_period {period}")
    # pattern periodicity sanity: every scanned layer must match its slot
    for i in range(prelude, n):
        slot = (i - prelude) % period
        if layer_kind(cfg, i) != layer_kind(cfg, prelude + slot):
            raise ValueError(
                f"layer {i} kind {layer_kind(cfg, i)} != slot {slot} kind "
                f"{layer_kind(cfg, prelude + slot)}; adjust scan_period")
    keys = jax.random.split(key, n + cfg.n_enc_layers + 4)
    params: Dict[str, Any] = {
        "embed": L.embedding_init(keys[-1], cfg.vocab, cfg.d_model, cfg.pdtype()),
        "final_norm": _norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        params["head"] = kr.init(keys[-2], cfg.d_model, cfg.vocab, kr.DENSE,
                                 cfg.pdtype())
    cross = cfg.enc_dec
    params["prelude"] = [
        _layer_init(keys[i], cfg, i, cross) for i in range(prelude)]
    n_periods = (n - prelude) // period
    slots = []
    for s in range(period):
        layer_ids = [prelude + t * period + s for t in range(n_periods)]
        slots.append(_stack_layers(
            [_layer_init(keys[i], cfg, i, cross) for i in layer_ids]))
    params["blocks"] = slots
    if cfg.enc_dec:
        ek = keys[n:n + cfg.n_enc_layers]
        enc_cfg = dataclasses.replace(
            cfg, mla=False, is_ssm=False, attn_period=0, n_experts=0,
            use_rope=False)
        enc_layers = []
        for i in range(cfg.n_enc_layers):
            lp = _layer_init(ek[i], enc_cfg, i, cross=False)
            enc_layers.append(lp)
        params["enc_blocks"] = _stack_layers(enc_layers)
        params["enc_norm"] = _norm_init(cfg)
    return params


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _remat_wrap(cfg: ModelConfig, fn):
    if not cfg.remat or cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def _sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / d))
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def encode(params, frames: jnp.ndarray, cfg: ModelConfig, *, backend="ref"):
    """Whisper encoder: frames are stub frame-embeddings (B, S_enc, d)."""
    x = frames.astype(cfg.adtype())
    x = x + _sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    enc_cfg = dataclasses.replace(cfg, mla=False, is_ssm=False, attn_period=0,
                                  n_experts=0, use_rope=False)
    kind = {"mixer": "attn", "window": None, "ffn": "mlp"}

    def body(x, lp):
        acfg = dataclasses.replace(attn_cfg_for(enc_cfg, kind), causal=False)
        h = _norm(cfg, lp["pre_norm"], x)
        h, _ = A.attn_apply(lp["mixer"], h, acfg, spec=cfg.kratos,
                            backend=backend)
        x = x + h
        h = _norm(cfg, lp["ffn_norm"], x)
        h = L.mlp_apply(lp["ffn"], h, activation=cfg.activation,
                        spec=cfg.kratos, backend=backend)
        return x + h, None

    x, _ = jax.lax.scan(_remat_wrap(cfg, body), x, params["enc_blocks"])
    return _norm(cfg, params["enc_norm"], x)


def forward(params, tokens: jnp.ndarray, cfg: ModelConfig, *, backend="ref",
            img_embeds=None, enc_out=None, caches=None, index=None,
            last_only: bool = False, pages=None, probe=None,
            ) -> Tuple[jnp.ndarray, ...]:
    """Decoder forward. tokens: (B, S_text). Returns (logits, aux, caches) —
    or (logits, aux, caches, probe_mat) when `probe` is passed.

    img_embeds: (B, n_img, d) vision-stub tokens prepended (llava).
    enc_out: (B, S_enc, d) encoder output for cross-attention (whisper).
    caches: pytree matching params['prelude'/'blocks'] (+ 'cross') or None.
    index: decode position (None = full-sequence). A scalar decodes the
    whole batch at one shared position (lock-step serving); a (B,) vector
    gives every batch row its own position — the continuous-batching slab
    decode, where requests at different depths share one step.
    last_only: compute logits only for the final position (prefill) — the
    (B, S, vocab) logits tensor is by far the largest in a 32k prefill, and
    only the last column is consumed.
    pages: native paged-decode operand ({'table': (B, pp) int32 page table,
    'size': page_size, 'len': cache_len}); with it, `caches`' positional
    attention leaves are PAGE-MAJOR store leaves (serve.paging
    PageLayout.as_tree) that the attention layers read/write through the
    table — no slab view is ever materialized. Requires `index` (decode).
    probe: serve.ledger.LedgerProbe (or None). With a probe, every layer's
    GEMM taps sum into one (probe.cfg.width,) row; prelude rows collect in
    Python, scanned rows exit the layer scan as stacked ys, and the rows
    assemble into an (n_layers, width) matrix appended to the return tuple.
    Layer order is TRUE model order: scanned slot s, period t is layer
    `prelude + t * scan_period + s`.
    """
    x = L.embed(params["embed"], tokens, scale=cfg.emb_scale).astype(cfg.adtype())
    if img_embeds is not None:
        x = jnp.concatenate([img_embeds.astype(x.dtype), x], axis=1)
    if cfg.enc_dec and not cfg.use_rope:
        s = x.shape[1]
        pe = _sinusoidal_positions(32768 if index is not None else s,
                                   cfg.d_model).astype(x.dtype)
        if index is None:
            x = x + pe[:s]
        elif jnp.ndim(index) == 0:
            x = x + jax.lax.dynamic_slice_in_dim(pe, index, s, axis=0)
        else:                       # per-slot positions: gather (B, S, d)
            x = x + jnp.take(pe, A._positions_for(index, s), axis=0)
    positions = None if index is None else A._positions_for(index, x.shape[1])
    aux_total = jnp.zeros((), jnp.float32)

    new_caches: Optional[Dict] = None if caches is None else \
        {"prelude": [], "blocks": [None] * cfg.scan_period}

    prelude_rows: List[jnp.ndarray] = []

    # prelude layers (unscanned)
    for li, lp in enumerate(params["prelude"]):
        kind = layer_kind(cfg, li)
        c = caches["prelude"][li] if caches is not None else None
        cc = c.get("cross") if (c is not None and "cross" in c) else None
        mc = c.get("mixer") if c is not None else None
        x, aux, nm, ncr = _layer_apply(
            lp, x, cfg, kind, backend=backend, positions=positions,
            cache=mc, index=index, enc_out=enc_out, cross_cache=cc,
            pages=pages, probe=probe)
        aux_total += aux
        if probe is not None:
            prelude_rows.append(probe.layer_row())
        if caches is not None:
            entry = {"mixer": nm}
            if ncr is not None:
                entry["cross"] = ncr
            new_caches["prelude"].append(entry)

    # scanned periodic blocks
    n_periods = (cfg.n_layers - cfg.prelude_layers) // cfg.scan_period
    slot_rows: List[jnp.ndarray] = []
    for slot in range(cfg.scan_period):
        kind = layer_kind(cfg, cfg.prelude_layers + slot)
        stacked = params["blocks"][slot]
        c_stack = caches["blocks"][slot] if caches is not None else None

        def body(carry, xs, _kind=kind):
            x, aux = carry
            if caches is not None:
                lp, cache_sl = xs
                mc = cache_sl.get("mixer")
                cc = cache_sl.get("cross")
            else:
                lp, mc, cc = xs, None, None
            x, a, nm, ncr = _layer_apply(
                lp, x, cfg, _kind, backend=backend, positions=positions,
                cache=mc, index=index, enc_out=enc_out, cross_cache=cc,
                pages=pages, probe=probe)
            out = None
            if caches is not None:
                out = {"mixer": nm}
                if ncr is not None:
                    out["cross"] = ncr
            if probe is not None:
                out = (out, probe.layer_row())   # row exits via scan ys
            return (x, aux + a), out

        xs = (stacked, c_stack) if caches is not None else stacked
        (x, aux_total), new_stack = jax.lax.scan(
            _remat_wrap(cfg, body), (x, aux_total), xs)
        if probe is not None:
            new_stack, rows = new_stack          # (n_periods, width)
            slot_rows.append(rows)
        if caches is not None:
            new_caches["blocks"][slot] = new_stack

    if last_only:
        x = x[:, -1:]
    x = _norm(cfg, params["final_norm"], x)
    x = L.shard(x, "batch", "seq", None)
    logits = L.unembed(params["embed"], x, params.get("head"),
                       softcap=cfg.logit_softcap)
    if probe is None:
        return logits, aux_total, new_caches
    # assemble the per-layer probe matrix in true layer order
    mat = jnp.zeros((cfg.n_layers, probe.cfg.width), jnp.float32)
    for li, row in enumerate(prelude_rows):
        mat = mat.at[li].set(row)
    for slot, rows in enumerate(slot_rows):
        ids = cfg.prelude_layers + slot \
            + cfg.scan_period * jnp.arange(n_periods)
        mat = mat.at[ids].set(rows)
    return logits, aux_total, new_caches, mat


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def make_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.float32) -> Dict:
    """Decode caches matching the params tree layout (prelude + stacked)."""
    def one(i: int) -> Dict:
        kind = layer_kind(cfg, i)
        if kind["mixer"] == "attn":
            mc = A.make_cache(attn_cfg_for(cfg, kind), batch, max_len, dtype)
        else:
            mc = S.make_mamba_cache(mamba_cfg_for(cfg), batch, dtype)
        entry = {"mixer": mc}
        if cfg.enc_dec:
            entry["cross"] = {
                "k": jnp.zeros((batch, cfg.n_kv_heads, cfg.enc_positions, cfg.dh), dtype),
                "v": jnp.zeros((batch, cfg.n_kv_heads, cfg.enc_positions, cfg.dh), dtype),
            }
        return entry

    prelude = [one(i) for i in range(cfg.prelude_layers)]
    n_periods = (cfg.n_layers - cfg.prelude_layers) // cfg.scan_period
    blocks = []
    for s in range(cfg.scan_period):
        ids = [cfg.prelude_layers + t * cfg.scan_period + s
               for t in range(n_periods)]
        blocks.append(_stack_layers([one(i) for i in ids]))
    return {"prelude": prelude, "blocks": blocks}


# ---------------------------------------------------------------------------
# Sampling (device-side; fused into the serving decode step)
# ---------------------------------------------------------------------------

def sample_tokens(logits: jnp.ndarray, key, temperature: jnp.ndarray,
                  ) -> jnp.ndarray:
    """Per-row Gumbel-max / greedy sampling, fully on device.

    logits: (B, vocab); temperature: (B,) float32 per-slot vector (<= 0 means
    greedy argmax for that row — no rng consumed semantics: the key is split
    by the caller per micro-step regardless, which is what makes K-step decode
    blocks reproducible for any K). Returns (B,) int32 token ids.

    Gumbel-max sampling (argmax(logits/T + G)) is exactly categorical
    sampling from softmax(logits/T), so the full-vocab softmax never needs to
    be materialized and only the sampled ids ever cross to the host.
    """
    logits = logits.astype(jnp.float32)
    temperature = jnp.asarray(temperature, jnp.float32)
    g = jax.random.gumbel(key, logits.shape, jnp.float32)
    safe_t = jnp.maximum(temperature, 1e-6)[:, None]
    scores = jnp.where((temperature > 0.0)[:, None],
                       logits / safe_t + g, logits)
    return jnp.argmax(scores, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def lm_loss(logits: jnp.ndarray, labels: jnp.ndarray,
            mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean cross-entropy; labels (B, S) int32; mask (B, S) optional."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
