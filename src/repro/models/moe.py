"""Mixture-of-Experts: shared + routed experts with top-k capacity routing.

Covers deepseek-moe-16b / deepseek-v2-lite (2 shared + 64 routed, top-6,
fine-grained experts) and jamba (16 routed, top-2, no shared).

Dispatch is **grouped sort-based** (GShard-style groups, static shapes,
EP-friendly):

  * tokens are routed in groups — one group per batch row for full
    sequences (so the argsort/searchsorted run *locally* per data shard; no
    distributed sort in the SPMD partition), or a single global group for
    decode steps (S=1, where per-row groups would waste E*C slots per
    token);
  * within a group: top-k gate -> stable sort by expert id -> each expert
    takes its contiguous run up to capacity C = ceil(G*k/E * factor);
    overflow tokens drop (residual passes through);
  * expert batches (E, C, d) are einsum'd against expert weights with E
    sharded over the 'expert' (model) mesh axis — XLA inserts the
    dispatch/combine all-to-alls;
  * combine: weighted scatter-add back to token order.

Expert FFN projections are Kratos-able: with a KratosSpec attached, every
expert's gate/up/down GEMM runs block-sparse/quantized (same plan across
experts, different learned values).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import kratos as kr
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_norm_topk: bool = True       # normalize top-k weights (deepseek)
    aux_loss_coef: float = 0.001
    activation: str = "silu"


def moe_init(key, cfg: MoEConfig, spec: kr.KratosSpec = kr.DENSE,
             dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 5)
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    std = d ** -0.5
    p = {
        "router": {"w": jax.random.normal(ks[0], (d, e), jnp.float32) * std},
        "w_gate": jax.random.normal(ks[1], (e, d, f), dtype) * std,
        "w_up": jax.random.normal(ks[2], (e, d, f), dtype) * std,
        "w_down": jax.random.normal(ks[3], (e, f, d), dtype) * (f ** -0.5),
    }
    if cfg.n_shared:
        p["shared"] = L.mlp_init(ks[4], d, cfg.n_shared * f, gated=True,
                                 spec=spec, dtype=dtype)
    return p


def capacity(cfg: MoEConfig, group_tokens: int) -> int:
    c = int(-(-group_tokens * cfg.top_k // cfg.n_experts)
            * cfg.capacity_factor)
    return max(cfg.top_k, min(c, group_tokens))


def _expert_ffn(p, xe: jnp.ndarray, cfg: MoEConfig, spec: kr.KratosSpec,
                backend: str) -> jnp.ndarray:
    """xe: (G, E, C, d) -> (G, E, C, d). Kratos-sparse when spec set."""
    act = L.ACTIVATIONS[cfg.activation]
    tree = (not spec.is_identity and spec.impl == "tree"
            and kr.plan_for(cfg.d_model, cfg.d_ff_expert, spec) is not None)
    if not tree:
        g = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(xe.dtype))
        u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(xe.dtype))
        h = act(g) * u
        h = L.shard(h, None, "expert", None, "ffn")
        return jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(xe.dtype))

    # tree path: vmap the Kratos gathered-block matmul over experts
    def one(we_gate, we_up, we_down, xx):      # xx: (G, C, d)
        g = kr.apply({"w": we_gate}, xx, spec, backend=backend)
        u = kr.apply({"w": we_up}, xx, spec, backend=backend)
        h = act(g) * u
        return kr.apply({"w": we_down}, h, spec, backend=backend)

    out = jax.vmap(one, in_axes=(0, 0, 0, 1), out_axes=1)(
        p["w_gate"], p["w_up"], p["w_down"], xe)
    return out


def _route_group(xf, router_w, cfg: MoEConfig, c: int):
    """One routing group. xf: (G, d). Returns dispatch data + aux stats."""
    g_tokens = xf.shape[0]
    e, k = cfg.n_experts, cfg.top_k
    logits = xf.astype(jnp.float32) @ router_w                  # (G, E)
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(gates, k)                      # (G, k)
    if cfg.router_norm_topk:
        top_w = top_w / (jnp.sum(top_w, axis=-1, keepdims=True) + 1e-9)

    flat_e = top_e.reshape(-1)                                  # (G*k,)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e))
    pos_in_e = jnp.arange(g_tokens * k) - starts[sorted_e]
    keep = pos_in_e < c
    slot = jnp.where(keep, sorted_e * c + pos_in_e, e * c)

    slot_to_assign = jnp.full((e * c + 1,), g_tokens * k, jnp.int32)
    slot_to_assign = slot_to_assign.at[slot].set(order.astype(jnp.int32))
    slot_assign = slot_to_assign[:e * c]
    slot_valid = slot_assign < g_tokens * k
    slot_token = jnp.where(slot_valid, slot_assign // k, 0)
    slot_weight = jnp.where(
        slot_valid, flat_w[jnp.where(slot_valid, slot_assign, 0)], 0.0)

    dispatch_frac = jnp.mean(
        jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=(0, 1)) * k
    gate_frac = jnp.mean(gates, axis=0)
    return slot_token, slot_weight, slot_valid, dispatch_frac, gate_frac


def moe_apply(params: Dict, x: jnp.ndarray, cfg: MoEConfig, *,
              spec: kr.KratosSpec = kr.DENSE, backend: str = "ref",
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss)."""
    b, s, d = x.shape
    e = cfg.n_experts
    # group = batch row for sequences (local sort per data shard);
    # single global group for decode (S == 1).
    if s > 1:
        n_groups, g_tokens = b, s
    else:
        n_groups, g_tokens = 1, b * s
    c = capacity(cfg, g_tokens)
    xg = x.reshape(n_groups, g_tokens, d)

    slot_token, slot_weight, slot_valid, dfrac, gfrac = jax.vmap(
        lambda xf: _route_group(xf, params["router"]["w"], cfg, c))(xg)

    aux = cfg.aux_loss_coef * e * jnp.mean(
        jnp.sum(dfrac * gfrac, axis=-1))

    # dispatch: (G, E*C, d)
    xe = jnp.take_along_axis(xg, slot_token[..., None], axis=1)
    xe = xe * slot_valid[..., None].astype(xe.dtype)
    xe = xe.reshape(n_groups, e, c, d)
    xe = L.shard(xe, None, "expert", None, None)

    ye = _expert_ffn(params, xe, cfg, spec, backend)            # (G,E,C,d)
    ye = L.shard(ye, None, "expert", None, None)

    # combine: weighted scatter-add back to token order
    contrib = ye.reshape(n_groups, e * c, d) \
        * slot_weight[..., None].astype(x.dtype)
    tgt = jnp.where(slot_valid, slot_token, g_tokens)           # drop slot
    yg = jnp.zeros((n_groups, g_tokens, d), x.dtype)
    yg = jax.vmap(lambda acc, idx, val: acc.at[idx].add(val, mode="drop"))(
        yg, tgt, contrib)
    y = yg.reshape(b, s, d)

    if cfg.n_shared:
        y = y + L.mlp_apply(params["shared"], x, activation=cfg.activation,
                            spec=spec, backend=backend)
    return y, aux


def moe_ref(params: Dict, x: jnp.ndarray, cfg: MoEConfig) -> jnp.ndarray:
    """Dense per-token oracle (no capacity drops) for unit tests."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ params["router"]["w"]
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(gates, cfg.top_k)
    if cfg.router_norm_topk:
        top_w = top_w / (jnp.sum(top_w, axis=-1, keepdims=True) + 1e-9)
    act = L.ACTIVATIONS[cfg.activation]

    def ffn_e(eid, xx):
        g = xx @ params["w_gate"][eid].astype(xx.dtype)
        u = xx @ params["w_up"][eid].astype(xx.dtype)
        return (act(g) * u) @ params["w_down"][eid].astype(xx.dtype)

    all_out = jnp.stack([ffn_e(i, xf) for i in range(cfg.n_experts)])  # (E,T,d)
    sel = all_out[top_e, jnp.arange(xf.shape[0])[:, None]]             # (T,k,d)
    yf = jnp.sum(sel * top_w[..., None].astype(x.dtype), axis=1)
    y = yf.reshape(b, s, d)
    if cfg.n_shared:
        y = y + L.mlp_apply(params["shared"], x, activation=cfg.activation)
    return y
