"""Mamba-1 selective state-space mixer (falcon-mamba-7b, jamba).

The recurrence  h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t ;  y_t = C_t h_t
+ D x_t  is evaluated with a *chunked associative scan*: the sequence is cut
into chunks of `chunk` tokens; within a chunk we use
`jax.lax.associative_scan` over the (decay, update) monoid, and chunk carries
propagate through an outer `lax.scan`. This bounds the materialized state
tensor to (B, chunk, d_inner, d_state) — without chunking, a 4k-token
training step of falcon-mamba would materialize ~17 GB of scan states per
device.

Decode is the O(1) single-step recurrence on a (B, d_inner, d_state) state +
a (B, d_conv-1, d_inner) conv tail — this is why the long_500k cell is
trivially feasible for SSM archs (DESIGN.md §shape-cell skips).

falcon-mamba adds RMSNorm on (B, C, dt) streams (`bcdt_rms=True`).

The in/out/x/dt projections are plain GEMMs and therefore Kratos-able; the
recurrence itself has no weight matrix to sparsify (DESIGN.md
§Arch-applicability).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import kratos as kr
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_inner: int
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0            # 0 -> ceil(d_model / 16)
    bcdt_rms: bool = False      # falcon-mamba
    chunk: int = 256

    @property
    def rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)


def mamba_init(key, cfg: MambaConfig, spec: kr.KratosSpec = kr.DENSE,
               dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 6)
    d, di, st, r = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.rank
    p = {
        "in_proj": kr.init(ks[0], d, 2 * di, spec, dtype),
        "conv_w": jax.random.normal(ks[1], (cfg.d_conv, di), dtype) * 0.2,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": kr.init(ks[2], di, r + 2 * st, spec, dtype),
        "dt_proj": {"w": jax.random.normal(ks[3], (r, di), dtype) * (r ** -0.5),
                    "b": jnp.log(jnp.expm1(jnp.full((di,), 0.01, dtype)))},
        "A_log": jnp.log(jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None],
                                  (di, 1))),
        "D": jnp.ones((di,), dtype),
        "out_proj": kr.init(ks[4], di, d, spec, dtype),
    }
    if cfg.bcdt_rms:
        p["b_norm"] = L.rmsnorm_init(st, dtype)
        p["c_norm"] = L.rmsnorm_init(st, dtype)
        p["dt_norm"] = L.rmsnorm_init(r, dtype)
    return p


def _depthwise_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                    tail: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Causal depthwise conv1d. u: (B, S, di); w: (K, di); tail: (B, K-1, di)."""
    k = w.shape[0]
    pad = tail if tail is not None else jnp.zeros(
        (u.shape[0], k - 1, u.shape[2]), u.dtype)
    up = jnp.concatenate([pad, u], axis=1)                  # (B, S+K-1, di)
    out = sum(up[:, i:i + u.shape[1]] * w[i] for i in range(k))
    return out + b


def _ssm_params(params, u, cfg: MambaConfig, spec, backend):
    """u: (B, S, di) -> dt (B,S,di), B_ (B,S,st), C_ (B,S,st)."""
    st, r = cfg.d_state, cfg.rank
    xdbc = kr.apply(params["x_proj"], u, spec, backend=backend)
    dt_in, b_, c_ = jnp.split(xdbc, [r, r + st], axis=-1)
    if cfg.bcdt_rms:
        dt_in = L.rmsnorm(params["dt_norm"], dt_in)
        b_ = L.rmsnorm(params["b_norm"], b_)
        c_ = L.rmsnorm(params["c_norm"], c_)
    dt = jax.nn.softplus(dt_in @ params["dt_proj"]["w"].astype(u.dtype)
                         + params["dt_proj"]["b"].astype(u.dtype))
    return dt, b_, c_


def _scan_chunked(dA, dBx, cfg: MambaConfig):
    """dA, dBx: (B, S, di, st) -> h: (B, S, di, st) via chunked assoc scan."""
    b, s, di, st = dA.shape
    ck = min(cfg.chunk, s)
    n_chunks = s // ck
    rem = s - n_chunks * ck

    def combine(a, b_):
        (a1, b1), (a2, b2) = a, b_
        return a1 * a2, b1 * a2 + b2

    def chunk_step(h0, xs):
        da, dbx = xs                                        # (B, ck, di, st)
        acc_a, acc_b = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        h = acc_a * h0[:, None] + acc_b                     # prefix-applied
        return h[:, -1], h

    if n_chunks:
        da_c = dA[:, :n_chunks * ck].reshape(b, n_chunks, ck, di, st)
        dbx_c = dBx[:, :n_chunks * ck].reshape(b, n_chunks, ck, di, st)
        h_last, hs = jax.lax.scan(
            chunk_step, jnp.zeros((b, di, st), dA.dtype),
            (da_c.transpose(1, 0, 2, 3, 4), dbx_c.transpose(1, 0, 2, 3, 4)))
        h = hs.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * ck, di, st)
    else:
        h_last = jnp.zeros((b, di, st), dA.dtype)
        h = jnp.zeros((b, 0, di, st), dA.dtype)
    if rem:
        _, h_tail = chunk_step(h_last, (dA[:, -rem:], dBx[:, -rem:]))
        h = jnp.concatenate([h, h_tail], axis=1)
    return h


def mamba_apply(params, x, cfg: MambaConfig, *, spec=kr.DENSE, backend="ref",
                cache: Optional[Dict] = None, index=None,
                ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """x: (B, S, d). cache: {'conv': (B,K-1,di), 'ssm': (B,di,st)} for decode."""
    b, s, d = x.shape
    di, st = cfg.d_inner, cfg.d_state
    ug = kr.apply(params["in_proj"], x, spec, backend=backend)
    u, gate = jnp.split(ug, 2, axis=-1)                     # (B,S,di) each

    decode = cache is not None and index is not None
    conv_tail = cache["conv"] if decode else None
    u_conv = _depthwise_conv(u, params["conv_w"].astype(u.dtype),
                             params["conv_b"].astype(u.dtype), conv_tail)
    u_act = jax.nn.silu(u_conv)
    u_act = L.shard(u_act, "batch", "seq", "ffn")

    dt, b_, c_ = _ssm_params(params, u_act, cfg, spec, backend)
    A = -jnp.exp(params["A_log"]).astype(jnp.float32)       # (di, st)
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)     # (B,S,di,st)
    dBx = (dt.astype(jnp.float32) * u_act.astype(jnp.float32))[..., None] \
        * b_.astype(jnp.float32)[:, :, None, :]             # (B,S,di,st)

    new_cache = None
    if decode:
        assert s == 1
        h = dA[:, 0] * cache["ssm"] + dBx[:, 0]             # (B,di,st)
        new_conv = jnp.concatenate([cache["conv"][:, 1:], u[:, :1]], axis=1) \
            if cfg.d_conv > 1 else cache["conv"]
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "ssm": h.astype(cache["ssm"].dtype)}
        y = jnp.einsum("bds,bs->bd", h, c_[:, 0].astype(jnp.float32))[:, None]
    else:
        kernel_ok = (backend in ("pallas", "interpret")
                     and di % 8 == 0 and s % 4 == 0)
        if kernel_ok:
            # fused Pallas path: the recurrence state stays in VMEM and the
            # (B,S,di,st) state tensor never touches HBM (EXPERIMENTS §H4)
            from repro.kernels import ops as kops
            bd = 128 if di % 128 == 0 else 8
            ck = 16 if s % 16 == 0 else 4
            y32, h_last = kops.ssm_scan(
                u_act.astype(jnp.float32), dt.astype(jnp.float32),
                b_.astype(jnp.float32), c_.astype(jnp.float32), A,
                backend=backend, bd=bd, ck=ck)
            y = y32
            if cache is not None:
                h = h_last[:, None]                         # (B,1,di,st)
        else:
            h = _scan_chunked(dA, dBx, cfg)                 # (B,S,di,st)
            y = jnp.einsum("bsdn,bsn->bsd", h, c_.astype(jnp.float32))
        if cache is not None:  # prefill: save final state + conv tail
            tail = jnp.concatenate(
                [jnp.zeros((b, max(0, cfg.d_conv - 1 - s), di), u.dtype),
                 u[:, -(cfg.d_conv - 1):]], axis=1) if cfg.d_conv > 1 else \
                jnp.zeros((b, 0, di), u.dtype)
            new_cache = {"conv": tail.astype(cache["conv"].dtype),
                         "ssm": h[:, -1].astype(cache["ssm"].dtype)}
    y = y.astype(x.dtype) + u_act * params["D"].astype(x.dtype)
    y = y * jax.nn.silu(gate)
    out = kr.apply(params["out_proj"], y, spec, backend=backend)
    out = L.shard(out, "batch", None, "dm_in")   # see layers.mlp_apply note
    return out, new_cache


def make_mamba_cache(cfg: MambaConfig, batch: int, dtype=jnp.float32) -> Dict:
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.d_state), dtype),
    }


def mamba_scan_ref(dA, dBx):
    """Naive sequential recurrence oracle for tests. (B,S,di,st) -> same."""
    def step(h, xs):
        da, dbx = xs
        h = da * h + dbx
        return h, h
    b, s, di, st = dA.shape
    _, hs = jax.lax.scan(step, jnp.zeros((b, di, st), dA.dtype),
                         (dA.transpose(1, 0, 2, 3), dBx.transpose(1, 0, 2, 3)))
    return hs.transpose(1, 0, 2, 3)
