"""Modality frontends — STUBS per the brief.

The [audio] and [vlm] assigned architectures specify the transformer
BACKBONE; `input_specs()` provides precomputed frame/patch embeddings, and
these helpers generate such embeddings from raw-ish inputs so examples and
tests have something concrete to feed:

  * whisper: raw waveform -> log-mel-ish frames -> (B, 1500, d) embeddings
    via a FIXED seeded projection (stands in for the two conv1d layers);
  * llava-next anyres: image -> 5 tiles x 576 patches -> (B, 2880, d)
    embeddings via a fixed seeded projection (stands in for CLIP-ViT +
    the multimodal projector).

They are deterministic, shape-faithful, and cheap — NOT trained vision or
audio towers. DESIGN.md §arch mapping records this as an explicit stub.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

WHISPER_FRAMES = 1500        # 30 s at 50 fps after the conv stride-2
LLAVA_TILES = 5              # anyres: 4 crops + 1 downscaled overview
LLAVA_PATCHES_PER_TILE = 576  # 24 x 24 at patch 14 on 336px tiles


def _fixed_projection(seed: int, d_in: int, d_out: int) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([seed, d_in, d_out]))
    return (rng.standard_normal((d_in, d_out)) / np.sqrt(d_in)).astype(
        np.float32)


def whisper_frames(waveform: np.ndarray, d_model: int,
                   n_mels: int = 128) -> jnp.ndarray:
    """waveform: (B, T) float. Returns (B, 1500, d_model) frame embeddings."""
    b, t = waveform.shape
    hop = max(1, t // WHISPER_FRAMES)
    frames = waveform[:, :hop * WHISPER_FRAMES].reshape(
        b, WHISPER_FRAMES, hop)
    # crude energy features standing in for the log-mel filterbank
    feats = np.stack([
        np.log1p(np.abs(frames)).mean(-1),
        frames.std(-1),
        frames.max(-1),
        frames.min(-1),
    ], axis=-1).astype(np.float32)                       # (B, 1500, 4)
    feats = np.repeat(feats, n_mels // 4, axis=-1)       # (B, 1500, n_mels)
    proj = _fixed_projection(0xA0D10, n_mels, d_model)
    return jnp.asarray(feats @ proj)


def llava_patches(image: np.ndarray, d_model: int) -> jnp.ndarray:
    """image: (B, H, W, 3) float in [0,1]. Returns (B, 2880, d) embeddings.

    Anyres tiling is simulated: the image is resized (strided) into 5 tiles
    of 24x24 patch grids; each patch's mean colour + position becomes the
    feature vector fed to the fixed projection.
    """
    b, h, w, _ = image.shape
    grid = 24
    feats = []
    for tile in range(LLAVA_TILES):
        # tile 0..3: quadrants; tile 4: whole image
        if tile < 4:
            ys = slice((tile // 2) * h // 2, (tile // 2 + 1) * h // 2)
            xs = slice((tile % 2) * w // 2, (tile % 2 + 1) * w // 2)
            sub = image[:, ys, xs]
        else:
            sub = image
        sh, sw = sub.shape[1] // grid, sub.shape[2] // grid
        sub = sub[:, :sh * grid, :sw * grid]
        patches = sub.reshape(b, grid, sh, grid, sw, 3).mean((2, 4))
        pos = np.stack(np.meshgrid(np.linspace(0, 1, grid),
                                   np.linspace(0, 1, grid),
                                   indexing="ij"), -1)
        f = np.concatenate([patches,
                            np.broadcast_to(pos, (b, grid, grid, 2)),
                            np.full((b, grid, grid, 1), tile / 4.0)], -1)
        feats.append(f.reshape(b, grid * grid, 6))
    feats = np.concatenate(feats, axis=1).astype(np.float32)  # (B, 2880, 6)
    proj = _fixed_projection(0x11A7A, 6, d_model)
    return jnp.asarray(feats @ proj)
