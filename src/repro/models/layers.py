"""Shared model building blocks: norms, RoPE, MLPs, embeddings.

Every weight-stationary projection goes through `core.kratos`, so any layer
can be made block-sparse / low-precision by attaching a KratosSpec in the
model config — the paper's technique as a cross-cutting feature.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import kratos as kr


# ---------------------------------------------------------------------------
# Sharding helper: models annotate activations with *logical* axes; the
# distributed runtime installs a resolver from logical -> mesh axes. On a
# bare CPU (smoke tests) the resolver is absent and this is the identity.
# ---------------------------------------------------------------------------

_LOGICAL_RESOLVER = None  # set by repro.distributed.sharding.use_mesh(...)


def set_logical_resolver(fn) -> None:
    global _LOGICAL_RESOLVER
    _LOGICAL_RESOLVER = fn


def shard(x: jnp.ndarray, *logical_axes: Optional[str]) -> jnp.ndarray:
    if _LOGICAL_RESOLVER is None:
        return x
    return _LOGICAL_RESOLVER(x, logical_axes)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32) -> Dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Dict, x: jnp.ndarray, eps: float = 1e-6,
            scale_plus_one: bool = False) -> jnp.ndarray:
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    h = h * jax.lax.rsqrt(var + eps)
    s = params["scale"].astype(jnp.float32)
    if scale_plus_one:   # gemma-style (weights stored as deltas from 1)
        s = s + 1.0
    return (h * s).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32) -> Dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    h = x.astype(jnp.float32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(h - mu), axis=-1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + eps)
    out = h * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (B, H, S, Dh) (Dh even); positions: (S,) or (B, S) absolute."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                       # (dh/2,)
    pos = positions.astype(jnp.float32)
    ang = pos[..., :, None] * inv                     # (..., S, dh/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    if ang.ndim == 2:                                 # (S, dh/2) -> (1,1,S,dh/2)
        sin, cos = sin[None, None], cos[None, None]
    else:                                             # (B, S, dh/2) -> (B,1,S,dh/2)
        sin, cos = sin[:, None], cos[:, None]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated and plain), with Kratos-able projections
# ---------------------------------------------------------------------------

ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),     # nemotron squared-ReLU
    "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
}


def mlp_init(key, d: int, d_ff: int, *, gated: bool = True,
             spec: kr.KratosSpec = kr.DENSE, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 3)
    p = {}
    if gated:
        p["w_gate"] = kr.init(ks[0], d, d_ff, spec, dtype)
        p["w_up"] = kr.init(ks[1], d, d_ff, spec, dtype)
    else:
        p["w_up"] = kr.init(ks[1], d, d_ff, spec, dtype)
    p["w_down"] = kr.init(ks[2], d_ff, d, spec, dtype)
    return p


def mlp_apply(params: Dict, x: jnp.ndarray, *, activation: str = "silu",
              spec: kr.KratosSpec = kr.DENSE, backend: str = "ref",
              probe=None) -> jnp.ndarray:
    act = ACTIVATIONS[activation]
    up = kr.apply(params["w_up"], x, spec, backend=backend)
    if "w_gate" in params:
        gate = kr.apply(params["w_gate"], x, spec, backend=backend)
        h = act(gate) * up
    else:
        h = act(up)
    h = shard(h, "batch", "seq", "ffn")
    if probe is not None:
        # the activation-sparsity site: ReLU-family nonlinearities zero a
        # large fraction of h, and every zero row-element makes its w_down
        # k-slice ineffectual (serve.ledger)
        probe.tap(h, x.shape[-1])
    y = kr.apply(params["w_down"], h, spec, backend=backend)
    # pin the row-parallel product to batch-sharded rows: without this,
    # GSPMD may satisfy the weight's FSDP out-dim by all-gathering the
    # batch over 'data' (a 4.5 GiB/layer intermediate on nemotron-340b).
    return shard(y, "batch", None, "dm_in")


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d: int, dtype=jnp.float32) -> Dict:
    return {"emb": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(params: Dict, tokens: jnp.ndarray, *, scale: float = 1.0) -> jnp.ndarray:
    out = jnp.take(params["emb"], tokens, axis=0)
    if scale != 1.0:
        out = out * jnp.asarray(scale, out.dtype)
    return shard(out, "batch", "seq", None)


def unembed(params: Dict, x: jnp.ndarray, head: Optional[Dict] = None,
            *, softcap: Optional[float] = None) -> jnp.ndarray:
    from repro.kernels import ref as kref   # accum-dtype switch (see ref.py)
    w = head["w"] if head is not None else params["emb"].T
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype),
                        preferred_element_type=kref._DOT_ACCUM)
    logits = logits.astype(jnp.float32)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    return shard(logits, "batch", "seq", "vocab")
