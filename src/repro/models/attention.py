"""Attention variants for the assigned architectures:

  * MHA / GQA (grouped KV heads)                     — all LM archs
  * sliding-window attention w/ circular KV cache    — h2o-danube, mistral (llava), gemma2 local
  * local+global alternation                          — gemma2 (via per-layer window)
  * logit soft-capping                                — gemma2
  * MLA (multi-head latent attention, compressed KV) — minicpm3, deepseek-v2-lite
  * cross-attention                                   — whisper decoder

All projections are Kratos-able. Caches:
  full window:    k/v[(B, KV, S_max, dh)] written at `index`
  sliding window: circular buffer of size W (slot = pos % W) — the cache is
                  O(W) regardless of context length, which is what makes the
                  long_500k cell feasible for SWA archs
  MLA:            compressed c_kv (B, S, r) + shared rotary key (B, S, dr):
                  O(S * (r + dr)) instead of O(S * 2 * H * dh)

Paged serving (serve.paging): the block-paged KV pool stores full-window and
MLA caches page-major behind per-slot page tables. Decode consumes the
table NATIVELY here: when the forward threads a `pages` operand
({'table': (B, pp) int32, 'size': page_size, 'len': cache_len}), the decode
branches below write new K/V with in-place page-indexed scatters
(position p lands in page table[b, p // P] at offset p % P) and read
through the table — the Pallas kernel path (kernels.ops.paged_attention)
streams pages via its BlockSpec index map; the XLA/ref path takes a sliced
contiguous view that is bit-identical to the slab rows on every valid
position, so greedy decode is token-identical to the slab. The per-slot
positional validity masks are what keep unallocated table tail entries
(the shared garbage sink page) inert, the same way they keep the slab's
unwritten tail inert. Without `pages` the slab layout contract above holds
unchanged (train / prefill / suffix-prefill slot views).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import kratos as kr
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    use_rope: bool = True
    causal: bool = True
    window: Optional[int] = None
    softcap: Optional[float] = None
    qk_norm: bool = False
    attn_scale: Optional[float] = None   # override 1/sqrt(dh) (gemma2)
    # MLA
    mla: bool = False
    q_lora_rank: Optional[int] = None
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # cross-attention (whisper decoder)
    cross: bool = False

    @property
    def q_head_dim(self) -> int:
        return (self.qk_nope_dim + self.qk_rope_dim) if self.mla else self.head_dim

    @property
    def v_dim(self) -> int:
        return self.v_head_dim if self.mla else self.head_dim

    @property
    def scale(self) -> float:
        return self.attn_scale if self.attn_scale is not None \
            else self.q_head_dim ** -0.5


# ---------------------------------------------------------------------------
# Core masked attention (positions-aware; handles circular caches)
# ---------------------------------------------------------------------------

def attention_positional(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
                         softcap=None, scale=None, extra_mask=None):
    """q: (B,H,Sq,Dk); k: (B,KV,Skv,Dk); v: (B,KV,Skv,Dv); GQA via reshape.

    q_pos: (Sq,) int32 absolute positions; kv_pos: (Skv,) possibly non-monotonic
    (circular cache); extra_mask: (Skv,) bool validity.

    Continuous-batching decode passes PER-SLOT positions: any of q_pos /
    kv_pos / extra_mask may carry a leading batch axis ((B,Sq) / (B,Skv)),
    in which case the causal/window/validity mask is computed per batch row —
    each request in the slab attends under its own sequence clock.
    """
    b, h, sq, dk = q.shape
    kv, skv = k.shape[1], k.shape[2]
    scale = (dk ** -0.5) if scale is None else scale
    if kv != h:
        # broadcast k/v to full heads BEFORE the einsum: a (kv, g) split of
        # the head dim cannot shard when kv < mesh 'model' size (kv=8 heads
        # on a 16-way axis replicated a 6 GiB score tensor); the broadcast
        # keeps the head axis intact, which shards cleanly.
        g = h // kv
        k = jnp.broadcast_to(k[:, :, None], (b, kv, g, skv, dk)) \
            .reshape(b, h, skv, dk)
        v = jnp.broadcast_to(v[:, :, None], (b, kv, g, skv, v.shape[-1])) \
            .reshape(b, h, skv, v.shape[-1])
    # accumulate in kref dot-accum dtype: with f32-preferred, XLA:CPU hoists
    # a bf16->f32 convert of the (9 GiB, stacked) KV cache INSIDE the layer
    # loop (x2 per layer = TBs of churn); bf16 matches TPU MXU semantics
    # (bf16 operands stream from HBM, accumulate on-core). Softmax math is
    # still f32 (the small score tensor is upcast right after).
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=kref._DOT_ACCUM)
    s = s.astype(jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = jnp.ones((sq, skv), bool)
    qp = q_pos[..., :, None]                  # (Sq,1) or (B,Sq,1)
    kp = kv_pos[..., None, :]                 # (1,Skv) or (B,1,Skv)
    if causal:
        mask = mask & (kp <= qp)
    if window is not None:
        mask = mask & (kp > qp - window)
    if extra_mask is not None:
        mask = mask & extra_mask[..., None, :]
    # (Sq,Skv) -> (1,1,Sq,Skv); per-slot (B,Sq,Skv) -> (B,1,Sq,Skv)
    mask = mask[None, None] if mask.ndim == 2 else mask[:, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
    return o


# Above this many query positions the XLA path streams over q-chunks instead
# of materializing the full (Sq, Skv) score matrix (32k+ prefill would need
# O(S^2) f32 scores = TBs; chunking bounds live memory to chunk x Skv).
CHUNKED_ATTN_THRESHOLD = 4096
CHUNK_Q = 1024


def attention_chunked(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
                      softcap=None, scale=None, extra_mask=None,
                      chunk: int = CHUNK_Q):
    """Flash-style streaming attention in pure jnp (XLA path).

    Identical math to attention_positional but lax.map'd over q chunks, so
    peak live memory is (B, H, chunk, Skv) instead of (B, H, Sq, Skv). Exact
    softmax per chunk row (the full k/v is visible to every chunk).
    """
    b, h, sq, dk = q.shape
    chunk = min(chunk, sq)
    nc, rem = sq // chunk, sq % chunk
    body = sq - rem

    def one(args):
        qi, pi = args
        return attention_positional(qi, k, v, pi, kv_pos, causal=causal,
                                    window=window, softcap=softcap,
                                    scale=scale, extra_mask=extra_mask)

    qc = q[:, :, :body].reshape(b, h, nc, chunk, dk).transpose(2, 0, 1, 3, 4)
    pc = q_pos[:body].reshape(nc, chunk)
    oc = jax.lax.map(one, (qc, pc))                   # (nc, B, H, chunk, dv)
    out = oc.transpose(1, 2, 0, 3, 4).reshape(b, h, body, v.shape[-1])
    if rem:                                           # non-divisible tail
        tail = one((q[:, :, body:], q_pos[body:]))
        out = jnp.concatenate([out, tail], axis=2)
    return out


def _sdpa(q, k, v, cfg: AttnConfig, *, q_pos, kv_pos, extra_mask=None,
          backend="ref", contiguous=False, q_offset=0):
    """Dispatch: flash kernel for contiguous full-seq, positional math otherwise."""
    if (backend in ("pallas", "interpret") and contiguous
            and q.shape[-1] == v.shape[-1]):
        return ops.flash_attention(
            q, k, v, causal=cfg.causal, window=cfg.window, softcap=cfg.softcap,
            q_offset=q_offset, scale=cfg.scale, backend=backend)
    if q.shape[2] > CHUNKED_ATTN_THRESHOLD:
        return attention_chunked(
            q, k, v, q_pos, kv_pos, causal=cfg.causal, window=cfg.window,
            softcap=cfg.softcap, extra_mask=extra_mask, scale=cfg.scale)
    return attention_positional(
        q, k, v, q_pos, kv_pos, causal=cfg.causal, window=cfg.window,
        softcap=cfg.softcap, extra_mask=extra_mask, scale=cfg.scale)


# ---------------------------------------------------------------------------
# Standard (GQA) attention
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: AttnConfig, spec: kr.KratosSpec = kr.DENSE,
             dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 4)
    h, kv, dh, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    p = {
        "wq": kr.init(ks[0], d, h * dh, spec, dtype),
        "wk": kr.init(ks[1], d, kv * dh, spec, dtype),
        "wv": kr.init(ks[2], d, kv * dh, spec, dtype),
        "wo": kr.init(ks[3], h * dh, d, spec, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.rmsnorm_init(dh, dtype)
        p["k_norm"] = L.rmsnorm_init(dh, dtype)
    return p


def _positions_for(index, s: int) -> jnp.ndarray:
    """Absolute positions for a length-s segment starting at `index`.

    index: None (from 0) | scalar (shared decode clock) | (B,) per-slot
    clocks (continuous batching). The vector form is also a `lax.scan` carry
    in the multi-step device-resident decode (distributed.steps), so it must
    stay int32 — a weak-typed python int carry would change dtype across scan
    iterations. Returns (S,) or (B, S) int32."""
    if index is None:
        return jnp.arange(s)
    index = jnp.asarray(index, jnp.int32)
    if index.ndim == 0:
        return index + jnp.arange(s)
    return index[:, None] + jnp.arange(s)[None, :]


def _paged_leaf_view(leaf, table, cache_len: int):
    """Contiguous (B, ..., cache_len, d) view of a page-major cache leaf.

    leaf: (n_pages, ..., P, d); table: (B, pp) int32. The gathered view is
    value-identical to the slab rows on every position the validity masks
    admit (sink-page rows sit past the per-slot clocks), and slicing to
    `cache_len` makes the downstream attention math compile to exactly the
    slab program — the basis of paged/slab token-identity on the ref path.
    """
    g = leaf[table]                            # (B, pp, ..., P, d)
    g = jnp.moveaxis(g, 1, -3)                 # (B, ..., pp, P, d)
    g = g.reshape(*g.shape[:-3], g.shape[-3] * g.shape[-2], g.shape[-1])
    return jax.lax.slice_in_dim(g, 0, cache_len, axis=-2)


def _page_offsets(pages, index, b: int, s: int):
    """(page, offset, last) int32 arrays for writing s tokens at `index`.

    page/offset: (B, s) — position index[b] + j lands in page
    table[b, pos // P] at row pos % P. Positions past the slot's allocated
    footprint hit the table's sink-page tail (page 0): masked garbage, the
    paged analogue of the slab's padded-tail writes. last: (B,) absolute
    position of the final written token (the validity clock)."""
    table = pages["table"]
    psize = pages["size"]
    idx = jnp.asarray(index, jnp.int32)
    if idx.ndim == 0:
        idx = jnp.broadcast_to(idx, (b,))
    pos = idx[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    page = jnp.take_along_axis(table, pos // psize, axis=1)
    return page, pos % psize, idx + (s - 1)


def _split_heads(x, n, dh):
    b, s, _ = x.shape
    return x.reshape(b, s, n, dh).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def gqa_apply(params, x, cfg: AttnConfig, *, spec=kr.DENSE, backend="ref",
              positions=None, cache=None, index=None,
              kv_source=None, pages=None, probe=None,
              ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Full-sequence (train/prefill) or single-step (decode) GQA attention.

    cache: None (train) | dict with 'k','v' (and implicit layout by size).
    index: scalar int32 — tokens already in cache (decode), or None.
    kv_source: encoder output for cross-attention (whisper).
    pages: page-table operand for NATIVE paged decode ({'table','size',
    'len'} — see module docstring); the cache leaves are then page-major
    (n_pages, KV, P, dh). Windowed layers with W < len stay resident slab
    leaves and ignore it.
    probe: serve.ledger probe (or None) — taps the merged attention output
    feeding the packed `wo` GEMM at trace time.
    """
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(kr.apply(params["wq"], x, spec, backend=backend), h, dh)

    if cfg.cross:
        # cross-attention (whisper decoder): k/v from the encoder, cached at
        # prefill, reused verbatim every decode step.
        if cache is not None and index is not None:
            k, v, new_cache = cache["k"], cache["v"], cache
        else:
            k = _split_heads(kr.apply(params["wk"], kv_source, spec,
                                      backend=backend), kv, dh)
            v = _split_heads(kr.apply(params["wv"], kv_source, spec,
                                      backend=backend), kv, dh)
            new_cache = {"k": k, "v": v}
        skv = k.shape[2]
        o = attention_positional(
            q, k.astype(x.dtype), v.astype(x.dtype), jnp.arange(s),
            jnp.arange(skv), causal=False, softcap=cfg.softcap, scale=cfg.scale)
        mo = _merge_heads(o)
        if probe is not None:
            probe.tap(mo, cfg.d_model)
        y = kr.apply(params["wo"], mo, spec, backend=backend)
        return y, new_cache

    kv_in = x if kv_source is None else kv_source
    k = _split_heads(kr.apply(params["wk"], kv_in, spec, backend=backend), kv, dh)
    v = _split_heads(kr.apply(params["wv"], kv_in, spec, backend=backend), kv, dh)
    if cfg.qk_norm:
        q = L.rmsnorm(params["q_norm"], q)
        k = L.rmsnorm(params["k_norm"], k)

    if positions is None:
        positions = _positions_for(index, s)
    if cfg.use_rope:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    q = L.shard(q, "batch", "heads", "seq", None)

    new_cache = None
    if cache is None:
        # training / encoder: contiguous self-attention over s
        o = _sdpa(q, k, v, cfg, q_pos=positions, kv_pos=positions,
                  backend=backend, contiguous=True)
    elif index is None:
        # prefill: fill cache, contiguous attention
        new_cache = _prefill_cache(cache, k, v, cfg)
        o = _sdpa(q, k, v, cfg, q_pos=positions, kv_pos=positions,
                  backend=backend, contiguous=True)
    elif pages is not None and (cfg.window is None
                                or cfg.window >= pages["len"]):
        # NATIVE paged decode: cache leaves are page-major; write the new
        # tokens straight into their pages, read through the table.
        new_cache, o = _paged_gqa_decode(q, k, v, cfg, cache, index, pages,
                                         positions, x.dtype, backend)
    else:
        # decode: write k/v at index (circular for windowed layers), attend
        new_cache, kv_pos, valid = _decode_cache_write(cache, k, v, cfg, index)
        o = attention_positional(
            q, new_cache["k"].astype(x.dtype), new_cache["v"].astype(x.dtype),
            positions, kv_pos, causal=cfg.causal, window=cfg.window,
            softcap=cfg.softcap, extra_mask=valid, scale=cfg.scale)
    mo = _merge_heads(o)
    if probe is not None:
        probe.tap(mo, cfg.d_model)
    y = kr.apply(params["wo"], mo, spec, backend=backend)
    y = L.shard(y, "batch", None, "dm_in")   # see layers.mlp_apply note
    return y, new_cache


def make_gqa_cache(cfg: AttnConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    size = min(max_len, cfg.window) if cfg.window else max_len
    shape = (batch, cfg.n_kv_heads, size, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _prefill_cache(cache, k, v, cfg: AttnConfig):
    """Fill cache from a contiguous prefill of length s (s <= cache size or,
    for windowed layers, keep the last W positions in circular layout)."""
    size = cache["k"].shape[2]
    s = k.shape[2]
    if cfg.window and s > size:
        # keep last `size` positions, placed at their circular slots
        k_tail, v_tail = k[:, :, -size:], v[:, :, -size:]
        start = s - size
        slots = (start + jnp.arange(size)) % size
        inv = jnp.argsort(slots)
        return {"k": k_tail[:, :, inv].astype(cache["k"].dtype),
                "v": v_tail[:, :, inv].astype(cache["v"].dtype)}
    return {
        "k": jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
    }


def _decode_cache_write(cache, k, v, cfg: AttnConfig, index):
    """Write s token(s) at `index`..; return (cache, kv_positions, valid).

    s > 1 is the contiguous block write: the speculative-verify block
    (distributed.steps.make_speculative_decode_step) and the prefix-reuse
    SUFFIX PREFILL (steps.make_suffix_prefill_step — a prompt whose prefix
    KV is already resident lands its unmatched suffix here, batch-1 with a
    scalar `index` = matched length). The s positions land contiguously
    from `index` and validity extends to the LAST written position
    (causality still limits what each query row of the block sees).
    Multi-token writes into a WRAPPING circular window cache are
    unsupported (dynamic_update_slice cannot wrap) — the speculative path
    refuses those archs (serve.speculative.check_supported), prefix reuse
    disables itself on them (serve.paging.prefix_supported), and the slab
    is padded so in-range writes never clamp.

    index: scalar (lock-step batch, one shared position) or (B,) per-slot
    positions (continuous batching) — the vector form writes each batch row
    at its own cache offset and returns per-row (B, size) positions/validity
    for the per-slot attention mask.
    """
    size = cache["k"].shape[2]
    last = index + (k.shape[2] - 1)      # last written position (s == 1: index)
    slot = (index % size) if cfg.window else index
    # the barrier stops XLA from sinking the f32->bf16 convert of the update
    # INTO the stack update — fused, that turns the aliased in-place write
    # into a full cache-stack copy per layer (4.6 GiB x 96 on nemotron).
    k, v = jax.lax.optimization_barrier(
        (k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)))
    slots = jnp.arange(size)
    if jnp.ndim(index) == 0:
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, slot, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, slot, 0))
        if cfg.window:
            # slot s holds the latest position p <= last with p % size == s
            kv_pos = last - ((last - slots) % size)
            valid = kv_pos >= 0
        else:
            kv_pos = slots
            valid = slots <= last
    else:
        write = jax.vmap(
            lambda c, u, at: jax.lax.dynamic_update_slice(c, u, (0, at, 0)))
        ck = write(cache["k"], k, slot)
        cv = write(cache["v"], v, slot)
        if cfg.window:
            kv_pos = last[:, None] - ((last[:, None] - slots[None]) % size)
            valid = kv_pos >= 0
        else:
            kv_pos = slots
            valid = slots[None] <= last[:, None]
    return {"k": ck, "v": cv}, kv_pos, valid


def _paged_gqa_decode(q, k, v, cfg: AttnConfig, cache, index, pages,
                      positions, out_dtype, backend):
    """Page-table-native decode for full-window GQA layers.

    cache['k']/cache['v']: (n_pages, KV, P, dh) page-major store leaves.
    Writes the s new tokens with one in-place page-indexed scatter per leaf
    (the donated store updates in place — no slab view ever materializes),
    then attends: the Pallas/interpret path streams pages through
    kernels.ops.paged_attention's index map; the ref path takes the sliced
    contiguous view and runs the exact slab attention program (bit-identity
    with the slab decode branch by construction)."""
    b, s = q.shape[0], q.shape[2]
    page, off, last = _page_offsets(pages, index, b, s)
    k, v = jax.lax.optimization_barrier(
        (k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)))
    # advanced indices (B,s) at axes 0/2 with a sliced head axis between
    # them move to the front: the update operand is (B, s, KV, dh).
    ck = cache["k"].at[page, :, off, :].set(k.transpose(0, 2, 1, 3))
    cv = cache["v"].at[page, :, off, :].set(v.transpose(0, 2, 1, 3))
    new_cache = {"k": ck, "v": cv}
    if backend in ("pallas", "interpret") and q.shape[-1] == v.shape[-1]:
        o = ops.paged_attention(
            q, ck, cv, pages["table"], last, window=cfg.window,
            softcap=cfg.softcap, scale=cfg.scale, backend=backend)
        return new_cache, o.astype(q.dtype)
    ops.PAGED_ATTN_EVENTS.append(("ref", b, pages["table"].shape[1]))
    k_view = _paged_leaf_view(ck, pages["table"], pages["len"])
    v_view = _paged_leaf_view(cv, pages["table"], pages["len"])
    slots = jnp.arange(pages["len"])
    valid = slots[None] <= last[:, None]
    o = attention_positional(
        q, k_view.astype(out_dtype), v_view.astype(out_dtype),
        positions, slots, causal=cfg.causal, window=cfg.window,
        softcap=cfg.softcap, extra_mask=valid, scale=cfg.scale)
    return new_cache, o


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention) — minicpm3, deepseek-v2
# ---------------------------------------------------------------------------

def mla_init(key, cfg: AttnConfig, spec: kr.KratosSpec = kr.DENSE,
             dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 6)
    d, h = cfg.d_model, cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    p: Dict[str, Any] = {}
    if cfg.q_lora_rank:
        p["wq_a"] = kr.init(ks[0], d, cfg.q_lora_rank, spec, dtype)
        p["q_norm"] = L.rmsnorm_init(cfg.q_lora_rank, dtype)
        p["wq_b"] = kr.init(ks[1], cfg.q_lora_rank, h * qd, spec, dtype)
    else:
        p["wq"] = kr.init(ks[0], d, h * qd, spec, dtype)
    p["wkv_a"] = kr.init(ks[2], d, cfg.kv_lora_rank + cfg.qk_rope_dim, spec, dtype)
    p["kv_norm"] = L.rmsnorm_init(cfg.kv_lora_rank, dtype)
    p["wkv_b"] = kr.init(ks[3], cfg.kv_lora_rank,
                         h * (cfg.qk_nope_dim + cfg.v_head_dim), spec, dtype)
    p["wo"] = kr.init(ks[4], h * cfg.v_head_dim, d, spec, dtype)
    return p


def _mla_q(params, x, cfg, spec, backend):
    h = cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora_rank:
        qa = kr.apply(params["wq_a"], x, spec, backend=backend)
        q = kr.apply(params["wq_b"], L.rmsnorm(params["q_norm"], qa), spec,
                     backend=backend)
    else:
        q = kr.apply(params["wq"], x, spec, backend=backend)
    q = _split_heads(q, h, qd)
    return q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]


def _mla_expand_kv(params, c_kv, cfg, spec, backend):
    """(B, S, r) latent -> k_nope (B,H,S,nope), v (B,H,S,vd)."""
    h = cfg.n_heads
    kvb = kr.apply(params["wkv_b"], c_kv, spec, backend=backend)
    kvb = _split_heads(kvb, h, cfg.qk_nope_dim + cfg.v_head_dim)
    return kvb[..., :cfg.qk_nope_dim], kvb[..., cfg.qk_nope_dim:]


def mla_apply(params, x, cfg: AttnConfig, *, spec=kr.DENSE, backend="ref",
              positions=None, cache=None, index=None,
              kv_source=None, pages=None, probe=None,
              ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    b, s, d = x.shape
    h = cfg.n_heads
    if positions is None:
        positions = _positions_for(index, s)

    q_nope, q_rope = _mla_q(params, x, cfg, spec, backend)
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    kv_a = kr.apply(params["wkv_a"], x, spec, backend=backend)
    c_kv = L.rmsnorm(params["kv_norm"], kv_a[..., :cfg.kv_lora_rank])
    k_rope = kv_a[..., cfg.kv_lora_rank:][:, :, None]          # (B,S,1,dr)
    k_rope = L.apply_rope(k_rope.transpose(0, 2, 1, 3), positions,
                          cfg.rope_theta)                      # (B,1,S,dr)

    new_cache = None
    if cache is not None and index is not None and pages is not None:
        # NATIVE paged decode: append latents straight into their pages
        # (in-place page-indexed scatter), read the sliced table view —
        # value-identical to the slab rows, so the expand below compiles
        # to the exact slab program. MLA stays on the XLA view path (the
        # Pallas paged kernel is GQA-shaped: dk != dv and the latent
        # expansion happens outside the kernel).
        ops.PAGED_ATTN_EVENTS.append(("mla", b, pages["table"].shape[1]))
        c_upd, r_upd = jax.lax.optimization_barrier(
            (c_kv.astype(cache["c_kv"].dtype),
             k_rope.astype(cache["k_rope"].dtype)))
        page, off, last = _page_offsets(pages, index, b, c_upd.shape[1])
        ck = cache["c_kv"].at[page, off, :].set(c_upd)
        cr = cache["k_rope"].at[page, :, off, :].set(
            r_upd.transpose(0, 2, 1, 3))
        new_cache = {"c_kv": ck, "k_rope": cr}
        c_all = _paged_leaf_view(ck, pages["table"], pages["len"])
        kr_all = _paged_leaf_view(cr, pages["table"], pages["len"])
        kv_pos = jnp.arange(pages["len"])
        valid = kv_pos[None] <= last[:, None]
    elif cache is not None and index is not None:
        # decode: append compressed latents, expand the whole cache (naive MLA)
        c_upd, r_upd = jax.lax.optimization_barrier(
            (c_kv.astype(cache["c_kv"].dtype),
             k_rope.astype(cache["k_rope"].dtype)))  # see _decode_cache_write
        last = index + (c_upd.shape[1] - 1)   # s > 1: speculative block write
        if jnp.ndim(index) == 0:
            ck = jax.lax.dynamic_update_slice(
                cache["c_kv"], c_upd, (0, index, 0))
            cr = jax.lax.dynamic_update_slice(
                cache["k_rope"], r_upd, (0, 0, index, 0))
            kv_pos = jnp.arange(ck.shape[1])
            valid = kv_pos <= last
        else:                      # per-slot clocks (continuous batching)
            ck = jax.vmap(
                lambda c, u, at: jax.lax.dynamic_update_slice(c, u, (at, 0)))(
                cache["c_kv"], c_upd, index)
            cr = jax.vmap(
                lambda c, u, at: jax.lax.dynamic_update_slice(c, u, (0, at, 0)))(
                cache["k_rope"], r_upd, index)
            kv_pos = jnp.arange(ck.shape[1])
            valid = kv_pos[None] <= last[:, None]
        new_cache = {"c_kv": ck, "k_rope": cr}
        c_all, kr_all = ck, cr
    elif cache is not None:
        ck = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0))
        cr = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, 0, 0, 0))
        new_cache = {"c_kv": ck, "k_rope": cr}
        c_all, kr_all = c_kv, k_rope
        kv_pos, valid = positions, None
    else:
        c_all, kr_all = c_kv, k_rope
        kv_pos, valid = positions, None

    k_nope, v = _mla_expand_kv(params, c_all.astype(x.dtype), cfg, spec, backend)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_all.astype(x.dtype),
                                  (b, h, k_nope.shape[2], cfg.qk_rope_dim))],
        axis=-1)
    attn_fn = attention_chunked if s > CHUNKED_ATTN_THRESHOLD \
        else attention_positional
    o = attn_fn(
        q, k, v, positions, kv_pos, causal=cfg.causal, window=cfg.window,
        softcap=cfg.softcap, extra_mask=valid, scale=cfg.scale)
    mo = _merge_heads(o)
    if probe is not None:
        probe.tap(mo, cfg.d_model)
    y = kr.apply(params["wo"], mo, spec, backend=backend)
    y = L.shard(y, "batch", None, "dm_in")   # see layers.mlp_apply note
    return y, new_cache


def make_mla_cache(cfg: AttnConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, 1, max_len, cfg.qk_rope_dim), dtype),
    }


# ---------------------------------------------------------------------------
# Unified entry
# ---------------------------------------------------------------------------

def attn_init(key, cfg: AttnConfig, spec=kr.DENSE, dtype=jnp.float32) -> Dict:
    return mla_init(key, cfg, spec, dtype) if cfg.mla else gqa_init(key, cfg, spec, dtype)


def attn_apply(params, x, cfg: AttnConfig, **kw):
    fn = mla_apply if cfg.mla else gqa_apply
    return fn(params, x, cfg, **kw)


def make_cache(cfg: AttnConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    if cfg.cross:
        return None  # built at prefill from encoder output
    return (make_mla_cache if cfg.mla else make_gqa_cache)(cfg, batch, max_len, dtype)
