"""deepseek-v2-lite-16b [moe, MLA] — arXiv:2405.04434.

27L d_model=2048 16H; MLA kv_lora=512, qk_nope=128, qk_rope=64, v_head=128
(no q compression in Lite); MoE: 2 shared + 64 routed experts, top-6,
expert d_ff=1408; first layer dense (d_ff=10944). vocab=102400."""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,             # the single dense (first) layer
    vocab=102400,
    activation="silu",
    mla=True,
    q_lora_rank=None,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_experts=64,
    top_k=6,
    d_ff_expert=1408,
    n_shared_experts=2,
    moe_period=1,
    moe_offset=0,
    prelude_layers=1,
    capacity_factor=1.25,
    tie_embeddings=False,
    rope_theta=10000.0,
    scan_period=1,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b-smoke",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=192, vocab=256,
        activation="silu", mla=True, q_lora_rank=None, kv_lora_rank=32,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, n_experts=8, top_k=2,
        d_ff_expert=32, n_shared_experts=2, moe_period=1, moe_offset=0,
        prelude_layers=1, capacity_factor=2.0, tie_embeddings=False,
        scan_period=1)
