"""deepseek-moe-16b [moe] — arXiv:2401.06066 (DeepSeekMoE).

28L d_model=2048 16H (MHA, head_dim=128); fine-grained experts: 2 shared +
64 routed, top-6, expert d_ff=1408; first layer dense (d_ff=10944);
vocab=102400."""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,
    vocab=102400,
    activation="silu",
    n_experts=64,
    top_k=6,
    d_ff_expert=1408,
    n_shared_experts=2,
    moe_period=1,
    moe_offset=0,
    prelude_layers=1,
    capacity_factor=1.25,
    tie_embeddings=False,
    rope_theta=10000.0,
    scan_period=1,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-smoke",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=192, vocab=256, activation="silu", n_experts=8, top_k=2,
        d_ff_expert=32, n_shared_experts=2, moe_period=1, moe_offset=0,
        prelude_layers=1, capacity_factor=2.0, tie_embeddings=False,
        scan_period=1)
