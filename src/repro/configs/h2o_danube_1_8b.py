"""h2o-danube-1.8b [dense] — arXiv:2401.16818.

24L d_model=2560 32H (GQA kv=8, head_dim=80) d_ff=6912 vocab=32000;
llama+mistral mix with sliding-window attention (4096)."""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab=32000,
    activation="silu",
    window=4096,
    tie_embeddings=False,
    rope_theta=10000.0,
    scan_period=1,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b-smoke",
        n_layers=4, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=192, vocab=256, activation="silu", window=8,
        tie_embeddings=False, scan_period=1)
