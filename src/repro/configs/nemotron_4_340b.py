"""nemotron-4-340b [dense] — arXiv:2402.16819 (Nemotron-4 340B).

96L d_model=18432 96H GQA kv=8 d_ff=73728 vocab=256000; squared-ReLU
(non-gated) MLP, untied embeddings, RoPE."""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab=256000,
    activation="relu2",
    gated_mlp=False,
    tie_embeddings=False,
    rope_theta=10000.0,
    scan_period=1,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b-smoke",
        n_layers=4, d_model=96, n_heads=6, n_kv_heads=2, d_ff=384, vocab=256,
        activation="relu2", gated_mlp=False, tie_embeddings=False,
        scan_period=1)
