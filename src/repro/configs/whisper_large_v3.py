"""whisper-large-v3 [audio enc-dec] — arXiv:2212.04356.

32 encoder + 32 decoder layers, d_model=1280, 20H, d_ff=5120, vocab=51866,
LayerNorm, non-gated GELU MLPs, sinusoidal positions (no RoPE). The conv
frontend is a STUB per the brief: input_specs() provides precomputed frame
embeddings (B, 1500, 1280) = 30 s of audio at 50 fps; the assigned shape's
seq_len/batch apply to the decoder stream (DESIGN.md §arch mapping)."""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    n_layers=32,            # decoder
    n_enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    activation="gelu",
    gated_mlp=False,
    norm="layernorm",
    use_rope=False,
    enc_dec=True,
    enc_positions=1500,
    frontend="audio",
    tie_embeddings=True,
    scan_period=1,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3-smoke",
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, activation="gelu", gated_mlp=False,
        norm="layernorm", use_rope=False, enc_dec=True, enc_positions=24,
        frontend="audio", scan_period=1)
