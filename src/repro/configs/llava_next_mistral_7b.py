"""llava-next-mistral-7b [vlm] — hf:llava-hf/llava-v1.6-mistral-7b-hf.

Mistral-7B backbone: 32L d_model=4096 32H (GQA kv=8, head_dim=128)
d_ff=14336 vocab=32000, sliding window 4096. The anyres vision tower is a
STUB per the brief: input_specs() provides 2880 precomputed patch embeddings
(anyres tiling: 5 tiles x 576 patches) prepended to the text stream."""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    activation="silu",
    window=4096,
    frontend="vision",
    n_img_tokens=2880,
    tie_embeddings=False,
    rope_theta=10000.0,
    scan_period=1,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b-smoke",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, activation="silu", window=8, frontend="vision",
        n_img_tokens=8, tie_embeddings=False, scan_period=1)
