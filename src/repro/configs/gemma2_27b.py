"""gemma2-27b [dense] — arXiv:2408.00118, hf:google/gemma-2-27b.

46L d_model=4608 32H (GQA kv=16, head_dim=128) d_ff=36864 vocab=256000;
local(4096)/global alternating attention, attn softcap 50, final logit
softcap 30, sandwich (pre+post) norms, (1+w) RMSNorm, sqrt(d) embedding
scale, query scale 1/sqrt(144)."""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    activation="gelu_tanh",
    window=4096,
    local_global_period=2,
    attn_softcap=50.0,
    logit_softcap=30.0,
    sandwich_norm=True,
    rmsnorm_plus_one=True,
    emb_scale=4608 ** 0.5,
    attn_scale=(4608 / 32) ** -0.5,
    tie_embeddings=True,
    rope_theta=10000.0,
    scan_period=2,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b-smoke",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab=256, activation="gelu_tanh", window=8,
        local_global_period=2, attn_softcap=50.0, logit_softcap=30.0,
        sandwich_norm=True, rmsnorm_plus_one=True, emb_scale=8.0,
        attn_scale=0.25, scan_period=2)
