"""jamba-v0.1-52b [hybrid] — arXiv:2403.19887.

32L d_model=4096; Mamba:attention 7:1 interleave (attention at index 4 of
each 8-layer Jamba block); MoE (16 experts, top-2, expert ff = 14336) every
2nd layer, dense MLP (14336) otherwise. 32H GQA kv=8. No explicit positional
encoding (the SSM provides position information) — attention runs without
RoPE. vocab=65536, mamba d_state=16 d_conv=4 expand=2."""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    activation="silu",
    use_rope=False,
    n_experts=16,
    top_k=2,
    d_ff_expert=14336,
    n_shared_experts=0,
    moe_period=2,
    moe_offset=1,
    attn_period=8,
    attn_offset=4,
    d_state=16,
    d_conv=4,
    mamba_expand=2,
    capacity_factor=1.25,
    tie_embeddings=False,
    scan_period=8,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-smoke",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
        activation="silu", use_rope=False, n_experts=4, top_k=2,
        d_ff_expert=128, moe_period=2, moe_offset=1, attn_period=8,
        attn_offset=4, d_state=8, d_conv=4, mamba_expand=2,
        capacity_factor=2.0, tie_embeddings=False, scan_period=8,
        ssm_chunk=8)
