"""Architecture registry: the 10 assigned configs + kratos kernel benches.

`get_config(name)` returns the FULL published config (used only by the
512-device dry-run via ShapeDtypeStructs — never allocated on CPU);
`get_smoke(name)` returns a reduced same-family config for CPU smoke tests.
"""

from __future__ import annotations

import importlib
from typing import Dict

ARCH_IDS = (
    "minicpm3_4b",
    "nemotron_4_340b",
    "gemma2_27b",
    "h2o_danube_1_8b",
    "jamba_v0_1_52b",
    "whisper_large_v3",
    "deepseek_v2_lite_16b",
    "deepseek_moe_16b",
    "llava_next_mistral_7b",
    "falcon_mamba_7b",
)

# external ids (as assigned) -> module names
ALIASES = {
    "minicpm3-4b": "minicpm3_4b",
    "nemotron-4-340b": "nemotron_4_340b",
    "gemma2-27b": "gemma2_27b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "whisper-large-v3": "whisper_large_v3",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
}


def _module(name: str):
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{mod_name}")


def get_config(name: str, **overrides):
    import dataclasses
    cfg = _module(name).CONFIG
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_smoke(name: str, **overrides):
    import dataclasses
    cfg = _module(name).smoke_config()
    return dataclasses.replace(cfg, **overrides) if overrides else cfg
