"""minicpm3-4b [dense, MLA] — hf:openbmb/MiniCPM3-4B.

62L d_model=2560 40H d_ff=6400 vocab=73448; MLA with q_lora=768,
kv_lora=256, qk_nope=64, qk_rope=32, v_head=64; muP-style embedding/residual
scaling (scale_emb=12, scale_depth=1.4)."""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab=73448,
    activation="silu",
    mla=True,
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    emb_scale=12.0,
    residual_scale=1.4 / (62 ** 0.5),
    tie_embeddings=True,
    rope_theta=10000.0,
    scan_period=1,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b-smoke",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160, vocab=256,
        activation="silu", mla=True, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        emb_scale=12.0, residual_scale=1.4 / 2.0, scan_period=1)
