"""falcon-mamba-7b [ssm] — arXiv:2410.05355.

64 pure Mamba-1 layers (attention-free), d_model=4096, d_inner=8192
(expand=2), d_state=16, d_conv=4, dt_rank=256, vocab=65024; RMSNorm on the
B/C/dt streams (the falcon-mamba stabilization)."""

from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    n_layers=64,
    d_model=4096,
    n_heads=1,            # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=65024,
    is_ssm=True,
    d_state=16,
    d_conv=4,
    mamba_expand=2,
    bcdt_rms=True,
    tie_embeddings=False,
    scan_period=1,
    ssm_chunk=256,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b-smoke",
        n_layers=4, d_model=64, n_heads=1, n_kv_heads=1, d_ff=0, vocab=256,
        is_ssm=True, d_state=8, d_conv=4, mamba_expand=2, bcdt_rms=True,
        tie_embeddings=False, scan_period=1, ssm_chunk=8)
