"""Dense weight-stationary tiled GEMM — the TPU analogue of Kratos' `gemms`
(weight-stationary systolic array).

Like the FPGA systolic array, this datapath is *structurally dense*: zero
weights still occupy MXU cycles and HBM bandwidth, so its cost is independent
of sparsity. It exists (a) as the head-to-head baseline for the Fig. 5
reproduction (tree prunes, systolic doesn't) and (b) as the dense fast path
when sparsity == 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat as _compat


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_kb: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(t == n_kb - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def dense_matmul(
    x: jnp.ndarray,    # (m, n)
    w: jnp.ndarray,    # (n, p)
    *,
    bm: int = 128,
    bk: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    m, n = x.shape
    n2, p = w.shape
    assert n == n2, (x.shape, w.shape)
    # skinny-m path (decode: m = n_slots): adapt the row block to a
    # sublane-aligned size and zero-pad m up to it; the pad rows cost one
    # sublane of MXU work and are sliced off below.
    bm = _compat.skinny_bm(m, bm, x.dtype)
    x, m_orig = _compat.pad_rows(x, bm, "dense_matmul")
    m = x.shape[0]
    for name, dim, b in (("m", m, bm), ("n", n, bk), ("p", p, bn)):
        if dim % b:
            raise ValueError(f"{name}={dim} not divisible by its block {b}")
    grid = (m // bm, p // bn, n // bk)
    kernel = functools.partial(_mm_kernel, n_kb=n // bk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk, bn), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((m, p), x.dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)
    return out if m == m_orig else out[:m_orig]
