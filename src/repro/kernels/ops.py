"""Jit'd public wrappers around the Pallas kernels with backend dispatch.

Backends:
  'ref'       pure-jnp oracle (XLA) — default on CPU; used by the 512-device
              dry-run (Pallas lowers to TPU-only custom calls).
  'pallas'    real Pallas lowering — the TPU target.
  'interpret' Pallas kernel body executed step-by-step on CPU — used by the
              kernel test suite to validate the TPU code path.
  'auto'      'pallas' on TPU, 'ref' elsewhere.

Skinny-m: every GEMM accepts any row count m >= 1. Decode batches are
m = n_slots (a handful of rows); the kernels adapt their row block to a
sublane-aligned size, zero-pad m up to it and slice the result back
(pallas_compat.skinny_bm / pad_rows). `SKINNY_M_EVENTS` (re-exported here)
records each padded dispatch at trace time so serving benchmarks can assert
the decode GEMMs really run the packed Pallas path at slab width.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quantize as qz
from repro.kernels import ref as _ref
from repro.kernels.bsr_matmul import bsr_matmul as _bsr_pallas
from repro.kernels.dense_matmul import dense_matmul as _dense_pallas
from repro.kernels.quant_matmul import (
    quant_matmul as _quant_pallas,
    quant_matmul_w8a8 as _w8a8_pallas,
    bsr_quant_matmul as _bsr_quant_pallas,
)
from repro.kernels.flash_attention import flash_attention as _fa_pallas
from repro.kernels.flash_attention import (
    paged_flash_attention as _paged_fa_pallas,
)
from repro.kernels.pallas_compat import (  # noqa: F401 (re-export)
    PAGED_ATTN_EVENTS,
    SKINNY_M_EVENTS,
)

VALID_BACKENDS = ("auto", "ref", "pallas", "interpret")


def resolve_backend(backend: str) -> str:
    if backend not in VALID_BACKENDS:
        raise ValueError(f"backend must be one of {VALID_BACKENDS}, got {backend}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return backend


def _fit_block(block: int, dim: int) -> int:
    """Largest power-of-two block <= `block` that divides `dim`."""
    c = min(block, dim)
    while c > 1 and dim % c:
        c //= 2
    return max(c, 1)


def matmul(x, w, *, backend: str = "auto", bm: int = 128, bk: int = 128,
           bn: int = 128):
    """Dense weight-stationary GEMM ('systolic' analogue)."""
    b = resolve_backend(backend)
    if b == "ref":
        return _ref.dense_matmul_ref(x, w)
    m, n = x.shape
    p = w.shape[1]
    # bm is NOT fitted to m: the kernel's skinny-m path pads the row dim to a
    # sublane-aligned block (fitting bm to e.g. m=4 would force sub-sublane
    # tiles that the TPU cannot lay out).
    bk, bn = _fit_block(bk, n), _fit_block(bn, p)
    return _dense_pallas(x, w, bm=bm, bk=bk, bn=bn, interpret=(b == "interpret"))


def bsr_matmul(x, blocks, indices, *, backend: str = "auto", bm: int = 128):
    """Block-sparse tree GEMM; FLOPs ∝ (1 - sparsity)."""
    b = resolve_backend(backend)
    if b == "ref":
        return _ref.bsr_matmul_scan_ref(x, blocks, indices)
    return _bsr_pallas(x, blocks, indices, bm=bm,
                       interpret=(b == "interpret"))


def _fit_quant_blocks(qt, bk: int, bn: int):
    """Fit k/n blocks to the tensor (small smoke models have n < 128).

    The fitted bk is automatically a multiple of the sub-byte packing
    factor: pack_codes requires n % vpb == 0 and vpb is a power of two, so
    the largest power-of-two divisor of n is >= vpb."""
    n, p = qt.shape
    return _fit_block(bk, n), _fit_block(bn, p)


def quant_matmul(x, qt: qz.QuantizedTensor, *, backend: str = "auto",
                 bm: int = 128, bk: int = 128, bn: int = 128):
    """Weight-only quantized GEMM (w{8,4,2,1}a16)."""
    b = resolve_backend(backend)
    if b == "ref":
        return _ref.quant_matmul_ref(x, qt)
    bk, bn = _fit_quant_blocks(qt, bk, bn)
    return _quant_pallas(x, qt, bm=bm, bk=bk, bn=bn, interpret=(b == "interpret"))


def quant_matmul_w8a8(x, qt: qz.QuantizedTensor, *, backend: str = "auto",
                      bm: int = 128, bk: int = 128, bn: int = 128):
    b = resolve_backend(backend)
    if b == "ref":
        return _ref.quant_matmul_w8a8_ref(x, qt)
    bk, bn = _fit_quant_blocks(qt, bk, bn)
    return _w8a8_pallas(x, qt, bm=bm, bk=bk, bn=bn, interpret=(b == "interpret"))


def bsr_quant_matmul(x, qblocks, scales, indices, bits: int, *,
                     backend: str = "auto", bm: int = 128):
    """Sparse + quantized tree GEMM (pruning x quantization compounded)."""
    b = resolve_backend(backend)
    if b == "ref":
        return _ref.bsr_quant_matmul_ref(x, qblocks, scales, indices, bits)
    return _bsr_quant_pallas(x, qblocks, scales, indices, bits, bm=bm,
                             interpret=(b == "interpret"))


def ssm_scan(u, dt, b, c, a, *, backend: str = "auto", bd: int = 128,
             ck: int = 16):
    """Selective-scan (Mamba-1) recurrence. Returns (y, h_final)."""
    from repro.kernels import ssm_scan as _ssm
    bk = resolve_backend(backend)
    if bk == "ref":
        return _ssm.ssm_scan_ref(u, dt, b, c, a)
    return _ssm.ssm_scan(u, dt, b, c, a, bd=bd, ck=ck,
                         interpret=(bk == "interpret"))


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    q_offset: int = 0, scale=None, backend: str = "auto",
                    bq: int = 128, bkv: int = 128):
    """q: (b, h, sq, d); k, v: (b, h_kv, skv, d). Returns (b, h, sq, d)."""
    b, h, sq, d = q.shape
    _, h_kv, skv, _ = k.shape
    bk = resolve_backend(backend)
    if bk == "ref":
        g = h // h_kv
        kk = jnp.repeat(k, g, axis=1) if g > 1 else k
        vv = jnp.repeat(v, g, axis=1) if g > 1 else v
        return _ref.attention_ref(q, kk, vv, causal=causal, window=window,
                                  softcap=softcap, q_offset=q_offset, scale=scale)
    out = _fa_pallas(
        q.reshape(b * h, sq, d), k.reshape(b * h_kv, skv, d),
        v.reshape(b * h_kv, skv, d),
        causal=causal, window=window, softcap=softcap, q_offset=q_offset,
        scale=scale, bq=bq, bkv=bkv, interpret=(bk == "interpret"))
    return out.reshape(b, h, sq, d)


def paged_attention(q, k_pages, v_pages, table, last, *, window=None,
                    softcap=None, scale=None, backend: str = "auto"):
    """Page-table-native decode attention.

    q: (b, h, sq, d); k_pages/v_pages: (n_pages, h_kv, P, d) page-major
    store leaves; table: (b, pp) int32 page ids; last: (b,) int32 absolute
    position of each slot's final query token. Returns (b, h, sq, d).
    Causal by construction. Records a PAGED_ATTN_EVENTS entry at trace time
    so serving tests/benchmarks can assert the gather-free path dispatched.
    """
    bk = resolve_backend(backend)
    PAGED_ATTN_EVENTS.append((bk, q.shape[0], table.shape[1]))
    if bk == "ref":
        return _ref.paged_attention_ref(
            q, k_pages, v_pages, table, last,
            window=window, softcap=softcap, scale=scale)
    return _paged_fa_pallas(
        q, k_pages, v_pages, table, last, window=window, softcap=softcap,
        scale=scale, interpret=(bk == "interpret"))
