"""Tiled (flash) attention for TPU with causal / sliding-window / soft-cap
support and GQA-aware k/v streaming.

Not part of Kratos itself, but the perf-critical compute of the assigned LM
architectures — and the same Kratos philosophy applies at tile level: blocks
that are *structurally* dead (fully above the causal diagonal, or outside the
sliding window) are skipped entirely via `pl.when`, so compute scales with
the live fraction of the score matrix, exactly like tree-pruning dead MACs.

Layout: q (bh, sq, d); k, v (bh_kv, skv, d); GQA group g = bh // bh_kv is
resolved in the BlockSpec index map (no k/v broadcast is materialized).
Running max / denominator live in (bq, 128) VMEM scratch (lane-replicated),
the standard TPU idiom.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat as _compat

_LANES = 128
_NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               bq: int, bkv: int, n_kv: int, scale: float,
               causal: bool, window: Optional[int],
               softcap: Optional[float], q_offset: int):
    iq = pl.program_id(1)
    ikv = pl.program_id(2)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq + q_offset           # absolute position of this q tile
    kv_start = ikv * bkv

    # Structural block skipping (the "pruned tree" of attention):
    live = jnp.bool_(True)
    if causal:
        live &= kv_start <= q_start + bq - 1
    if window is not None:
        live &= kv_start + bkv - 1 > q_start - window

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.bool_(True)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * alpha
                        + jnp.dot(p.astype(v_ref.dtype), v_ref[0],
                                  preferred_element_type=jnp.float32))
        m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_cur, l_ref.shape)

    @pl.when(ikv == n_kv - 1)
    def _flush():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,            # (bh, sq, d)
    k: jnp.ndarray,            # (bh_kv, skv, d)
    v: jnp.ndarray,            # (bh_kv, skv, d)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, sq, d = q.shape
    bh_kv, skv, _ = k.shape
    assert bh % bh_kv == 0, (bh, bh_kv)
    g = bh // bh_kv
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    if sq % bq or skv % bkv:
        raise ValueError(f"seq lengths ({sq},{skv}) not divisible by blocks ({bq},{bkv})")
    scale = (d ** -0.5) if scale is None else scale
    grid = (bh, sq // bq, skv // bkv)
    kernel = functools.partial(
        _fa_kernel, bq=bq, bkv=bkv, n_kv=skv // bkv, scale=scale,
        causal=causal, window=window, softcap=softcap, q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j: (h // g, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
