"""Tiled (flash) attention for TPU with causal / sliding-window / soft-cap
support and GQA-aware k/v streaming.

Not part of Kratos itself, but the perf-critical compute of the assigned LM
architectures — and the same Kratos philosophy applies at tile level: blocks
that are *structurally* dead (fully above the causal diagonal, or outside the
sliding window) are skipped entirely via `pl.when`, so compute scales with
the live fraction of the score matrix, exactly like tree-pruning dead MACs.

Layout: q (bh, sq, d); k, v (bh_kv, skv, d); GQA group g = bh // bh_kv is
resolved in the BlockSpec index map (no k/v broadcast is materialized).
Running max / denominator live in (bq, 128) VMEM scratch (lane-replicated),
the standard TPU idiom.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat as _compat

_LANES = 128
_NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               bq: int, bkv: int, n_kv: int, scale: float,
               causal: bool, window: Optional[int],
               softcap: Optional[float], q_offset: int):
    iq = pl.program_id(1)
    ikv = pl.program_id(2)

    @pl.when(ikv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq + q_offset           # absolute position of this q tile
    kv_start = ikv * bkv

    # Structural block skipping (the "pruned tree" of attention):
    live = jnp.bool_(True)
    if causal:
        live &= kv_start <= q_start + bq - 1
    if window is not None:
        live &= kv_start + bkv - 1 > q_start - window

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.bool_(True)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * alpha
                        + jnp.dot(p.astype(v_ref.dtype), v_ref[0],
                                  preferred_element_type=jnp.float32))
        m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_cur, l_ref.shape)

    @pl.when(ikv == n_kv - 1)
    def _flush():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,            # (bh, sq, d)
    k: jnp.ndarray,            # (bh_kv, skv, d)
    v: jnp.ndarray,            # (bh_kv, skv, d)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    bh, sq, d = q.shape
    bh_kv, skv, _ = k.shape
    assert bh % bh_kv == 0, (bh, bh_kv)
    g = bh // bh_kv
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    if sq % bq or skv % bkv:
        raise ValueError(f"seq lengths ({sq},{skv}) not divisible by blocks ({bq},{bkv})")
    scale = (d ** -0.5) if scale is None else scale
    grid = (bh, sq // bq, skv // bkv)
    kernel = functools.partial(
        _fa_kernel, bq=bq, bkv=bkv, n_kv=skv // bkv, scale=scale,
        causal=causal, window=window, softcap=softcap, q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j: (h // g, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, _LANES), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Page-table-native decode attention (serve.paging)
# ---------------------------------------------------------------------------

def _paged_kernel(table_ref, last_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, sq: int, s_pad: int, page: int,
                  n_pp: int, scale: float, window: Optional[int],
                  softcap: Optional[float]):
    """One (slot, head, kv-page) grid cell of paged decode attention.

    The page table and per-slot `last` clocks arrive as scalar-prefetch
    operands: the K/V BlockSpec index maps read `table_ref[b, ip]` to
    translate (slot, kv-block) -> page id, so K/V stream straight from the
    page-major store — no gathered slab view exists anywhere. Positions are
    derived from the grid (kv position = ip * page + column), and per-slot
    validity is the causal test against `last_ref[b]`: sink-page rows and
    write-headroom garbage all live at positions > last and mask out.
    """
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    last_b = last_ref[b]
    kv_start = ip * page
    # Runtime block skipping — the paged analogue of _fa_kernel's structural
    # `live`: pages wholly past the slot's clock (allocation headroom, sink
    # rows) or wholly behind its window never touch the MXU.
    live = kv_start <= last_b
    if window is not None:
        live &= kv_start + page - 1 > last_b - (sq - 1) - window

    @pl.when(live)
    def _compute():
        qv = q_ref[0, 0].astype(jnp.float32)            # (s_pad, d)
        kv = k_ref[0, 0].astype(jnp.float32)            # (page, d)
        s = jax.lax.dot_general(qv, kv, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        # query row i sits at absolute position last - (sq - 1) + i; padded
        # rows (i >= sq) see later positions and are sliced away by the
        # caller, so their extra visibility is harmless.
        qpos = (last_b - (sq - 1)
                + jax.lax.broadcasted_iota(jnp.int32, (s_pad, page), 0))
        kpos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (s_pad, page), 1)
        mask = kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * alpha
                        + jnp.dot(p.astype(v_ref.dtype), v_ref[0, 0],
                                  preferred_element_type=jnp.float32))
        m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_cur, l_ref.shape)

    @pl.when(ip == n_pp - 1)
    def _flush():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_flash_attention(
    q: jnp.ndarray,            # (b, h, sq, d) — decode block, sq small
    k_pages: jnp.ndarray,      # (n_pages, h_kv, P, d) page-major store leaf
    v_pages: jnp.ndarray,      # (n_pages, h_kv, P, d)
    table: jnp.ndarray,        # (b, pp) int32 page ids (sink page = 0)
    last: jnp.ndarray,         # (b,) int32 absolute position of q[:, -1]
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Decode attention reading K/V directly through the page table.

    Grid (b, h, pages_per_slot); `table`/`last` ride the scalar-prefetch
    path so the K/V index maps resolve page ids before each block's DMA.
    GQA resolves in the index map (h // g) exactly like flash_attention.
    Causal by construction (decode: queries are the stream tail).
    """
    b, h, sq, d = q.shape
    n_pages, h_kv, page, _ = k_pages.shape
    assert h % h_kv == 0, (h, h_kv)
    g = h // h_kv
    pp = table.shape[1]
    scale = (d ** -0.5) if scale is None else scale

    sub = _compat.sublane(q.dtype)
    s_pad = -(-sq // sub) * sub
    if s_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, s_pad - sq), (0, 0)))

    kernel = functools.partial(
        _paged_kernel, sq=sq, s_pad=s_pad, page=page, n_pp=pp, scale=scale,
        window=window, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, pp),
        in_specs=[
            pl.BlockSpec((1, 1, s_pad, d),
                         lambda ib, ih, ip, tbl, lst: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, page, d),
                         lambda ib, ih, ip, tbl, lst: (tbl[ib, ip],
                                                       ih // g, 0, 0)),
            pl.BlockSpec((1, 1, page, d),
                         lambda ib, ih, ip, tbl, lst: (tbl[ib, ip],
                                                       ih // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, s_pad, d),
                               lambda ib, ih, ip, tbl, lst: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((s_pad, _LANES), jnp.float32),
            pltpu.VMEM((s_pad, _LANES), jnp.float32),
            pltpu.VMEM((s_pad, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, s_pad, d), q.dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(table.astype(jnp.int32), last.astype(jnp.int32), q, k_pages, v_pages)
    return out[:, :, :sq] if s_pad != sq else out
