"""Block-sparse weight-stationary GEMM — the TPU kernel for Kratos' `gemmt`
multiply-adder tree.

The FPGA tree prunes zero-weight leaves at synthesis; here, the *grid itself*
is pruned: the kernel iterates only over the `nnz` nonzero k-blocks of each
output-column block. Zero blocks are never fetched from HBM and never touch
the MXU, so compute and weight traffic scale with (1 - sparsity) — the
paper's Fig. 5 linearity, in time instead of area.

The per-output-block k-index table rides in as a scalar-prefetch operand
(SMEM), so the x BlockSpec's index_map can look up which k-tile to stream —
the Pallas/TPU idiom for data-dependent-but-statically-shaped access.

Grid: (m/bm, n_pb, nnz), k innermost ('arbitrary') so the f32 VMEM scratch
accumulates across the pruned k-loop and is flushed once per output tile.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat as _compat


def _bsr_kernel(idx_ref, x_ref, b_ref, o_ref, acc_ref, *, nnz: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], b_ref[0, 0],
        preferred_element_type=jnp.float32)

    @pl.when(t == nnz - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def bsr_matmul(
    x: jnp.ndarray,            # (m, n)
    blocks: jnp.ndarray,       # (n_pb, nnz, bk, bn)
    indices: jnp.ndarray,      # int32[n_pb, nnz]
    *,
    bm: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    m, n = x.shape
    n_pb, nnz, bk, bn = blocks.shape
    # skinny-m path: decode runs at m = n_slots; pad to a sublane-aligned
    # row block instead of rejecting, and slice the pad rows off at the end.
    bm = _compat.skinny_bm(m, bm, x.dtype)
    x, m_orig = _compat.pad_rows(x, bm, "bsr_matmul")
    m = x.shape[0]
    if m % bm:
        raise ValueError(f"m={m} not divisible by bm={bm}")
    if n % bk:
        raise ValueError(f"n={n} not divisible by bk={bk}")

    grid = (m // bm, n_pb, nnz)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            # x tile: the k-index is read from the prefetched table.
            pl.BlockSpec((bm, bk), lambda i, j, t, idx: (i, idx[j, t])),
            # one packed weight block (j, t).
            pl.BlockSpec((1, 1, bk, bn), lambda i, j, t, idx: (j, t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t, idx: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    kernel = functools.partial(_bsr_kernel, nnz=nnz)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n_pb * bn), x.dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(indices, jnp.int32), x, blocks)
    return out if m == m_orig else out[:m_orig]
