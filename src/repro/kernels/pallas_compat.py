"""Version compatibility for the Pallas TPU API surface.

jax renamed `pltpu.TPUCompilerParams` -> `pltpu.CompilerParams`; the kernels
are written against the new name and this shim resolves whichever the
installed jax provides.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams",
                         getattr(_pltpu, "TPUCompilerParams", None))

if CompilerParams is None:                             # pragma: no cover
    class CompilerParams:  # type: ignore[no-redef]
        """Fail loudly at construction, not with a NoneType call error."""

        def __init__(self, *args, **kwargs):
            raise ImportError(
                "this jax exposes neither pallas-TPU CompilerParams nor "
                "TPUCompilerParams; update repro.kernels.pallas_compat for "
                "the installed jax version")
