"""Version compatibility + shared tiling helpers for the Pallas TPU kernels.

jax renamed `pltpu.TPUCompilerParams` -> `pltpu.CompilerParams`; the kernels
are written against the new name and this shim resolves whichever the
installed jax provides.

Also hosts the skinny-m row-padding helpers shared by every GEMM kernel:
decode batches are m = n_slots (4-ish) rows while the kernels tile m in
MXU-sized blocks, so each kernel pads m up to a sublane-aligned block and
slices the result back (`pad_rows` / `skinny_bm`). Events are recorded in
`SKINNY_M_EVENTS` at trace time so benchmarks/tests can assert the decode
GEMMs really take this path (same idiom as serve_bench.PackedRouteCounter).
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
from jax.experimental.pallas import tpu as _pltpu

from repro.instrument import REGISTRY

CompilerParams = getattr(_pltpu, "CompilerParams",
                         getattr(_pltpu, "TPUCompilerParams", None))

if CompilerParams is None:                             # pragma: no cover
    class CompilerParams:  # type: ignore[no-redef]
        """Fail loudly at construction, not with a NoneType call error."""

        def __init__(self, *args, **kwargs):
            raise ImportError(
                "this jax exposes neither pallas-TPU CompilerParams nor "
                "TPUCompilerParams; update repro.kernels.pallas_compat for "
                "the installed jax version")


# ---------------------------------------------------------------------------
# Skinny-m support (decode GEMMs: m = n_slots << 128)
# ---------------------------------------------------------------------------

# TPU minimum second-to-minor tile extent by element width (pallas guide):
# f32 -> 8, bf16/f16 -> 16, int8/fp8 -> 32. The lane dim is always 128.
_SUBLANE_BY_ITEMSIZE = {4: 8, 2: 16, 1: 32}

# (kernel_name, m, bm) appended whenever a GEMM pads its row dim — at trace
# time, like kratos.apply_packed instrumentation. Registry-backed
# (repro.instrument.REGISTRY, stream "skinny_m"): wrap trace-and-assert
# blocks in `REGISTRY.scoped(...)` instead of hand-clearing; the historical
# name stays as an alias of the same list.
SKINNY_M_EVENTS = REGISTRY.event_list("skinny_m")

# (backend, n_slots, pages_per_slot) appended whenever the paged-attention
# decode path traces — same trace-time idiom as SKINNY_M_EVENTS. Benchmarks
# and tests assert page-table-native decode really dispatched (and that the
# gather/scatter wrap did NOT) by inspecting this alongside
# serve.paging.GATHER_EVENTS. Registry stream "paged_attn".
PAGED_ATTN_EVENTS = REGISTRY.event_list("paged_attn")


def sublane(dtype) -> int:
    """Minimum sublane multiple for `dtype` (second-to-minor tile extent)."""
    return _SUBLANE_BY_ITEMSIZE.get(jnp.dtype(dtype).itemsize, 8)


def skinny_bm(m: int, bm: int, dtype) -> int:
    """Adaptive row-block for any m >= 1.

    Policy, in order: (1) m divides bm's grid — keep the caller's bm (no
    padding, no event); (2) a sublane-aligned power-of-two block divides m
    exactly — use it (large non-divisible m keeps an exact grid, e.g. m=200
    runs bm=8 with zero pad rows); (3) otherwise pad: block = m rounded up
    to the dtype's sublane multiple, capped at bm but never below the
    sublane minimum — a 4-row f32 decode GEMM gets an 8-row block instead
    of failing the `m % 128` check (or silently building a 0-sized grid)."""
    if m % bm == 0:
        return bm
    sub = sublane(dtype)
    exact = 1
    while exact * 2 <= min(bm, m):
        exact *= 2                      # largest power of two <= min(bm, m)
    while exact > 1 and m % exact:
        exact //= 2
    if exact >= sub:
        return exact
    m_up = -(-m // sub) * sub
    return max(sub, min(bm, m_up))


def pad_rows(x: jnp.ndarray, bm: int, kernel: str) -> Tuple[jnp.ndarray, int]:
    """Zero-pad the row dim of `x` up to a multiple of `bm`.

    Returns (padded_x, original_m); callers slice the kernel output back to
    original_m rows. Records a SKINNY_M_EVENTS entry when padding happens.
    """
    m = x.shape[0]
    pad = (-m) % bm
    if pad == 0:
        return x, m
    SKINNY_M_EVENTS.append((kernel, m, bm))
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1)), m
