"""Selective-scan (Mamba-1) Pallas kernel — the fix for the worst roofline
cell in the 40-cell table (falcon-mamba train/prefill, memory-dominated).

The XLA path materializes the state tensor h = (B, S, d_inner, d_state) in
HBM (assoc-scan levels make it ~10x worse): ~460 s memory term on the
production mesh. This kernel keeps the recurrence state in VMEM and streams
only the O(B*S*d_inner) inputs/outputs through HBM — the state never touches
HBM at all:

    HBM traffic = u, dt (B,S,di) in; B_, C_ (B,S,st) in; y (B,S,di) out
                ≈ 3-4 * B*S*di * bytes  (vs ~ B*S*di*st * levels for XLA)
                => st * ~10 = ~160x less state traffic.

Layout: the (di, st) state lives transposed as (st, bd) VMEM scratch so the
d_inner tile (bd=128) rides the 128-lane axis and d_state=16 the sublanes —
every per-step op is a full-width VPU op. The sequence axis is the innermost
('arbitrary') grid dim: chunks of ck positions stream through VMEM while the
scratch carries the state across chunks; inside a chunk the recurrence is
unrolled (ck small, default 16).

Numerics match models.ssm exactly: h_t = exp(dt_t*A)*h_{t-1} + dt_t*B_t*u_t,
y_t = C_t . h_t (the D*u and gating terms stay outside, they're elementwise).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat as _compat


def _ssm_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, h_ref, state_ref,
                *, ck: int, n_ck: int, return_final: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    a_t = a_ref[...].T.astype(jnp.float32)             # (st, bd)
    ys = []
    for t in range(ck):
        dt_t = dt_ref[0, t].astype(jnp.float32)        # (bd,)
        u_t = u_ref[0, t].astype(jnp.float32)
        b_t = b_ref[0, t].astype(jnp.float32)          # (st,)
        c_t = c_ref[0, t].astype(jnp.float32)
        da = jnp.exp(dt_t[None, :] * a_t)              # (st, bd)
        dbx = (dt_t * u_t)[None, :] * b_t[:, None]
        state_ref[...] = da * state_ref[...] + dbx
        ys.append(jnp.sum(state_ref[...] * c_t[:, None], axis=0))  # (bd,)
    y_ref[0, ...] = jnp.stack(ys).astype(y_ref.dtype)

    if return_final:
        @pl.when(k == n_ck - 1)
        def _flush():
            h_ref[0, ...] = state_ref[...].T.astype(h_ref.dtype)


def ssm_scan(
    u: jnp.ndarray,        # (B, S, di) pre-activation inputs
    dt: jnp.ndarray,       # (B, S, di) softplus'd step sizes
    b: jnp.ndarray,        # (B, S, st) input gate
    c: jnp.ndarray,        # (B, S, st) output gate
    a: jnp.ndarray,        # (di, st)   negative state matrix (-exp(A_log))
    *,
    bd: int = 128,
    ck: int = 16,
    interpret: bool = False,
):
    """Returns (y (B, S, di), h_final (B, di, st))."""
    bsz, s, di = u.shape
    st = a.shape[1]
    if di % bd:
        raise ValueError(f"d_inner={di} not divisible by bd={bd}")
    if s % ck:
        raise ValueError(f"seq={s} not divisible by ck={ck}")
    n_ck = s // ck
    grid = (bsz, di // bd, n_ck)
    kernel = functools.partial(_ssm_kernel, ck=ck, n_ck=n_ck,
                               return_final=True)
    y, h = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ck, bd), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, ck, bd), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, ck, st), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((1, ck, st), lambda i, j, k: (i, k, 0)),
            pl.BlockSpec((bd, st), lambda i, j, k: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, ck, bd), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((1, bd, st), lambda i, j, k: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, di), u.dtype),
            jax.ShapeDtypeStruct((bsz, di, st), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((st, bd), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(u, dt, b, c, a)
    return y, h


def ssm_scan_ref(u, dt, b, c, a):
    """Pure-jnp oracle (same math as models.ssm sequential reference)."""
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * a.astype(jnp.float32))
    dBx = (dt.astype(jnp.float32) * u.astype(jnp.float32))[..., None] \
        * b.astype(jnp.float32)[:, :, None, :]

    def step(h, xs):
        da, dbx = xs
        h = da * h + dbx
        return h, h

    bsz, s, di, st = dA.shape
    h0 = jnp.zeros((bsz, di, st), jnp.float32)
    h_last, hs = jax.lax.scan(step, h0, (dA.transpose(1, 0, 2, 3),
                                         dBx.transpose(1, 0, 2, 3)))
    hs = hs.transpose(1, 0, 2, 3)                       # (B, S, di, st)
    y = jnp.einsum("bsdn,bsn->bsd", hs, c.astype(jnp.float32))
    return y.astype(u.dtype), h_last
